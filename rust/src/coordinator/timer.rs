//! Hashed timer wheel for the event-loop server's idle-timeout reaping.
//!
//! The threaded server charges one `SO_RCVTIMEO` per blocking read; an
//! event loop has one thread and thousands of connections, so timeouts
//! become data: each connection schedules an entry at
//! `last_activity + timeout`, and the loop asks the wheel how long
//! `poll` may sleep and which entries have come due.
//!
//! The wheel is deliberately coarse. Slots cover `granularity` each
//! (`timeout / 8`, clamped to 10–500 ms), so an entry fires within one
//! granularity of its deadline — idle-timeout enforcement, not a
//! high-resolution timer. Entries are `(token, conn_id)` pairs; firing
//! is **advisory**: the loop re-validates against the connection's
//! actual `last_activity` (the connection may have spoken since, or the
//! slot may even hold a closed connection's recycled token — the
//! monotonic `conn_id` catches that) and reschedules instead of closing
//! when the entry is stale. That re-validation is also why deadlines
//! beyond the wheel's span can simply be clamped to the farthest slot.

use std::time::{Duration, Instant};

/// Slots in the wheel. With the granularity clamp this spans at least
/// 640 ms and at most 32 s — always ≥ the 8-granularity timeout, so an
/// in-span deadline never wraps onto a nearer slot.
pub const WHEEL_SLOTS: usize = 64;

/// One idle-deadline registry for a single timeout duration.
pub struct TimerWheel {
    slots: Vec<Vec<(usize, u64)>>,
    granularity: Duration,
    /// Start of the current slot's coverage window; advances by one
    /// granularity per tick as `expire` consumes time.
    base: Instant,
    cursor: usize,
    len: usize,
}

impl TimerWheel {
    pub fn new(timeout: Duration, now: Instant) -> TimerWheel {
        let granularity = (timeout / 8)
            .max(Duration::from_millis(10))
            .min(Duration::from_millis(500));
        TimerWheel {
            slots: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            granularity,
            base: now,
            cursor: 0,
            len: 0,
        }
    }

    pub fn granularity(&self) -> Duration {
        self.granularity
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Register `(token, conn_id)` to fire at `deadline`. Deadlines
    /// beyond the wheel's span clamp to the farthest slot (the early
    /// fire is re-validated and rescheduled); deadlines at or before
    /// `base` land one slot out rather than firing instantly.
    pub fn schedule(&mut self, deadline: Instant, token: usize, conn_id: u64) {
        let ticks = deadline
            .saturating_duration_since(self.base)
            .as_nanos()
            .checked_div(self.granularity.as_nanos())
            .unwrap_or(0) as usize;
        let offset = ticks.clamp(1, WHEEL_SLOTS - 1);
        let slot = (self.cursor + offset) % WHEEL_SLOTS;
        self.slots[slot].push((token, conn_id));
        self.len += 1;
    }

    /// How long the poller may sleep before the next entry could come
    /// due. `None` when the wheel is empty.
    pub fn next_wakeup(&self, now: Instant) -> Option<Duration> {
        if self.is_empty() {
            return None;
        }
        Some((self.base + self.granularity).saturating_duration_since(now))
    }

    /// Advance through every slot whose window has fully elapsed by
    /// `now`, draining their entries. The caller re-validates each
    /// entry before acting on it.
    pub fn expire(&mut self, now: Instant) -> Vec<(usize, u64)> {
        let mut due = Vec::new();
        while now.saturating_duration_since(self.base) >= self.granularity {
            self.base += self.granularity;
            self.cursor = (self.cursor + 1) % WHEEL_SLOTS;
            let fired = std::mem::take(&mut self.slots[self.cursor]);
            self.len -= fired.len();
            due.extend(fired);
        }
        due
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_after_the_timeout_not_before() {
        let t0 = Instant::now();
        let timeout = Duration::from_millis(800);
        let mut w = TimerWheel::new(timeout, t0);
        assert_eq!(w.granularity(), Duration::from_millis(100));
        w.schedule(t0 + timeout, 3, 7);
        assert!(!w.is_empty());
        // Just before the deadline window: nothing fires.
        assert!(w.expire(t0 + Duration::from_millis(650)).is_empty());
        // Once the covering slot elapses, the entry is due.
        let due = w.expire(t0 + timeout + w.granularity());
        assert_eq!(due, vec![(3, 7)]);
        assert!(w.is_empty());
        // Entries drain exactly once.
        assert!(w.expire(t0 + Duration::from_secs(60)).is_empty());
    }

    #[test]
    fn granularity_clamps_short_and_long_timeouts() {
        let t0 = Instant::now();
        assert_eq!(
            TimerWheel::new(Duration::from_millis(8), t0).granularity(),
            Duration::from_millis(10)
        );
        assert_eq!(
            TimerWheel::new(Duration::from_secs(3600), t0).granularity(),
            Duration::from_millis(500)
        );
    }

    #[test]
    fn far_deadlines_clamp_to_the_wheel_span_and_still_fire() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(Duration::from_millis(80), t0);
        // Deadline far past the span: clamped, fires at span's edge
        // (the event loop re-validates and reschedules — early is fine,
        // lost is not).
        w.schedule(t0 + Duration::from_secs(3600), 1, 1);
        let span = w.granularity() * WHEEL_SLOTS as u32;
        let due = w.expire(t0 + span + w.granularity());
        assert_eq!(due, vec![(1, 1)]);
    }

    #[test]
    fn past_deadlines_fire_on_the_next_tick() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(Duration::from_millis(80), t0);
        w.schedule(t0, 9, 2); // already due
        assert!(w.next_wakeup(t0).is_some());
        let due = w.expire(t0 + w.granularity() * 2);
        assert_eq!(due, vec![(9, 2)]);
    }

    #[test]
    fn next_wakeup_tracks_the_tick_boundary() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(Duration::from_millis(800), t0);
        assert_eq!(w.next_wakeup(t0), None, "empty wheel needs no wakeup");
        w.schedule(t0 + Duration::from_millis(400), 1, 1);
        let d = w.next_wakeup(t0).unwrap();
        assert!(d <= w.granularity());
    }
}
