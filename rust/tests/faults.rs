//! End-to-end robustness under deterministic fault injection.
//!
//! A live event-loop server with a seeded fault plane (torn frames,
//! short reads/writes, dispatch stalls) is driven by the self-healing
//! `ResilientClient`. The properties under test are the PR's acceptance
//! criteria: no acknowledged observation is ever lost or double-applied
//! (retry + dedup = exactly-once), the plans a chaos run serves are
//! bit-identical to a fault-free control, and shed requests come back as
//! structured `overloaded` errors on a connection that stays open.
//!
//! The event loop is unix-only, and it is the only front end with the
//! wire-seam fault hooks, so the whole file is gated.
#![cfg(unix)]

use std::time::Duration;

use ksplus::coordinator::eventloop::EventLoopServer;
use ksplus::coordinator::faults::FaultSpec;
use ksplus::coordinator::protocol::{ErrorCode, Request};
use ksplus::coordinator::remote::{RemoteClient, ResilientClient, RetryPolicy};
use ksplus::coordinator::server::ServerConfig;
use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
use ksplus::coordinator::BackendSpec;
use ksplus::trace::Execution;

fn start_server(faults: Option<&FaultSpec>) -> (Coordinator, EventLoopServer) {
    let coord = Coordinator::start(
        CoordinatorConfig { k: 3, shards: 2, ..Default::default() },
        BackendSpec::Native,
    )
    .expect("start coordinator");
    let server = EventLoopServer::start_with_config(
        "127.0.0.1:0",
        coord.client(),
        ServerConfig { faults: faults.map(FaultSpec::plane), ..Default::default() },
    )
    .expect("start event-loop server");
    (coord, server)
}

/// A client tuned for fault soaking: mutation retry (with dedup stamps)
/// on, short backoffs, a breaker threshold far above any plausible
/// unlucky streak — the tests measure healing, not fail-fast.
fn healing_client(addr: std::net::SocketAddr, seed: u64) -> ResilientClient {
    let mut rc = ResilientClient::new(
        addr.to_string(),
        RetryPolicy {
            max_attempts: 20,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(20),
            retry_mutations: true,
            breaker_threshold: 64,
            breaker_cooldown: Duration::from_millis(20),
            seed,
        },
    );
    rc.set_timeout(Some(Duration::from_secs(10)));
    rc.set_max_wire_version(2);
    rc
}

fn exec(task: &str, i: u64) -> Execution {
    let input = 1000.0 + 10.0 * i as f64;
    let samples: Vec<f64> = (0..6)
        .map(|j: u64| 0.001 * input * (0.5 + 0.1 * ((i + j) % 5) as f64))
        .collect();
    Execution::new(task, input, 1.0, samples)
}

#[test]
fn seeded_chaos_loses_no_acks_and_plans_match_fault_free_control() {
    let inputs = [1500.0, 4200.0, 8000.0];
    let mut total_retries = 0u64;
    for seed in [3u64, 17, 99] {
        // Control: the identical logical op sequence, no faults, driven
        // through the in-process client.
        let control = Coordinator::start(
            CoordinatorConfig { k: 3, shards: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .expect("start control coordinator");
        let ctl = control.client();
        let spec = FaultSpec::parse(&format!(
            "seed={seed},short-io=0.25,corrupt=0.15,stall=0.2:1"
        ))
        .expect("parse fault spec");
        let (_coord, mut server) = start_server(Some(&spec));
        let mut rc = healing_client(server.addr(), 0xACC0 ^ seed);

        let hist: Vec<Execution> = (0..8).map(|i| exec("chaos-task", i)).collect();
        ctl.train("chaos-task", hist.clone());
        assert_eq!(rc.train("chaos-task", &hist).expect("train"), 8, "seed {seed}");

        let mut acked = 0u64;
        for i in 0..30u64 {
            let e = exec("chaos-task", 100 + i);
            ctl.observe("chaos-task", e.clone());
            let ack = rc.observe("chaos-task", &e).expect("observe");
            acked += 1;
            // The ack itself proves exactly-once as it goes: a lost fold
            // or a double-applied retry would skew the running count.
            assert_eq!(ack.executions, 8 + acked, "seed {seed}");
        }
        // Exactly-once, server-side: every acked observation counted
        // once, none lost, none duplicated by a replayed retry.
        let stats = rc.stats().expect("stats");
        assert_eq!(stats.observations, acked, "seed {seed}: lost or duplicated acks");
        // The chaos run serves plans bit-identical to the control:
        // injected faults may cost retries, never state.
        for &input in &inputs {
            let chaos = rc.plan("chaos-task", input).expect("plan").plan;
            let clean = ctl.plan("chaos-task", input);
            assert_eq!(
                format!("{:?}/{:?}", chaos.starts, chaos.peaks),
                format!("{:?}/{:?}", clean.starts, clean.peaks),
                "seed {seed}, input {input}: chaos diverged from fault-free control"
            );
        }
        total_retries += rc.counters().retries;
        server.stop();
    }
    // Across three seeded runs the fault plane virtually certainly fired;
    // a zero here means the injection never reached the wire seam.
    assert!(total_retries > 0, "chaos runs never needed a single retry");
}

#[test]
fn heavy_frame_tearing_still_applies_mutations_exactly_once() {
    // corrupt=0.3 tears roughly a third of all response frames (acks and
    // hello responses alike), severing the connection each time — the
    // harshest dedup workout short of a dead server.
    let spec = FaultSpec::parse("seed=5,corrupt=0.3").expect("parse fault spec");
    let (_coord, mut server) = start_server(Some(&spec));
    let mut rc = healing_client(server.addr(), 0xBEEF);

    let hist: Vec<Execution> = (0..6).map(|i| exec("dedup-task", i)).collect();
    assert_eq!(rc.train("dedup-task", &hist).expect("train"), 6);
    for i in 0..20u64 {
        let ack = rc.observe("dedup-task", &exec("dedup-task", 100 + i)).expect("observe");
        assert_eq!(ack.executions, 6 + i + 1);
    }
    let stats = rc.stats().expect("stats");
    assert_eq!(stats.observations, 20, "retries broke exactly-once");
    assert_eq!(stats.tasks_trained, 1);
    let c = rc.counters();
    assert!(c.retries > 0, "corrupt=0.3 never tore a frame: {c:?}");
    assert!(c.reconnects > 0, "torn frames never severed the connection: {c:?}");
    server.stop();
}

#[test]
fn shed_requests_are_structured_overloaded_and_the_connection_survives() {
    let coord = Coordinator::start(
        CoordinatorConfig { k: 3, shards: 1, ..Default::default() },
        BackendSpec::Native,
    )
    .expect("start coordinator");
    let mut server = EventLoopServer::start_with_config(
        "127.0.0.1:0",
        coord.client(),
        ServerConfig { max_inflight: 2, ..Default::default() },
    )
    .expect("start event-loop server");
    let mut rc = RemoteClient::connect(server.addr()).expect("connect");
    rc.negotiate(2).expect("negotiate");

    // One pipelined burst far past the in-flight cap: the excess must
    // come back as `overloaded`, in order, without closing the socket.
    let reqs: Vec<Request> = (0..8)
        .map(|_| Request::Plan { task: "t".into(), input_mb: 100.0 })
        .collect();
    let verdicts = rc.pipeline(&reqs).expect("pipelined burst");
    assert_eq!(verdicts.len(), 8);
    let ok = verdicts.iter().filter(|v| v.is_ok()).count();
    let shed = verdicts
        .iter()
        .filter(|v| matches!(v, Err(e) if e.code == ErrorCode::Overloaded))
        .count();
    assert_eq!(ok + shed, 8, "a verdict was neither served nor overloaded");
    assert!(ok >= 2, "the in-flight cap starved admitted requests");
    assert!(shed >= 1, "an 8-deep burst past max_inflight=2 never shed");
    // The very same connection still serves — shedding is load control,
    // not a protocol error — and the stats counters agree with the
    // client's view.
    let s = rc.stats().expect("stats on the shed connection");
    assert_eq!(s.shed as usize, shed);
    assert_eq!(s.requests as usize, ok);
    server.stop();
}
