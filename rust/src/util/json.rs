//! Minimal JSON substrate (no `serde_json` offline): parser + serializer.
//!
//! Used for `artifacts/manifest.json`, experiment result files, and the
//! coordinator's wire format. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII data).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\n\t\"\\ bA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\ bA"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → ∞"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"file":"fit.hlo.txt","shape":[256,512]}],"n":2}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn display_escaping_roundtrip() {
        let j = Json::Str("line\nbreak \"q\" \\ tab\t".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn manifest_shape_access() {
        let src = r#"{"buckets":{"fit_b":256,"fit_n":512},"entries":[{"name":"fit","inputs":[{"shape":[256,512]}]}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("buckets").unwrap().get("fit_b").unwrap().as_usize(), Some(256));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
    }
}
