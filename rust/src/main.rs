//! `repro` — KS+ reproduction CLI.
//!
//! Subcommands:
//!   experiment <figN|all>  regenerate a paper figure's data
//!   trace-gen              write a synthetic workflow trace as CSV
//!   segment                segment a trace's executions (Algorithm 1)
//!   simulate               cluster simulation with a chosen method
//!   serve                  smoke-run the online coordinator
//!   loadgen                closed-loop load test over shard counts
//!   scenarios              perturbed-stream wastage matrix per policy
//!   protocol-smoke         wire conformance check over live TCP (v1/v2)
//!   record                 capture golden session traces from a live server
//!   replay                 re-drive traces, assert bit-identical responses
//!
//! Run `repro <cmd> --help` for flags.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
use ksplus::coordinator::{BackendSpec, PredictorPolicy};
use ksplus::experiments::{self, ExpConfig};
use ksplus::predictor;
use ksplus::segments::algorithm::get_segments;
use ksplus::sim::cluster::{run_cluster, ClusterConfig, PredictorSource};
use ksplus::trace::workflow::Workflow;
use ksplus::trace::{io as trace_io, split_train_test};
use ksplus::util::cli::Command;
use ksplus::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        print_help();
        return;
    }
    let cmd = args[0].clone();
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "experiment" => cmd_experiment(rest),
        "trace-gen" => cmd_trace_gen(rest),
        "segment" => cmd_segment(rest),
        "simulate" => cmd_simulate(rest),
        "serve" => cmd_serve(rest),
        "loadgen" => cmd_loadgen(rest),
        "scenarios" => cmd_scenarios(rest),
        "protocol-smoke" => cmd_protocol_smoke(rest),
        "record" => cmd_record(rest),
        "replay" => cmd_replay(rest),
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "repro — KS+ (e-Science 2024) reproduction\n\n\
         USAGE: repro <command> [flags]\n\n\
         COMMANDS:\n\
           experiment <fig1a..fig8|all>   regenerate a figure (see DESIGN.md)\n\
           trace-gen                      synthesize a workflow trace (CSV)\n\
           segment                        run Algorithm 1 on a trace\n\
           simulate                       discrete-event cluster simulation\n\
           serve                          coordinator service smoke run\n\
           loadgen                        closed-loop coordinator load test\n\
           scenarios                      perturbed-stream wastage matrix per policy\n\
           protocol-smoke                 wire conformance check over TCP (v1/v2)\n\
           record                         capture golden session traces\n\
           replay                         replay traces, assert bit-identity\n"
    );
}

/// Resolve a `--policy` flag value, listing the valid names on error.
fn policy_from_flag(name: &str) -> Result<PredictorPolicy> {
    PredictorPolicy::parse(name).with_context(|| {
        format!(
            "unknown policy '{name}' (valid: {})",
            PredictorPolicy::names().join(", ")
        )
    })
}

fn exp_config(a: &ksplus::util::cli::Args) -> Result<ExpConfig> {
    let seeds: Vec<u64> = (1..=a.get_usize("seeds")? as u64).collect();
    Ok(ExpConfig {
        seeds,
        k: a.get_usize("k")?,
        capacity_gb: a.get_f64("capacity")?,
        trace_seed: a.get_u64("trace-seed")?,
        trace_csv: a.get("trace").map(PathBuf::from),
        ..Default::default()
    })
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let cmd = Command::new("repro experiment <id>", "Regenerate a paper figure")
        .flag("seeds", "number of train/test split seeds", Some("10"))
        .flag("k", "segment count for segment methods", Some("4"))
        .flag("capacity", "node memory capacity in GB", Some("128"))
        .flag("trace-seed", "trace generation seed", Some("42"))
        .flag(
            "trace",
            "evaluate on this ingested CSV (either supported header shape) instead of \
             the synthetic workflows",
            None,
        )
        .flag("out", "directory for JSON results", Some("results"));
    let a = cmd.parse(argv)?;
    let Some(id) = a.positional.first() else {
        bail!("missing experiment id\n\n{}", cmd.usage());
    };
    let cfg = exp_config(&a)?;
    let out_dir = a.get("out").map(PathBuf::from);
    let text = experiments::run(id, &cfg, out_dir.as_deref())?;
    print!("{text}");
    Ok(())
}

fn cmd_trace_gen(argv: &[String]) -> Result<()> {
    let cmd = Command::new("repro trace-gen", "Synthesize a workflow trace")
        .flag("workflow", "eager or sarek", Some("eager"))
        .flag("seed", "generation seed", Some("42"))
        .flag("samples", "target samples per execution", Some("200"))
        .flag("out", "output CSV path", Some("trace.csv"));
    let a = cmd.parse(argv)?;
    let name = a.get("workflow").unwrap();
    let wf = Workflow::by_name(name).with_context(|| format!("unknown workflow '{name}'"))?;
    let trace = wf.generate(a.get_u64("seed")?, a.get_usize("samples")?);
    let out = PathBuf::from(a.get("out").unwrap());
    trace_io::write_csv(&out, &trace)?;
    println!(
        "wrote {} executions of {} task types to {}",
        trace.total_instances(),
        trace.tasks.len(),
        out.display()
    );
    Ok(())
}

fn cmd_segment(argv: &[String]) -> Result<()> {
    let cmd = Command::new("repro segment", "Segment a trace (Algorithm 1)")
        .flag("trace", "input CSV (from trace-gen)", None)
        .flag("task", "task type to segment", Some("bwa"))
        .flag("k", "number of segments", Some("4"))
        .flag("limit", "max executions to print", Some("5"));
    let a = cmd.parse(argv)?;
    let Some(path) = a.get("trace") else {
        bail!("--trace is required\n\n{}", cmd.usage());
    };
    let trace = trace_io::read_csv(Path::new(path), "input")?;
    let task = a.get("task").unwrap();
    let traces = trace.task(task).with_context(|| format!("no task '{task}' in trace"))?;
    let k = a.get_usize("k")?;
    for (i, e) in traces.executions.iter().take(a.get_usize("limit")?).enumerate() {
        let seg = get_segments(&e.samples, k);
        let plan = seg.to_plan(e.dt);
        println!(
            "exec {i}: input {:.0} MB, duration {:.0} s -> {} segments",
            e.input_mb,
            e.duration(),
            seg.peaks.len()
        );
        for j in 0..seg.peaks.len() {
            println!(
                "  segment {j}: start {:>7.1} s  peak {:>6.2} GB",
                plan.starts[j], plan.peaks[j]
            );
        }
    }
    Ok(())
}

struct Trained(std::collections::BTreeMap<String, Box<dyn predictor::Predictor>>);

impl PredictorSource for Trained {
    fn get(&self, task: &str) -> Option<&dyn predictor::Predictor> {
        self.0.get(task).map(|p| p.as_ref())
    }
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("repro simulate", "Cluster simulation")
        .flag("workflow", "eager or sarek", Some("eager"))
        .flag("method", "prediction method", Some("ksplus"))
        .flag("k", "segments", Some("4"))
        .flag("nodes", "cluster nodes", Some("4"))
        .flag("capacity", "GB per node", Some("128"))
        .flag("seed", "trace + split seed", Some("42"))
        .flag("train-frac", "training fraction", Some("0.5"));
    let a = cmd.parse(argv)?;
    let wf = Workflow::by_name(a.get("workflow").unwrap()).context("unknown workflow")?;
    let trace = wf.generate(a.get_u64("seed")?, 200);
    let method = a.get("method").unwrap();
    let k = a.get_usize("k")?;
    let capacity = a.get_f64("capacity")?;
    let frac = a.get_f64("train-frac")?;

    // Train per task; simulate the concatenated test sets.
    let mut predictors = Trained(Default::default());
    let mut test_executions = Vec::new();
    for (idx, t) in trace.tasks.iter().enumerate() {
        let mut rng = Rng::new(a.get_u64("seed")?).fork(idx as u64 + 1);
        let (train, test) = split_train_test(t, frac, &mut rng);
        let pred =
            experiments::trained_predictor(method, k, capacity, &wf, &t.task, &train)?;
        predictors.0.insert(t.task.clone(), pred);
        test_executions.extend(test);
    }
    let cfg = ClusterConfig { nodes: a.get_usize("nodes")?, node_capacity_gb: capacity };
    let r = run_cluster(&cfg, &predictors, &test_executions);
    println!("== cluster simulation: {} / {} ==", wf.name, method);
    println!("tasks          : {}", r.outcomes.len());
    println!("makespan       : {:.0} s", r.makespan_s);
    println!("throughput     : {:.1} tasks/h", r.throughput_per_h);
    println!("mean wait      : {:.1} s", r.mean_wait_s);
    println!("total wastage  : {:.0} GBs", r.report.total_wastage_gbs());
    println!("failures       : {}", r.report.total_failures());
    println!("efficiency     : {:.1}% of allocated GBs used", r.report.efficiency() * 100.0);
    Ok(())
}

/// Default serve backend: PJRT when compiled in, else native.
#[cfg(feature = "pjrt")]
const DEFAULT_BACKEND: &str = "pjrt";
#[cfg(not(feature = "pjrt"))]
const DEFAULT_BACKEND: &str = "native";

/// Resolve a `--backend` flag value into a spec, failing fast when the
/// binary lacks the feature it needs.
fn backend_spec_from_flag(backend: &str) -> Result<BackendSpec> {
    let spec = match backend {
        "native" => BackendSpec::Native,
        "pjrt" => BackendSpec::Pjrt(None),
        other => bail!("unknown backend '{other}'"),
    };
    if !spec.available() {
        bail!(
            "this repro binary was built without the 'pjrt' feature; rebuild \
             with `cargo build --release --features pjrt` or pass --backend native"
        );
    }
    Ok(spec)
}

/// Either TCP front end, so `serve` and `protocol-smoke` hold whichever
/// one the flags picked. The event loop is the default wherever the
/// readiness syscalls exist; `--threaded` keeps the thread-per-connection
/// server reachable as a parity oracle.
enum FrontEnd {
    Threaded(ksplus::coordinator::server::Server),
    #[cfg(unix)]
    EventLoop(ksplus::coordinator::eventloop::EventLoopServer),
}

impl FrontEnd {
    fn addr(&self) -> std::net::SocketAddr {
        match self {
            FrontEnd::Threaded(s) => s.addr(),
            #[cfg(unix)]
            FrontEnd::EventLoop(s) => s.addr(),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            FrontEnd::Threaded(_) => "threaded",
            #[cfg(unix)]
            FrontEnd::EventLoop(_) => "eventloop",
        }
    }
}

/// Start the requested front end over a coordinator client. `threaded:
/// false` asks for the event loop, which only exists where epoll/kqueue
/// do.
#[cfg(unix)]
fn start_front_end(
    addr: &str,
    client: ksplus::coordinator::service::Client,
    cfg: ksplus::coordinator::server::ServerConfig,
    threaded: bool,
) -> Result<FrontEnd> {
    if threaded {
        Ok(FrontEnd::Threaded(ksplus::coordinator::server::Server::start_with_config(
            addr, client, cfg,
        )?))
    } else {
        Ok(FrontEnd::EventLoop(ksplus::coordinator::eventloop::EventLoopServer::start_with_config(
            addr, client, cfg,
        )?))
    }
}

#[cfg(not(unix))]
fn start_front_end(
    addr: &str,
    client: ksplus::coordinator::service::Client,
    cfg: ksplus::coordinator::server::ServerConfig,
    _threaded: bool,
) -> Result<FrontEnd> {
    // No epoll/kqueue on this platform: the threaded server is the only
    // front end, whatever the flag says.
    Ok(FrontEnd::Threaded(ksplus::coordinator::server::Server::start_with_config(
        addr, client, cfg,
    )?))
}

/// Deterministic fingerprint of the plans the service would serve: one
/// fixed-input plan per trained task (sorted by name), hashed over the
/// exact f64 bits via the plan's shortest-roundtrip text form. Two
/// coordinators print the same fingerprint iff they serve bit-identical
/// plans — CI compares this line across a snapshot/restore cycle.
fn plan_fingerprint(client: &ksplus::coordinator::service::Client, tasks: &[String]) -> u64 {
    let mut text = String::new();
    let mut sorted: Vec<&String> = tasks.iter().collect();
    sorted.sort();
    for task in sorted {
        for input in [1500.0, 6000.0, 9000.0] {
            let out = client.plan_detailed(task, input);
            text.push_str(&format!(
                "{task}/{input}:{:?}/{:?}/{}/{}/{:?};",
                out.plan.starts, out.plan.peaks, out.predictor, out.model_version,
                out.fallback_reason
            ));
        }
    }
    ksplus::util::fnv1a(&text)
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    use ksplus::coordinator::server::ServerConfig;
    use ksplus::coordinator::snapshot;

    let cmd = Command::new("repro serve", "Coordinator service smoke run or TCP server")
        .flag("backend", "native or pjrt", Some(DEFAULT_BACKEND))
        .flag("requests", "number of plan requests (smoke mode)", Some("1000"))
        .flag("k", "segments", Some("4"))
        .flag("shards", "coordinator worker shards", Some("1"))
        .flag(
            "policy",
            "default predictor policy (ksplus | witt-lr | tovar-ppm | ksegments | default-limits)",
            Some("ksplus"),
        )
        .flag("workflow", "training workflow", Some("eager"))
        .flag("listen", "serve the JSON wire protocol on this addr (e.g. 127.0.0.1:7070)", None)
        .flag(
            "snapshot-dir",
            "restore model state from this directory on start and persist it there \
             (periodically in listen mode, on exit in smoke mode)",
            None,
        )
        .flag("snapshot-every", "seconds between periodic snapshots in listen mode", Some("30"))
        .flag("max-conns", "maximum concurrent wire connections", Some("1024"))
        .flag(
            "idle-timeout",
            "close wire connections idle for this many seconds (0 = never)",
            Some("0"),
        )
        .flag(
            "max-frame-bytes",
            "maximum request frame size in bytes, on either wire",
            Some("1048576"),
        )
        .flag(
            "dispatch-threads",
            "event-loop dispatch worker threads (0 = size from the core count)",
            Some("0"),
        )
        .flag(
            "max-queue-depth",
            "bound the event-loop dispatch queue; excess requests are shed with a \
             structured 'overloaded' error (0 = unbounded)",
            Some("0"),
        )
        .flag(
            "max-inflight",
            "cap in-flight requests per connection; past it requests on that \
             connection are shed with 'overloaded' (0 = unbounded)",
            Some("0"),
        )
        .flag(
            "fault-spec",
            "arm deterministic fault injection, e.g. \
             seed=42,short-io=0.1,corrupt=0.05,stall=0.1:5,torn=0.01 (see docs/PROTOCOL.md)",
            None,
        )
        .bool_flag(
            "threaded",
            "serve with the thread-per-connection front end instead of the event loop",
        );
    let a = cmd.parse(argv)?;
    let spec = backend_spec_from_flag(a.get("backend").unwrap())?;
    let policy = policy_from_flag(a.get("policy").unwrap())?;
    let wf = Workflow::by_name(a.get("workflow").unwrap()).context("unknown workflow")?;
    let trace = wf.generate(42, 150);
    let shards = a.get_usize("shards")?;
    let coord = Coordinator::start(
        CoordinatorConfig {
            k: a.get_usize("k")?,
            shards,
            default_policy: policy,
            ..Default::default()
        },
        spec,
    )?;
    let client = coord.client();
    let snapshot_dir = a.get("snapshot-dir").map(PathBuf::from);
    let faults = match a.get("fault-spec") {
        Some(s) => {
            let spec = ksplus::coordinator::faults::FaultSpec::parse(s)
                .with_context(|| format!("parsing --fault-spec '{s}'"))?;
            eprintln!("fault injection armed: {s}");
            Some(spec.plane())
        }
        None => None,
    };

    // Crash-safety: a snapshot on disk wins over the synthetic
    // pre-training — restoring it reproduces the exact pre-crash plans.
    // A torn snapshot (crash mid-write of a pre-atomic writer, or an
    // injected torn-write fault) must not wedge the service: warn, leave
    // the debris for forensics, start from synthetic training instead.
    let mut restored = 0usize;
    if let Some(dir) = &snapshot_dir {
        match snapshot::load_snapshot_file(dir)? {
            snapshot::SnapshotLoad::Loaded(doc) => {
                restored = client.restore_snapshot(&doc)?;
                println!(
                    "restored {restored} task models from {}",
                    snapshot::snapshot_path(dir).display()
                );
            }
            snapshot::SnapshotLoad::Corrupt { path, reason } => {
                eprintln!(
                    "warning: ignoring corrupt snapshot {} ({reason}); \
                     starting from synthetic training",
                    path.display()
                );
            }
            snapshot::SnapshotLoad::Missing => {}
        }
    }
    if restored == 0 {
        for t in &trace.tasks {
            client.train(&t.task, t.executions.clone());
        }
    }
    let task_names: Vec<String> = trace.tasks.iter().map(|t| t.task.clone()).collect();

    if let Some(addr) = a.get("listen") {
        // Server mode: expose the wire protocol and block. The event
        // loop serves by default where it exists; --threaded keeps the
        // thread-per-connection oracle reachable.
        let idle = a.get_u64("idle-timeout")?;
        let server_cfg = ServerConfig {
            max_conns: a.get_usize("max-conns")?,
            read_timeout: (idle > 0).then(|| std::time::Duration::from_secs(idle)),
            max_frame_bytes: a.get_usize("max-frame-bytes")?,
            dispatch_threads: a.get_usize("dispatch-threads")?,
            max_queue_depth: a.get_usize("max-queue-depth")?,
            max_inflight: a.get_usize("max-inflight")?,
            faults: faults.clone(),
            ..Default::default()
        };
        let server = start_front_end(addr, coord.client(), server_cfg, a.get_bool("threaded"))?;
        println!(
            "serving {} predictions on {} ({} front end, {} task models pre-trained, {} shard(s))\n\
             protocol: wire v1 (one JSON object per line) by default; negotiate wire v2\n\
             (length-prefixed binary) via hello — op: hello | configure | train | observe |\n\
             plan | failure | stats | snapshot | reshard (see docs/PROTOCOL.md)\n\
             Ctrl-C to stop.",
            policy.name(),
            server.addr(),
            server.kind(),
            trace.tasks.len(),
            shards
        );
        let every = a.get_u64("snapshot-every")?;
        match &snapshot_dir {
            Some(dir) if every > 0 => {
                // Periodic persistence: a crash loses at most `every`
                // seconds of training.
                let dir = dir.clone();
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(every));
                    // A failed periodic snapshot (disk trouble, or an
                    // injected torn write) costs durability, not
                    // availability — the server keeps serving.
                    match snapshot::write_snapshot_file_faulted(
                        &dir,
                        &client.snapshot_json(),
                        faults.as_deref(),
                    ) {
                        Ok(path) => eprintln!("snapshot written to {}", path.display()),
                        Err(e) => eprintln!("warning: snapshot failed: {e:#}"),
                    }
                }
            }
            _ => loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            },
        }
    }
    let n = a.get_usize("requests")?;
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        let task = &trace.tasks[rng.below(trace.tasks.len())];
        let input = task.executions[rng.below(task.executions.len())].input_mb;
        let plan = client.plan(&task.task, input);
        assert!(plan.is_valid());
    }
    let elapsed = t0.elapsed();
    let stats = client.stats();
    println!("== coordinator smoke run ({}) ==", a.get("backend").unwrap());
    println!("shards         : {shards}");
    println!("requests       : {}", stats.requests);
    println!("batches        : {} (mean size {:.1})", stats.batches, stats.mean_batch_size());
    println!("throughput     : {:.0} plans/s", n as f64 / elapsed.as_secs_f64());
    println!("latency p50    : {:.0} us", stats.latency_percentile_us(50.0));
    println!("latency p99    : {:.0} us", stats.latency_percentile_us(99.0));
    println!("plan fingerprint: {:016x}", plan_fingerprint(&client, &task_names));
    if let Some(dir) = &snapshot_dir {
        let path = snapshot::write_snapshot_file(dir, &client.snapshot_json())?;
        println!("snapshot       : {}", path.display());
    }
    Ok(())
}

fn cmd_loadgen(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "repro loadgen",
        "Closed-loop load generator: plans/sec and latency per shard count",
    )
    .flag("shards", "comma-separated shard counts to sweep (e.g. 1,2,4)", Some("1"))
    .flag("clients", "concurrent closed-loop client threads", Some("8"))
    .flag("requests", "total plan requests per shard count", Some("5000"))
    .flag("observe-frac", "probability of an observe op per plan (online retraining mix)", Some("0"))
    .flag("k", "segments", Some("4"))
    .flag(
        "policy",
        "predictor policy the tasks train and serve under (ksplus | witt-lr | tovar-ppm | ksegments | default-limits)",
        Some("ksplus"),
    )
    .flag("workflow", "training workflow", Some("eager"))
    .flag("backend", "native or pjrt", Some(DEFAULT_BACKEND))
    .flag(
        "chaos-kills",
        "crash/restore this many shards mid-run (needs >= 2 shards); the run fails if any \
         observation is lost",
        Some("0"),
    )
    .flag(
        "server",
        "serving stack to drive: none (in-process), threaded, or eventloop",
        Some("none"),
    )
    .flag("wire", "wire the TCP clients negotiate: v1 or v2", Some("v1"))
    .flag("pipeline", "requests each TCP client keeps in flight", Some("1"))
    .flag(
        "chaos-faults",
        "arm seeded server-side fault injection (e.g. seed=7,short-io=0.2,corrupt=0.05,\
         stall=0.1:2); clients become self-healing and the run still fails on any lost ack",
        None,
    )
    .flag(
        "max-queue-depth",
        "bound the event-loop dispatch queue so excess load is shed with 'overloaded' \
         (0 = unbounded; needs --server eventloop)",
        Some("0"),
    )
    .flag(
        "dispatch-threads",
        "event-loop dispatch worker threads (0 = default); set 1 to make a queue \
         squeeze actually bind",
        Some("0"),
    )
    .flag(
        "scenario",
        "drive the stream from a scenario spec (name=...,param=..., see docs/SCENARIOS.md) \
         instead of the plain workflow mix; plans are replayed against the perturbed \
         executions and OOMs become live failure/retry traffic (in-process server only)",
        None,
    )
    .flag("out", "write per-run JSON reports to this directory", None)
    .flag("bench-json", "write the sweep as machine-readable BENCH_hotpath.json here", None);
    let a = cmd.parse(argv)?;
    let spec = backend_spec_from_flag(a.get("backend").unwrap())?;
    let policy = policy_from_flag(a.get("policy").unwrap())?;
    let shard_counts = a.get_usize_list("shards")?;
    let clients = a.get_usize("clients")?;
    let requests = a.get_usize("requests")?;
    let observe_frac = a.get_f64("observe-frac")?;
    let chaos_kills = a.get_usize("chaos-kills")?;
    let server = experiments::loadgen::ServeMode::parse(a.get("server").unwrap())
        .with_context(|| format!("unknown server mode '{}'", a.get("server").unwrap()))?;
    let wire = ksplus::coordinator::wire::Wire::parse(a.get("wire").unwrap())
        .with_context(|| format!("unknown wire '{}'", a.get("wire").unwrap()))?;
    let pipeline = a.get_usize("pipeline")?;
    let chaos_faults = match a.get("chaos-faults") {
        Some(s) => Some(
            ksplus::coordinator::faults::FaultSpec::parse(s)
                .with_context(|| format!("parsing --chaos-faults '{s}'"))?,
        ),
        None => None,
    };
    let max_queue_depth = a.get_usize("max-queue-depth")?;
    let dispatch_threads = a.get_usize("dispatch-threads")?;

    println!(
        "== loadgen: {} clients, {} requests per run, observe-frac {}, policy {}, backend {}, \
         server {}, wire {}, pipeline {}{}{}{}{} ==",
        clients,
        requests,
        observe_frac,
        policy.name(),
        a.get("backend").unwrap(),
        server.name(),
        wire.name(),
        pipeline,
        if chaos_kills > 0 {
            format!(", chaos-kills {chaos_kills}")
        } else {
            String::new()
        },
        match a.get("chaos-faults") {
            Some(s) => format!(", chaos-faults {s}"),
            None => String::new(),
        },
        if max_queue_depth > 0 {
            format!(", max-queue-depth {max_queue_depth}")
        } else {
            String::new()
        },
        match a.get("scenario") {
            Some(s) => format!(", scenario {s}"),
            None => String::new(),
        }
    );
    println!(
        "{:>6}  {:>10}  {:>9}  {:>9}  {:>10}  {:>10}  shard spread",
        "shards", "plans/s", "p50 (us)", "p99 (us)", "mean batch", "observes/s"
    );
    let mut baseline: Option<f64> = None;
    let mut reports = Vec::with_capacity(shard_counts.len());
    for &shards in &shard_counts {
        let report = experiments::loadgen::run(&experiments::loadgen::LoadGenConfig {
            shards,
            clients,
            requests,
            observe_frac,
            k: a.get_usize("k")?,
            workflow: a.get("workflow").unwrap().to_string(),
            spec: spec.clone(),
            policy,
            chaos_kills,
            server,
            wire,
            pipeline,
            chaos_faults: chaos_faults.clone(),
            max_queue_depth,
            dispatch_threads,
            scenario: a.get("scenario").map(String::from),
        })?;
        let speedup = match baseline {
            None => {
                baseline = Some(report.plans_per_s);
                String::new()
            }
            Some(base) if base > 0.0 => format!("  ({:.2}x)", report.plans_per_s / base),
            Some(_) => String::new(),
        };
        println!(
            "{:>6}  {:>10.0}  {:>9.0}  {:>9.0}  {:>10.1}  {:>10.0}  {:?}{}",
            report.shards,
            report.plans_per_s,
            report.p50_us,
            report.p99_us,
            report.mean_batch_size,
            report.observes_per_s,
            report.per_shard_requests,
            speedup
        );
        if report.failures > 0 {
            println!(
                "        scenario: {} OOM failures replayed through the live failure/retry op",
                report.failures
            );
        }
        if report.shed > 0 || report.retries > 0 || report.reconnects > 0 {
            println!(
                "        robustness: shed {}, queue-depth max {}, retries {}, \
                 reconnects {}, circuit-opens {} — zero acked observations lost",
                report.shed,
                report.queue_depth_max,
                report.retries,
                report.reconnects,
                report.circuit_opens
            );
        }
        if let Some(dir) = a.get("out") {
            let dir = PathBuf::from(dir);
            std::fs::create_dir_all(&dir)?;
            let path = dir.join(format!("loadgen_shards{shards}.json"));
            std::fs::write(&path, report.to_json().to_string())?;
        }
        reports.push(report);
    }
    if let Some(path) = a.get("bench-json") {
        experiments::loadgen::write_bench_json(Path::new(path), &reports)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// The scenario matrix: replay perturbed execution streams (heavy tails,
/// concept drift, correlated groups, retry storms, stragglers) through
/// the offline OOM/retry simulator under every serving policy, print the
/// per-(scenario x policy) wastage/failure table, and merge it into
/// `BENCH_scenarios.json`. `--thresholds` turns the table into a CI
/// gate; `--dag` additionally replays bounded slices through the
/// DAG-aware cluster scheduler so stragglers show up as makespan.
fn cmd_scenarios(argv: &[String]) -> Result<()> {
    use ksplus::scenario::{engine, presets, ScenarioSpec};
    use ksplus::util::json::Json;

    let cmd = Command::new(
        "repro scenarios",
        "Scenario engine: perturbed-stream wastage matrix per serving policy",
    )
    .bool_flag("matrix", "replay the six built-in scenarios under every policy")
    .flag(
        "scenario",
        "replay a single spec (name=...,param=..., see docs/SCENARIOS.md) instead of \
         the presets; the spec's own sizing wins unless --n is nonzero",
        None,
    )
    .bool_flag("quick", "CI smoke sizing for the presets (400 executions per cell)")
    .flag(
        "n",
        "executions per (scenario, policy) cell (0 = 40000, or 400 under --quick)",
        Some("0"),
    )
    .flag(
        "policies",
        "comma-separated policies to replay (default: ksplus,witt-lr,tovar-ppm,\
         ksegments,default-limits)",
        None,
    )
    .flag("seed", "base stream seed for the presets", Some("42"))
    .flag("workflow", "synthetic source workflow for the presets (eager or sarek)", Some("eager"))
    .flag(
        "trace",
        "ingested CSV (either supported header shape) as the presets' base distribution \
         instead of the synthetic workflow",
        None,
    )
    .flag(
        "bench-json",
        "merge the matrix (and --figs output) into this machine-readable file",
        Some("BENCH_scenarios.json"),
    )
    .flag(
        "thresholds",
        "gate the matrix against this thresholds file (schema \
         ksplus-scenario-thresholds/v1); exits non-zero on any violation",
        None,
    )
    .bool_flag(
        "figs",
        "also regenerate fig6/fig7/fig8 (3 seeds, honouring --trace) and merge their \
         JSON under \"figures\"",
    )
    .bool_flag(
        "dag",
        "additionally replay a bounded slice of each synthetic scenario through the \
         DAG-aware cluster scheduler and print stage makespans",
    )
    .flag("nodes", "DAG replay: cluster nodes", Some("4"))
    .flag("dag-limit", "DAG replay: executions per (scenario, policy)", Some("400"));
    let a = cmd.parse(argv)?;

    let n_flag = a.get_usize("n")?;
    let trace = a.get("trace").map(PathBuf::from);
    let mut specs: Vec<ksplus::scenario::ScenarioSpec> = if let Some(s) = a.get("scenario") {
        // A hand-written spec carries its own sizing; only an explicit
        // --n overrides it.
        let mut spec = ScenarioSpec::parse(s)?;
        if n_flag > 0 {
            spec.n = n_flag;
        }
        vec![spec]
    } else if a.get_bool("matrix") {
        let n = match n_flag {
            0 if a.get_bool("quick") => engine::QUICK_N,
            0 => engine::FULL_N,
            n => n,
        };
        let seed = a.get_u64("seed")?;
        let workflow = a.get("workflow").unwrap().to_string();
        let specs: Vec<ScenarioSpec> = presets()
            .into_iter()
            .map(|s| ScenarioSpec {
                n,
                seed,
                workflow: workflow.clone(),
                trace: trace.clone(),
                ..s
            })
            .collect();
        for s in &specs {
            s.validate()?;
        }
        specs
    } else {
        bail!("nothing to run: pass --matrix or --scenario <spec>\n\n{}", cmd.usage());
    };

    let policies: Vec<&str> = match a.get("policies") {
        Some(list) => {
            let ps: Vec<&str> =
                list.split(',').map(str::trim).filter(|p| !p.is_empty()).collect();
            for p in &ps {
                if engine::method_for_policy(p).is_none() {
                    bail!(
                        "unknown policy '{p}' (valid: {})",
                        engine::default_policies().join(", ")
                    );
                }
            }
            ps
        }
        None => engine::default_policies(),
    };

    let matrix = engine::run_matrix(&specs, &policies)?;
    print!("{}", matrix.render("Scenario wastage matrix"));

    // Optional figure reproductions ride along in the same document so
    // one artifact holds the whole evaluation.
    let mut figures: Vec<(String, Json)> = Vec::new();
    if a.get_bool("figs") {
        let cfg = ExpConfig {
            seeds: vec![1, 2, 3],
            trace_csv: trace.clone(),
            ..Default::default()
        };
        for (key, out) in [
            ("fig6", experiments::fig6::run(&cfg)?),
            ("fig7", experiments::fig7::run(&cfg)?),
            ("fig8", experiments::fig8::run(&cfg)?),
        ] {
            print!("{}", out.text);
            figures.push((key.to_string(), out.json));
        }
    }

    if a.get_bool("dag") {
        let nodes = a.get_usize("nodes")?;
        let limit = a.get_usize("dag-limit")?;
        for spec in &specs {
            if spec.trace.is_some() {
                println!("dag: skipping '{}' (a trace CSV carries no DAG)", spec.name);
                continue;
            }
            for policy in &policies {
                let cluster =
                    ClusterConfig { nodes, node_capacity_gb: spec.capacity_gb };
                let r = engine::run_scenario_dag(spec, policy, &cluster, limit)?;
                println!(
                    "dag {:>11} / {:<14}: makespan {:>8.0} s, failures {:>4}, wastage {:>10.0} GBs",
                    spec.name,
                    policy,
                    r.makespan_s,
                    r.report.total_failures(),
                    r.report.total_wastage_gbs()
                );
            }
        }
    }

    let bench = PathBuf::from(a.get("bench-json").unwrap());
    engine::write_bench_json(&bench, &matrix, figures)?;
    println!("wrote {}", bench.display());

    // The gate runs last so the artifact above reflects the failing run.
    if let Some(path) = a.get("thresholds") {
        let t = engine::Thresholds::load(Path::new(path))
            .with_context(|| format!("loading thresholds {path}"))?;
        let violations = t.check(&matrix);
        if !violations.is_empty() {
            for v in &violations {
                eprintln!("THRESHOLD VIOLATION: {v}");
            }
            bail!("{} scenario threshold violation(s) against {path}", violations.len());
        }
        println!("thresholds OK ({path})");
    }
    Ok(())
}

/// Wire conformance smoke: starts a real TCP server (either front end),
/// negotiates the requested wire, drives one request of every op (plus
/// malformed and semantically invalid requests) through the typed
/// `RemoteClient`, and asserts on the structured responses — two
/// different per-task policies on the one server, provenance checked.
/// Exits non-zero on any mismatch; run by CI on every push, on both
/// wires.
fn cmd_protocol_smoke(argv: &[String]) -> Result<()> {
    use ksplus::coordinator::protocol::{ErrorCode, Request};
    use ksplus::coordinator::remote::RemoteClient;
    use ksplus::coordinator::server::ServerConfig;
    use ksplus::coordinator::wire::Wire;
    use ksplus::segments::StepPlan;
    use ksplus::trace::Execution;
    use ksplus::util::json::Json;

    let cmd = Command::new(
        "repro protocol-smoke",
        "Wire conformance: every op + malformed requests over a live TCP server",
    )
    .flag("shards", "coordinator worker shards", Some("2"))
    .flag(
        "policy",
        "service default policy (ksplus | witt-lr | tovar-ppm | ksegments | default-limits)",
        Some("ksplus"),
    )
    .flag("server", "front end to test: threaded or eventloop", Some("threaded"))
    .flag("wire", "wire to negotiate: v1 or v2", Some("v1"));
    let a = cmd.parse(argv)?;
    let shards = a.get_usize("shards")?;
    let policy = policy_from_flag(a.get("policy").unwrap())?;
    let wire = Wire::parse(a.get("wire").unwrap())
        .with_context(|| format!("unknown wire '{}'", a.get("wire").unwrap()))?;
    let threaded = match a.get("server").unwrap() {
        "threaded" => true,
        "eventloop" | "event-loop" => false,
        other => bail!("unknown server mode '{other}' (threaded | eventloop)"),
    };
    let coord = Coordinator::start(
        CoordinatorConfig { k: 3, shards, default_policy: policy, ..Default::default() },
        BackendSpec::Native,
    )?;
    let server =
        start_front_end("127.0.0.1:0", coord.client(), ServerConfig::default(), threaded)?;
    let mut rc = RemoteClient::connect(server.addr())?;

    // hello: version + capability negotiation onto the requested wire.
    let info = rc.negotiate(wire.version())?;
    anyhow::ensure!(
        info.version == wire.version(),
        "asked for wire {} but negotiated v{}",
        wire.name(),
        info.version
    );
    anyhow::ensure!(info.shards == shards, "hello reports {} shards", info.shards);
    for op in [
        "hello", "configure", "train", "observe", "plan", "failure", "stats", "snapshot",
        "reshard",
    ] {
        anyhow::ensure!(info.ops.iter().any(|o| o == op), "hello does not advertise {op}");
    }
    anyhow::ensure!(
        info.policies.len() == PredictorPolicy::names().len(),
        "hello advertises {} policies",
        info.policies.len()
    );

    // Two different policies on the one server.
    rc.configure(Some("smoke-ks"), PredictorPolicy::KsPlus)?;
    rc.configure(Some("smoke-witt"), PredictorPolicy::WittLr)?;

    // A small two-phase synthetic history.
    let hist: Vec<Execution> = (0..12)
        .map(|i| {
            let input = 1000.0 + 500.0 * i as f64;
            let n = 6 + (i % 3) as usize;
            let samples: Vec<f64> = (0..n)
                .map(|j| 0.001 * input * if j < n / 2 { 0.5 } else { 1.0 })
                .collect();
            Execution::new("smoke", input, 1.0, samples)
        })
        .collect();
    anyhow::ensure!(rc.train("smoke-ks", &hist)? == 12, "train ack count");
    rc.train("smoke-witt", &hist)?;

    // observe: provenance follows the binding, count increments.
    let ack = rc.observe("smoke-ks", &hist[0])?;
    anyhow::ensure!(
        ack.executions == 13 && ack.predictor == "ksplus",
        "observe ack {ack:?}"
    );

    // plan: provenance separates the two policies and the fallback.
    let pk = rc.plan("smoke-ks", 5000.0)?;
    anyhow::ensure!(pk.predictor == "ksplus", "ks plan predictor {}", pk.predictor);
    anyhow::ensure!(pk.model_version == 13, "ks plan version {}", pk.model_version);
    anyhow::ensure!(pk.fallback_reason.is_none(), "trained plan marked fallback");
    let pw = rc.plan("smoke-witt", 5000.0)?;
    anyhow::ensure!(pw.predictor == "witt-lr", "witt plan predictor {}", pw.predictor);
    anyhow::ensure!(pw.plan.k() == 1, "witt plans are flat");
    let pf = rc.plan("smoke-unknown", 10.0)?;
    anyhow::ensure!(
        pf.predictor == "default-limits" && pf.fallback_reason == Some("untrained-task"),
        "fallback provenance {pf:?}"
    );

    // failure: retry strategy routed by the task's policy.
    let retry = rc.report_failure(Some("smoke-witt"), &pw.plan, 1.0)?;
    anyhow::ensure!(retry.predictor == "witt-lr", "witt retry predictor");
    anyhow::ensure!(
        retry.plan.peaks[0] >= pw.plan.peaks[0],
        "witt retry must not lower the allocation"
    );
    let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
    let retry = rc.report_failure(None, &prev, 60.0)?;
    anyhow::ensure!(retry.predictor == "ksplus", "task-less retry is KS+");
    anyhow::ensure!(retry.plan.starts == vec![0.0, 60.0], "KS+ rescaling {:?}", retry.plan);

    // stats: every counter visible, fallbacks counted.
    let s = rc.stats()?;
    anyhow::ensure!(s.shards == shards, "stats shards {}", s.shards);
    anyhow::ensure!(s.requests == 3, "stats requests {}", s.requests);
    anyhow::ensure!(s.tasks_trained == 2, "stats tasks_trained {}", s.tasks_trained);
    anyhow::ensure!(s.observations == 1, "stats observations {}", s.observations);
    anyhow::ensure!(s.fallbacks == 1, "stats fallbacks {}", s.fallbacks);
    anyhow::ensure!(s.failures_handled == 2, "stats failures {}", s.failures_handled);

    // Malformed lines: each class maps to its specific structured code.
    // Raw line bytes are a v1-only probe — on a v2 connection they would
    // corrupt the binary framing, so there the byte-level classes
    // (invalid-json has no v2 analogue) are skipped and the semantic
    // classes below carry the conformance check.
    let mut error_classes = 3;
    if wire == Wire::V1 {
        error_classes += 10;
        for (line, want) in [
            ("### not json", "invalid-json"),
            (r#"{"op":"frobnicate"}"#, "unknown-op"),
            (r#"{"op":"plan","task":"x"}"#, "missing-field"),
            (r#"{"op":"plan","task":"x","input_mb":"big"}"#, "invalid-field"),
            (r#"{"op":"train","task":"x","history":[]}"#, "empty-history"),
            (
                r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1,"samples":[]}}"#,
                "empty-samples",
            ),
            (r#"{"op":"configure","task":"x","policy":"nope"}"#, "unknown-policy"),
            (r#"{"op":"hello","min_version":99}"#, "unsupported-version"),
            (r#"{"op":"reshard"}"#, "missing-field"),
            (r#"{"op":"reshard","shards":0}"#, "invalid-field"),
        ] {
            let j = rc.raw(line)?;
            anyhow::ensure!(
                j.get("ok") == Some(&Json::Bool(false)),
                "malformed line accepted: {line}"
            );
            let code = j.get("error").and_then(|e| e.get("code")).and_then(Json::as_str);
            anyhow::ensure!(code == Some(want), "expected {want} for {line}, got {j}");
        }
    }
    // Semantically invalid but well-framed requests: expressible as
    // typed values, so both wires must reject them with the same codes.
    for (req, want) in [
        (
            Request::Train { task: "x".into(), history: vec![], dedup: None },
            ErrorCode::EmptyHistory,
        ),
        (Request::Reshard { shards: 0 }, ErrorCode::InvalidField),
        (
            Request::Hello { client: None, min_version: Some(99), max_version: None },
            ErrorCode::UnsupportedVersion,
        ),
    ] {
        match rc.call_raw(&req)? {
            Err(e) => anyhow::ensure!(
                e.code == want,
                "expected {} for {req:?}, got {} ({})",
                want.as_str(),
                e.code.as_str(),
                e.message
            ),
            Ok(resp) => bail!("invalid request accepted: {req:?} -> {resp:?}"),
        }
    }
    // The connection survived every error.
    let s = rc.stats()?;
    anyhow::ensure!(s.requests == 3, "error handling leaked plan requests");
    anyhow::ensure!(
        s.conns_refused == 0 && s.conn_timeouts == 0,
        "unexpected connection counters: refused {} timeouts {}",
        s.conns_refused,
        s.conn_timeouts
    );

    // snapshot: a restorable document covering every trained task.
    let doc = rc.snapshot()?;
    anyhow::ensure!(
        doc.get("schema").and_then(Json::as_str).is_some(),
        "snapshot carries no schema: {doc}"
    );
    let snap_tasks = doc.get("tasks").and_then(Json::as_arr).map(Vec::len).unwrap_or(0);
    anyhow::ensure!(snap_tasks >= 2, "snapshot covers {snap_tasks} tasks, expected >= 2");

    // reshard: grow then shrink the pool; the plans a client sees must
    // be bit-identical across both moves (trained state migrates).
    let before = rc.plan("smoke-ks", 7000.0)?;
    let ids = rc.reshard(shards + 1)?;
    anyhow::ensure!(ids.len() == shards + 1, "reshard grew to {} shards", ids.len());
    let grown = rc.plan("smoke-ks", 7000.0)?;
    anyhow::ensure!(grown == before, "growing the pool changed a plan");
    let ids = rc.reshard(shards)?;
    anyhow::ensure!(ids.len() == shards, "reshard shrank to {} shards", ids.len());
    let shrunk = rc.plan("smoke-ks", 7000.0)?;
    anyhow::ensure!(shrunk == before, "shrinking the pool changed a plan");

    println!(
        "protocol-smoke: wire v{} over the {} front end OK — {} ops, {} policies, {} shard(s), \
         default policy {}, provenance + fallback counting + snapshot/reshard plan parity + \
         {} error classes verified",
        info.version,
        server.kind(),
        info.ops.len(),
        info.policies.len(),
        shards,
        policy.name(),
        error_classes
    );
    Ok(())
}

fn cmd_record(argv: &[String]) -> Result<()> {
    use ksplus::coordinator::session;

    let cmd = Command::new(
        "repro record",
        "Capture golden session traces from a live, dispatch-tapped server",
    )
    .flag("case", "case name to record, or 'all'", Some("all"))
    .flag("out-dir", "directory receiving <case>/trace.json", Some("golden"));
    let a = cmd.parse(argv)?;
    let out_dir = PathBuf::from(a.get("out-dir").unwrap());
    let cases: Vec<String> = match a.get("case").unwrap() {
        "all" => session::case_names().iter().map(|s| s.to_string()).collect(),
        one => {
            // Fail on typos before spending time recording.
            session::case_config(one)?;
            vec![one.to_string()]
        }
    };
    for case in &cases {
        let trace = session::record_case(case)
            .with_context(|| format!("recording case '{case}'"))?;
        let path = out_dir.join(case).join(session::TRACE_FILE);
        trace.write_file(&path)?;
        println!(
            "recorded {case}: {} steps -> {}",
            trace.steps.len(),
            path.display()
        );
    }
    Ok(())
}

fn cmd_replay(argv: &[String]) -> Result<()> {
    use ksplus::coordinator::session::{self, SessionTrace};
    use ksplus::coordinator::wire::Wire;

    let cmd = Command::new(
        "repro replay",
        "Re-drive recorded session traces against fresh coordinators and assert\n\
         bit-identical responses across front ends and wires",
    )
    .flag("trace", "replay a single trace file", None)
    .bool_flag("all-goldens", "replay every committed golden case")
    .flag("goldens-dir", "directory of committed goldens", Some("golden"))
    .flag("server", "front end(s): threaded|eventloop|all", Some("all"))
    .flag("wire", "wire(s): v1|v2|all", Some("all"))
    .flag("shards", "override the recorded shard count", None)
    .flag(
        "fault-seed",
        "arm benign seeded faults (short reads/writes + dispatch stalls) during \
         replay; the transcripts must still be bit-identical",
        None,
    );
    let a = cmd.parse(argv)?;

    let shards = match a.get("shards") {
        None => None,
        Some(s) => Some(
            s.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--shards wants an integer, got '{s}'"))?,
        ),
    };
    let fault_seed = match a.get("fault-seed") {
        None => None,
        Some(s) => Some(
            s.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--fault-seed wants an integer, got '{s}'"))?,
        ),
    };
    let server_sel = a.get("server").unwrap().to_string();
    let wire_sel = a.get("wire").unwrap().to_string();
    let combos: Vec<(&'static str, bool, Wire)> = session::all_combos()
        .into_iter()
        .filter(|(_, threaded, _)| match server_sel.as_str() {
            "threaded" => *threaded,
            "eventloop" => !*threaded,
            _ => true,
        })
        .filter(|(_, _, wire)| match wire_sel.as_str() {
            "v1" => *wire == Wire::V1,
            "v2" => *wire == Wire::V2,
            _ => true,
        })
        .collect();
    anyhow::ensure!(
        !combos.is_empty(),
        "no front-end/wire combination matches --server {server_sel} --wire {wire_sel} \
         on this platform (the event loop is unix-only)"
    );

    let traces: Vec<SessionTrace> = if a.get_bool("all-goldens") {
        let dir = PathBuf::from(a.get("goldens-dir").unwrap());
        session::case_names()
            .iter()
            .map(|case| SessionTrace::read_file(&dir.join(case).join(session::TRACE_FILE)))
            .collect::<Result<_>>()?
    } else if let Some(path) = a.get("trace") {
        vec![SessionTrace::read_file(Path::new(path))?]
    } else {
        bail!("nothing to replay: pass --trace <file> or --all-goldens\n\n{}", cmd.usage());
    };

    let mut total = 0usize;
    for trace in &traces {
        // The first combo's transcript is the cross-combo baseline the
        // rest must reproduce bit-for-bit.
        let mut baseline: Option<(&'static str, Vec<String>)> = None;
        for &(combo, threaded, wire) in &combos {
            let transcript =
                session::replay_trace_faulted(trace, threaded, wire, shards, fault_seed)
                    .with_context(|| format!("case '{}' on {combo}", trace.case_name))?;
            if let Some((base_combo, base)) = &baseline {
                diff_transcripts(&trace.case_name, base_combo, base, combo, &transcript)?;
            } else {
                baseline = Some((combo, transcript));
            }
            println!(
                "PASS {} on {combo} ({} steps)",
                trace.case_name,
                trace.steps.len()
            );
            total += 1;
        }
    }
    println!(
        "replay: {} case(s) x {} combo(s) = {total} run(s), all bit-identical{}",
        traces.len(),
        combos.len(),
        match fault_seed {
            Some(seed) => format!(" (benign faults armed, seed {seed})"),
            None => String::new(),
        }
    );
    Ok(())
}

/// Fail with the first divergent transcript line between two combos.
fn diff_transcripts(
    case: &str,
    base_combo: &str,
    base: &[String],
    combo: &str,
    got: &[String],
) -> Result<()> {
    let n = base.len().min(got.len());
    for i in 0..n {
        if base[i] != got[i] {
            bail!(
                "case '{case}' diverged at transcript line {i}:\n  {base_combo}: {}\n  {combo}: {}",
                base[i],
                got[i]
            );
        }
    }
    anyhow::ensure!(
        base.len() == got.len(),
        "case '{case}': {base_combo} produced {} transcript lines, {combo} produced {}",
        base.len(),
        got.len()
    );
    Ok(())
}
