//! Declarative flag parser for the `repro` binary (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub boolean: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    BadValue(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(n) => write!(f, "unknown flag --{n}"),
            CliError::MissingValue(n) => write!(f, "flag --{n} requires a value"),
            CliError::BadValue(n, v) => write!(f, "invalid value for --{n}: {v}"),
        }
    }
}

impl std::error::Error for CliError {}

pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, flags: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.flags.push(FlagSpec { name, help, default, boolean: false });
        self
    }

    pub fn bool_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, boolean: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\n  {}\n\nFlags:\n", self.about, self.name);
        for f in &self.flags {
            let d = f.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
            let v = if f.boolean { "" } else { " <value>" };
            s.push_str(&format!("  --{}{v}\n      {}{d}\n", f.name, f.help));
        }
        s
    }

    /// Parse raw argv (without the subcommand itself).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for f in &self.flags {
            if let Some(d) = f.default {
                args.flags.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                let value = if spec.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i).cloned().ok_or_else(|| CliError::MissingValue(name.clone()))?
                };
                args.flags.insert(name, value);
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.flags.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::BadValue(name.into(), v.clone()))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.flags.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::BadValue(name.into(), v.clone()))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.flags.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::BadValue(name.into(), v.clone()))
    }

    /// Comma-separated list of integers (e.g. `--shards 1,2,4`). Empty
    /// items are rejected, so trailing commas are flagged, not ignored.
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        let v = self.flags.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.split(',')
            .map(|item| {
                item.trim()
                    .parse()
                    .map_err(|_| CliError::BadValue(name.into(), v.clone()))
            })
            .collect()
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .flag("seed", "rng seed", Some("42"))
            .flag("out", "output path", None)
            .bool_flag("verbose", "chatty")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&[]).unwrap();
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get("out"), None);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&sv(&["--seed", "7", "--out=x.json"])).unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), 7);
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn boolean_flag() {
        let a = cmd().parse(&sv(&["--verbose"])).unwrap();
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&sv(&["fig6", "--seed", "1", "extra"])).unwrap();
        assert_eq!(a.positional, vec!["fig6".to_string(), "extra".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(cmd().parse(&sv(&["--nope"])), Err(CliError::UnknownFlag(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(cmd().parse(&sv(&["--out"])), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn bad_numeric_value() {
        let a = cmd().parse(&sv(&["--seed", "abc"])).unwrap();
        assert!(matches!(a.get_u64("seed"), Err(CliError::BadValue(_, _))));
    }

    #[test]
    fn usize_list_parses_and_rejects() {
        let c = Command::new("test", "t").flag("shards", "shard sweep", Some("1"));
        let a = c.parse(&sv(&["--shards", "1,2,4"])).unwrap();
        assert_eq!(a.get_usize_list("shards").unwrap(), vec![1, 2, 4]);
        let a = c.parse(&[]).unwrap();
        assert_eq!(a.get_usize_list("shards").unwrap(), vec![1]);
        let a = c.parse(&sv(&["--shards", "1,,4"])).unwrap();
        assert!(matches!(a.get_usize_list("shards"), Err(CliError::BadValue(_, _))));
        let a = c.parse(&sv(&["--shards", "2,x"])).unwrap();
        assert!(matches!(a.get_usize_list("shards"), Err(CliError::BadValue(_, _))));
    }

    #[test]
    fn usage_mentions_flags() {
        let u = cmd().usage();
        assert!(u.contains("--seed"));
        assert!(u.contains("default: 42"));
    }
}
