//! Fig 7: effect of the segment count k on KS+'s aggregated wastage,
//! for both workflows (paper: robust across k, shallow optimum near 6).

use anyhow::Result;

use crate::experiments::{eval_traces, evaluate_method, report, ExpConfig, ExpOutput};
use crate::util::json::Json;
use crate::util::stats;

pub const K_RANGE: std::ops::RangeInclusive<usize> = 2..=10;

pub fn collect(cfg: &ExpConfig) -> Result<Vec<(&'static str, usize, Vec<f64>)>> {
    let mut out = Vec::new();
    for (wf, trace, label) in eval_traces(cfg)? {
        for k in K_RANGE {
            let mut wastage = Vec::with_capacity(cfg.seeds.len());
            for &seed in &cfg.seeds {
                let r =
                    evaluate_method("ksplus", k, cfg.capacity_gb, &wf, &trace, 0.5, seed)?;
                wastage.push(r.total_wastage_gbs());
            }
            out.push((label, k, wastage));
        }
    }
    Ok(out)
}

pub fn run(cfg: &ExpConfig) -> Result<ExpOutput> {
    let series = collect(cfg)?;
    let mut text = String::new();
    let mut json_rows = Vec::new();
    let mut labels: Vec<&'static str> = Vec::new();
    for (label, _, _) in &series {
        if !labels.contains(label) {
            labels.push(label);
        }
    }
    for wf_name in labels {
        let mut table = report::Table::new(&["k", "wastage GBs"]);
        let rows: Vec<_> = series.iter().filter(|(w, _, _)| *w == wf_name).collect();
        for (_, k, wastage) in &rows {
            table.row(vec![k.to_string(), report::mean_pm_std(wastage)]);
            json_rows.push(Json::obj(vec![
                ("workflow", (*wf_name).into()),
                ("k", (*k).into()),
                ("wastage_gbs_mean", stats::mean(wastage).into()),
                ("wastage_gbs_std", stats::stddev(wastage).into()),
            ]));
        }
        text.push_str(&table.render(&format!("Fig 7 ({wf_name}): KS+ wastage vs k")));
        // Robustness summary: max/min ratio across k.
        let means: Vec<f64> = rows.iter().map(|(_, _, w)| stats::mean(w)).collect();
        let max = means.iter().cloned().fold(f64::MIN, f64::max);
        let min = means.iter().cloned().fold(f64::MAX, f64::min);
        let best_k = rows[means.iter().position(|&m| m == min).unwrap()].1;
        text.push_str(&format!(
            "  spread max/min = {:.2}x, best k = {best_k}\n\n",
            max / min
        ));
    }
    Ok(ExpOutput { text, json: Json::obj(vec![("fig7", Json::Arr(json_rows))]) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweeps_k_range() {
        let cfg = ExpConfig { seeds: vec![1], ..Default::default() };
        let series = collect(&cfg).unwrap();
        assert_eq!(series.len(), 2 * K_RANGE.count());
        // Wastage stays positive and finite for every k.
        assert!(series.iter().all(|(_, _, w)| w[0].is_finite() && w[0] > 0.0));
    }

    #[test]
    fn report_contains_both_workflows() {
        let cfg = ExpConfig { seeds: vec![1], ..Default::default() };
        let out = run(&cfg).unwrap();
        assert!(out.text.contains("Fig 7 (eager)"));
        assert!(out.text.contains("Fig 7 (sarek)"));
    }

    #[test]
    fn trace_csv_drives_fig7() {
        let cfg = ExpConfig {
            seeds: vec![1],
            trace_csv: Some(
                concat!(
                    env!("CARGO_MANIFEST_DIR"),
                    "/../golden/traces/nfcore_rnaseq_sample.csv"
                )
                .into(),
            ),
            ..Default::default()
        };
        let series = collect(&cfg).unwrap();
        assert_eq!(series.len(), K_RANGE.count());
        assert!(series.iter().all(|(w, _, _)| *w == "trace"));
        let out = run(&cfg).unwrap();
        assert!(out.text.contains("Fig 7 (trace)"));
    }
}
