//! Bench for Fig 8: per-task wastage for the nine eager tasks, KS+ vs
//! the strongest baseline (k-Segments Selective), one seed, 50 % train.

use ksplus::experiments::{evaluate_method, ExpConfig};
use ksplus::trace::workflow::Workflow;
use ksplus::util::bench::bench;

fn main() {
    let cfg = ExpConfig::default();
    let wf = Workflow::eager();
    let trace = wf.generate(cfg.trace_seed, cfg.target_samples);

    let mut ks = None;
    let mut sel = None;
    bench("fig8/ksplus-eval", 0, 3, || {
        ks = Some(
            evaluate_method("ksplus", cfg.k, cfg.capacity_gb, &wf, &trace, 0.5, 1).unwrap(),
        );
    });
    bench("fig8/kseg-selective-eval", 0, 3, || {
        sel = Some(
            evaluate_method(
                "ksegments-selective",
                cfg.k,
                cfg.capacity_gb,
                &wf,
                &trace,
                0.5,
                1,
            )
            .unwrap(),
        );
    });
    let (ks, sel) = (ks.unwrap(), sel.unwrap());
    println!("== fig8: per-task wastage, ksplus vs ksegments-selective ==");
    for (task, agg) in &ks.per_task {
        let base = sel.task_wastage(task);
        println!(
            "  {task:>16}: {:>9.0} vs {:>9.0} GBs ({:+.0}%)",
            agg.wastage_gbs,
            base,
            (agg.wastage_gbs / base - 1.0) * 100.0
        );
    }
}
