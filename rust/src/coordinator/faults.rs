//! Deterministic fault injection: a seeded [`FaultPlane`] that the
//! server front ends, the dispatch workers, and the snapshot writer
//! consult at their natural failure points. Every decision comes from a
//! per-seam fork of one seeded [`Rng`], so a fault schedule replays
//! bit-identically from its seed: the Nth read on the wire seam is
//! shortened (or not) the same way on every run with the same spec.
//!
//! Four fault kinds, one per seam:
//!
//! * `short-io` — wire codec seam: clamp a read or write to fewer bytes
//!   than the socket offered, exercising every partial-frame
//!   reassembly path. Harmless by construction (no bytes are lost or
//!   reordered, only split), so it is part of the *benign* spec the
//!   golden replay harness runs under.
//! * `corrupt` — wire codec seam, outbound only: truncate an encoded
//!   response frame mid-write and sever the connection. The client sees
//!   a torn frame / EOF, reconnects, and retries; requests are never
//!   corrupted (a corrupted request would legitimately change what the
//!   server applied, which is exactly what the no-lost-acks property
//!   must distinguish from).
//! * `stall` — service seam: sleep a dispatch worker before it serves a
//!   request, widening every queue/timeout race.
//! * `torn` — snapshot seam: leave a truncated prefix of the document
//!   in the snapshot's final path and fail the write, simulating the
//!   worst post-crash state of a non-atomic writer. Restore must
//!   classify the debris as corrupt and start fresh, not wedge.
//!
//! The spec grammar (`--fault-spec` / `--chaos-faults`) is
//! `key=value` pairs joined by commas:
//!
//! ```text
//! seed=42,short-io=0.1,corrupt=0.05,stall=0.1:5,torn=0.5
//! ```
//!
//! Probabilities are per-decision in `[0,1]`; `stall` takes an optional
//! `:millis` suffix (default 2ms). Omitted kinds default to 0 (never).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::rng::Rng;
use crate::util::sync::lock_recover;

/// Parsed fault specification: the seed plus one probability per kind.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub seed: u64,
    /// P(clamp) per wire read/write.
    pub short_io: f64,
    /// P(truncate + sever) per outbound response frame.
    pub corrupt: f64,
    /// P(sleep) per dispatched request.
    pub stall: f64,
    /// Stall duration when one fires.
    pub stall_ms: u64,
    /// P(tear) per snapshot write.
    pub torn: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec { seed: 0, short_io: 0.0, corrupt: 0.0, stall: 0.0, stall_ms: 2, torn: 0.0 }
    }
}

impl FaultSpec {
    /// Parse the `key=value,...` grammar. Unknown keys and out-of-range
    /// probabilities are errors — a typo'd fault spec silently injecting
    /// nothing would defeat the whole exercise.
    pub fn parse(s: &str) -> anyhow::Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault spec entry '{part}' is not key=value"))?;
            let prob = |v: &str| -> anyhow::Result<f64> {
                let p: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("'{key}={v}': not a number"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "'{key}={v}': probability must be in [0,1]"
                );
                Ok(p)
            };
            match key {
                "seed" => {
                    spec.seed = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("'seed={value}': not a u64"))?;
                }
                "short-io" => spec.short_io = prob(value)?,
                "corrupt" => spec.corrupt = prob(value)?,
                "stall" => match value.split_once(':') {
                    None => spec.stall = prob(value)?,
                    Some((p, ms)) => {
                        spec.stall = prob(p)?;
                        spec.stall_ms = ms
                            .parse()
                            .map_err(|_| anyhow::anyhow!("'stall={value}': bad millis"))?;
                    }
                },
                "torn" => spec.torn = prob(value)?,
                other => anyhow::bail!(
                    "unknown fault kind '{other}' (valid: seed, short-io, corrupt, stall, torn)"
                ),
            }
        }
        Ok(spec)
    }

    /// The benign-only spec the golden replay harness runs under
    /// (`repro replay --fault-seed N`): faults that stress framing and
    /// scheduling without losing or altering a single response byte, so
    /// replayed transcripts must stay bit-identical.
    pub fn benign(seed: u64) -> FaultSpec {
        FaultSpec { seed, short_io: 0.3, stall: 0.2, stall_ms: 1, ..FaultSpec::default() }
    }

    /// Does this spec inject anything at all?
    pub fn is_active(&self) -> bool {
        self.short_io > 0.0 || self.corrupt > 0.0 || self.stall > 0.0 || self.torn > 0.0
    }

    /// Build the shared runtime plane for this spec.
    pub fn plane(&self) -> std::sync::Arc<FaultPlane> {
        std::sync::Arc::new(FaultPlane::new(self.clone()))
    }
}

/// Injection counters, for loadgen reports and assertions that a run
/// actually exercised what it claimed to.
#[derive(Debug, Default)]
pub struct FaultCounters {
    pub short_io: AtomicU64,
    pub corrupt: AtomicU64,
    pub stall: AtomicU64,
    pub torn: AtomicU64,
}

/// Shared runtime state: one seeded RNG fork per seam, behind its own
/// (poison-recovering) lock so seams never perturb each other's
/// streams. Decision N on a seam is a pure function of (seed, seam, N).
pub struct FaultPlane {
    spec: FaultSpec,
    io: Mutex<Rng>,
    frames: Mutex<Rng>,
    stalls: Mutex<Rng>,
    snapshots: Mutex<Rng>,
    pub counters: FaultCounters,
}

impl std::fmt::Debug for FaultPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlane").field("spec", &self.spec).finish()
    }
}

impl FaultPlane {
    pub fn new(spec: FaultSpec) -> FaultPlane {
        let mut root = Rng::new(spec.seed);
        FaultPlane {
            io: Mutex::new(root.fork(1)),
            frames: Mutex::new(root.fork(2)),
            stalls: Mutex::new(root.fork(3)),
            snapshots: Mutex::new(root.fork(4)),
            spec,
            counters: FaultCounters::default(),
        }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Wire seam: how many of `avail` bytes this read/write may move.
    /// Always at least 1 (a zero-length read would be mistaken for EOF).
    pub fn clamp_io(&self, avail: usize) -> usize {
        if avail <= 1 || self.spec.short_io <= 0.0 {
            return avail;
        }
        let mut rng = lock_recover(&self.io);
        if rng.f64() >= self.spec.short_io {
            return avail;
        }
        self.counters.short_io.fetch_add(1, Ordering::Relaxed);
        1 + rng.below(avail)
    }

    /// Wire seam, outbound: should this encoded response frame be torn?
    /// When `true`, the caller truncates `bytes` to the returned prefix
    /// length and severs the connection after writing it.
    pub fn tear_frame(&self, len: usize) -> Option<usize> {
        if len == 0 || self.spec.corrupt <= 0.0 {
            return None;
        }
        let mut rng = lock_recover(&self.frames);
        if rng.f64() >= self.spec.corrupt {
            return None;
        }
        self.counters.corrupt.fetch_add(1, Ordering::Relaxed);
        // Keep a strict prefix: 0..len-1 bytes survive.
        Some(rng.below(len))
    }

    /// Service seam: maybe sleep before dispatching one request.
    pub fn maybe_stall(&self) {
        if self.spec.stall <= 0.0 {
            return;
        }
        let fire = lock_recover(&self.stalls).f64() < self.spec.stall;
        if fire {
            self.counters.stall.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_millis(self.spec.stall_ms));
        }
    }

    /// Snapshot seam: should this snapshot write be torn? When `Some(n)`
    /// the writer leaves only `n` bytes of the document in the final
    /// path and reports the write as failed (an injected crash).
    pub fn tear_snapshot(&self, len: usize) -> Option<usize> {
        if self.spec.torn <= 0.0 {
            return None;
        }
        let mut rng = lock_recover(&self.snapshots);
        if rng.f64() >= self.spec.torn {
            return None;
        }
        self.counters.torn.fetch_add(1, Ordering::Relaxed);
        Some(rng.below(len.max(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let s = FaultSpec::parse("seed=42,short-io=0.1,corrupt=0.05,stall=0.1:5,torn=0.5")
            .unwrap();
        assert_eq!(
            s,
            FaultSpec {
                seed: 42,
                short_io: 0.1,
                corrupt: 0.05,
                stall: 0.1,
                stall_ms: 5,
                torn: 0.5,
            }
        );
        assert!(s.is_active());
        // Defaults: everything off, stall at 2ms.
        let d = FaultSpec::parse("seed=7").unwrap();
        assert_eq!(d, FaultSpec { seed: 7, ..FaultSpec::default() });
        assert!(!d.is_active());
        // Stall without millis keeps the default duration.
        let st = FaultSpec::parse("stall=0.25").unwrap();
        assert_eq!((st.stall, st.stall_ms), (0.25, 2));
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "frobnicate=1",
            "short-io=2.0",
            "short-io=-0.1",
            "seed=abc",
            "stall=0.1:xyz",
            "short-io",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn decisions_replay_bit_identically_from_the_seed() {
        let spec = FaultSpec::parse("seed=1234,short-io=0.5,corrupt=0.3,torn=0.4").unwrap();
        let a = spec.plane();
        let b = spec.plane();
        for i in 0..200 {
            assert_eq!(a.clamp_io(64 + i), b.clamp_io(64 + i), "io decision {i}");
            assert_eq!(a.tear_frame(128), b.tear_frame(128), "frame decision {i}");
            assert_eq!(a.tear_snapshot(256), b.tear_snapshot(256), "snap decision {i}");
        }
        assert_eq!(
            a.counters.short_io.load(Ordering::Relaxed),
            b.counters.short_io.load(Ordering::Relaxed)
        );
        // A different seed produces a different schedule.
        let other = FaultSpec { seed: 99, ..spec.clone() }.plane();
        let same = (0..200).filter(|_| a.clamp_io(1024) == other.clamp_io(1024)).count();
        assert!(same < 200);
    }

    #[test]
    fn clamps_are_in_range_and_probabilistic() {
        let plane = FaultSpec::parse("seed=5,short-io=0.5,corrupt=0.5").unwrap().plane();
        let mut clamped = 0;
        for _ in 0..500 {
            let n = plane.clamp_io(64);
            assert!((1..=64).contains(&n));
            if n < 64 {
                clamped += 1;
            }
        }
        // ~50% fire rate, generous bounds.
        assert!((100..=400).contains(&clamped), "clamped {clamped}/500");
        for _ in 0..500 {
            if let Some(keep) = plane.tear_frame(32) {
                assert!(keep < 32, "torn frame must be a strict prefix");
            }
        }
        assert!(plane.counters.corrupt.load(Ordering::Relaxed) > 0);
        // A 1-byte buffer is never clamped (it would look like EOF).
        for _ in 0..50 {
            assert_eq!(plane.clamp_io(1), 1);
        }
    }

    #[test]
    fn benign_spec_never_alters_bytes() {
        let s = FaultSpec::benign(7);
        assert!(s.is_active());
        assert_eq!(s.corrupt, 0.0);
        assert_eq!(s.torn, 0.0);
        let plane = s.plane();
        for _ in 0..100 {
            assert_eq!(plane.tear_frame(64), None);
            assert_eq!(plane.tear_snapshot(64), None);
        }
    }

    #[test]
    fn inactive_plane_is_free_of_rng_traffic() {
        let plane = FaultSpec::default().plane();
        for _ in 0..10 {
            assert_eq!(plane.clamp_io(64), 64);
            assert_eq!(plane.tear_frame(64), None);
            assert_eq!(plane.tear_snapshot(64), None);
            plane.maybe_stall();
        }
        let c = &plane.counters;
        assert_eq!(c.short_io.load(Ordering::Relaxed), 0);
        assert_eq!(c.corrupt.load(Ordering::Relaxed), 0);
        assert_eq!(c.stall.load(Ordering::Relaxed), 0);
        assert_eq!(c.torn.load(Ordering::Relaxed), 0);
    }
}
