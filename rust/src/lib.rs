//! # KS+ — Predicting Workflow Task Memory Usage Over Time
//!
//! Production-grade reproduction of *KS+: Predicting Workflow Task Memory
//! Usage Over Time* (e-Science 2024). KS+ models a workflow task's memory
//! consumption as a monotonically increasing step function with `k`
//! variable-sized segments, predicts segment start times and peaks from
//! the task's input size, and rescales segment starts on OOM instead of
//! blindly doubling memory.
//!
//! Architecture (three layers, Python never on the request path):
//! - **L3 (this crate)**: trace substrate, segmentation, predictors
//!   (KS+ and all paper baselines), OOM/retry simulator, discrete-event
//!   cluster scheduler, experiment harness, and an online prediction
//!   service (`coordinator`).
//! - **L2/L1 (python/, build-time)**: batched OLS fit/predict and wastage
//!   scoring as JAX + Pallas kernels, AOT-lowered to HLO text artifacts.
//! - **runtime** (behind the `pjrt` cargo feature): loads
//!   `artifacts/*.hlo.txt` via the PJRT CPU client (`xla` crate) and
//!   executes them from the coordinator's hot path. Default builds are
//!   native-only — the coordinator's `Backend::Native` closed-form path —
//!   and need no XLA libraries; requesting `BackendSpec::Pjrt` in a
//!   native-only build returns a runtime error, not a compile error.
//!   Artifact lookup at runtime: `KSPLUS_ARTIFACTS`, else an `artifacts/`
//!   directory found next to (or above) the executable, else `./artifacts`.
//!
//! Quickstart: see `examples/quickstart.rs`; experiments: `repro
//! experiment fig6 --workflow eager`.

pub mod coordinator;
pub mod experiments;
pub mod metrics;
pub mod predictor;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
pub mod segments;
pub mod sim;
pub mod trace;
pub mod util;
