"""L1 roofline estimator (DESIGN.md SectionHardware-Adaptation).

Pallas interpret mode gives CPU-numpy timings only, so real-TPU
performance is *estimated* from the kernels' memory traffic and the
BlockSpec layout. For every kernel this module reports:

  - VMEM working set per grid step (must fit the ~16 MiB budget),
  - bytes moved HBM<->VMEM per invocation,
  - arithmetic intensity (flop/byte),
  - bandwidth-bound runtime estimate on a v4-class core (~1.2 TB/s),
  - MXU utilisation (zero by design: no matmuls; the kernels are VPU
    reductions, the roofline is HBM streaming).

Usage: python -m compile.roofline
"""

from __future__ import annotations

from dataclasses import dataclass

from compile.kernels import ols

VMEM_BYTES = 16 * 2**20
HBM_BW = 1.2e12  # bytes/s, v4-class
F32 = 4


@dataclass
class KernelEstimate:
    name: str
    vmem_per_step: int
    hbm_bytes: int
    flops: int
    grid_steps: int

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)

    @property
    def est_runtime_s(self) -> float:
        # Bandwidth-bound: all our kernels sit far left of the ridge.
        return self.hbm_bytes / HBM_BW

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_per_step <= VMEM_BYTES

    def row(self) -> str:
        return (
            f"{self.name:<28} {self.vmem_per_step / 2**20:>7.2f} MiB "
            f"{self.hbm_bytes / 2**20:>8.2f} MiB {self.intensity:>7.3f} "
            f"{self.est_runtime_s * 1e6:>8.1f} us "
            f"{'ok' if self.fits_vmem else 'OVER'}"
        )


def estimates(
    b: int = ols.FIT_B,
    n: int = ols.FIT_N,
    pb: int = ols.PREDICT_B,
    k: int = ols.PLAN_K,
    block_b: int = ols.BLOCK_B,
) -> list[KernelEstimate]:
    steps = max(b // block_b, 1)
    out = []
    # fit: 3 inputs [B,N], output [B,2]; ~8 flops/element (mul+adds for
    # 4 running sums) + O(B) epilogue.
    io_fit = (3 * b * n + b * 2) * F32
    out.append(
        KernelEstimate(
            f"fit b{b} n{n}",
            3 * block_b * n * F32 + block_b * 2 * F32,
            io_fit,
            8 * b * n + 12 * b,
            steps,
        )
    )
    io_fit_small = (3 * b * ols.FIT_N_SMALL + b * 2) * F32
    out.append(
        KernelEstimate(
            f"fit b{b} n{ols.FIT_N_SMALL} (small)",
            3 * block_b * ols.FIT_N_SMALL * F32 + block_b * 2 * F32,
            io_fit_small,
            8 * b * ols.FIT_N_SMALL + 12 * b,
            steps,
        )
    )
    # predict: coef [B,2] + 2x [B] in, [B] out; ~4 flops/row.
    io_pred = (pb * 2 + 3 * pb) * F32
    out.append(
        KernelEstimate(
            f"predict b{pb}",
            (min(block_b, pb) * 5) * F32,
            io_pred,
            4 * pb,
            max(pb // block_b, 1),
        )
    )
    # wastage: 3x [B,N] + [B] in, [B] out; ~3 flops/element.
    io_w = (3 * b * n + 2 * b) * F32
    out.append(
        KernelEstimate(
            f"wastage b{b} n{n}",
            3 * block_b * n * F32,
            io_w,
            3 * b * n,
            steps,
        )
    )
    # plan_wastage: 2x [B,K] + 2x [B,N] + [B] in, [B] out; the [B,N,K]
    # compare/max intermediate stays in VMEM.
    io_pw = (2 * b * k + 2 * b * n + 2 * b) * F32
    out.append(
        KernelEstimate(
            f"plan_wastage b{b} n{n} k{k}",
            (2 * block_b * k + 2 * block_b * n + block_b * n * k) * F32,
            io_pw,
            (3 * k + 3) * b * n,
            steps,
        )
    )
    return out


def main() -> None:
    print(
        f"{'kernel':<28} {'VMEM/step':>11} {'HBM moved':>12} {'fl/B':>7} "
        f"{'t@1.2TB/s':>11} fits"
    )
    for e in estimates():
        print(e.row())
    print(
        "\nAll kernels are HBM-bandwidth bound (intensity << ridge ~100 "
        "flop/B on v4); MXU idle by design. VMEM budget: 16 MiB."
    )


if __name__ == "__main__":
    main()
