//! Crash-safety integration tests: snapshot/restore round-trips through
//! the exact text form a snapshot file holds, a full server restart from
//! a snapshot file on disk, and a chaos run that kills live shards under
//! concurrent training without losing a single observation.
//!
//! The bar everywhere is *bit-identical plans* — the coordinator's plans
//! are pure functions of f64 accumulator state, and both the snapshot
//! text codec and the replica handoff preserve that state exactly, so
//! equality is asserted with `==`, never with tolerances.

use ksplus::coordinator::remote::RemoteClient;
use ksplus::coordinator::server::Server;
use ksplus::coordinator::service::{Client, Coordinator, CoordinatorConfig};
use ksplus::coordinator::snapshot::{read_snapshot_file, write_snapshot_file};
use ksplus::coordinator::{BackendSpec, PredictorPolicy, PlanOutcome};
use ksplus::trace::Execution;
use ksplus::util::json::Json;
use ksplus::util::prop::run_prop;
use ksplus::util::rng::Rng;

fn start(shards: usize) -> Coordinator {
    Coordinator::start(
        CoordinatorConfig { k: 3, shards, ..Default::default() },
        BackendSpec::Native,
    )
    .unwrap()
}

/// A deterministic two-phase execution history.
fn history(rng: &mut Rng, n: usize) -> Vec<Execution> {
    (0..n)
        .map(|_| {
            let input = rng.uniform(1500.0, 9500.0);
            let len = 5 + rng.below(6);
            let samples: Vec<f64> = (0..len)
                .map(|j| 0.0006 * input * if j < len / 2 { 0.6 } else { 1.3 })
                .collect();
            Execution::new("t", input, 1.0, samples)
        })
        .collect()
}

const PROBE_INPUTS: [f64; 3] = [1800.0, 5200.0, 9400.0];

fn probe(client: &Client, tasks: &[String]) -> Vec<PlanOutcome> {
    let mut out = Vec::with_capacity(tasks.len() * PROBE_INPUTS.len());
    for t in tasks {
        for &input in &PROBE_INPUTS {
            out.push(client.plan_detailed(t, input));
        }
    }
    out
}

#[test]
fn snapshot_text_roundtrip_is_bit_identical_for_every_policy() {
    // Property: train tasks under EVERY predictor policy (including the
    // alt-history policies that retrain from a retained window), dump the
    // snapshot, push it through its serialized text form, restore it into
    // a pool of a different width — and every plan, provenance included,
    // is unchanged down to the last f64 bit.
    run_prop("persistence_snapshot_roundtrip", 5, |rng| {
        let src = start(2);
        let client = src.client();
        let mut tasks = Vec::new();
        for name in PredictorPolicy::names() {
            let policy = PredictorPolicy::parse(name).unwrap();
            for j in 0..2 {
                let task = format!("{name}-{j}");
                client.configure(Some(&task), policy);
                let n = 6 + rng.below(5);
                client.train(&task, history(rng, n));
                // Stream a few singles so alt-history windows and model
                // versions advance past the batch train.
                for e in history(rng, 3) {
                    client.observe(&task, e);
                }
                tasks.push(task);
            }
        }
        let before = probe(&client, &tasks);

        // Through text: the exact bytes a snapshot file would hold.
        let text = client.snapshot_json().to_string();
        let doc = Json::parse(&text).unwrap();

        let dst = start(3); // deliberately a different pool width
        let restored = dst.client().restore_snapshot(&doc).unwrap();
        assert_eq!(restored, tasks.len(), "every task must restore");
        let after = probe(&dst.client(), &tasks);
        assert_eq!(before, after, "restored plans must be bit-identical");
    });
}

#[test]
fn snapshot_file_survives_a_full_server_restart() {
    // The operational loop end-to-end: train over the wire, snapshot
    // over the wire, persist to disk, tear the whole stack down, bring
    // up a fresh pool (different width), restore from the file, and
    // serve the same plans over a new socket.
    let dir = std::env::temp_dir().join(format!("ksplus_persist_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let mut rng = Rng::new(11);
    let hist = history(&mut rng, 10);
    let singles = history(&mut rng, 4);

    let coord_a = start(2);
    let server_a = Server::start("127.0.0.1:0", coord_a.client()).unwrap();
    let mut rc = RemoteClient::connect(server_a.addr()).unwrap();
    rc.configure(Some("wt"), PredictorPolicy::WittLr).unwrap();
    rc.train("ks", &hist).unwrap();
    rc.train("wt", &hist).unwrap();
    for e in &singles {
        rc.observe("ks", e).unwrap();
        rc.observe("wt", e).unwrap();
    }
    let before_ks = rc.plan("ks", 6000.0).unwrap();
    let before_wt = rc.plan("wt", 6000.0).unwrap();
    assert_eq!(before_ks.predictor, "ksplus");
    assert_eq!(before_wt.predictor, "witt-lr");

    let doc = rc.snapshot().unwrap();
    write_snapshot_file(&dir, &doc).unwrap();
    drop(rc);
    drop(server_a);
    drop(coord_a); // nothing of the first stack survives

    let doc2 = read_snapshot_file(&dir).unwrap().expect("snapshot file must exist");
    let coord_b = start(3);
    let restored = coord_b.client().restore_snapshot(&doc2).unwrap();
    assert_eq!(restored, 2);
    let server_b = Server::start("127.0.0.1:0", coord_b.client()).unwrap();
    let mut rc2 = RemoteClient::connect(server_b.addr()).unwrap();
    let after_ks = rc2.plan("ks", 6000.0).unwrap();
    let after_wt = rc2.plan("wt", 6000.0).unwrap();
    assert_eq!(after_ks, before_ks);
    assert_eq!(after_wt, before_wt);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn chaos_shard_kills_under_load_lose_no_training() {
    // Two coordinators fed the identical observe streams — one runs
    // undisturbed, the other has every one of its three shards
    // amnesia-crashed and restored from its ring standby mid-stream.
    // Afterwards both must serve bit-identical plans, and the chaos
    // pool must account for every single acked observation.
    //
    // One writer per task: replicas replay each task's stream in ack
    // order, so a single writer makes the replica fold order (and thus
    // the restored f64 state) exactly the primary's.
    const WRITERS: usize = 4;
    const TASKS_PER_WRITER: usize = 2;
    const OBSERVES_PER_TASK: usize = 25;

    let streams: Vec<Vec<(String, Vec<Execution>)>> = (0..WRITERS)
        .map(|w| {
            (0..TASKS_PER_WRITER)
                .map(|t| {
                    let mut rng = Rng::new(0xC4A05 ^ (w * TASKS_PER_WRITER + t) as u64);
                    (format!("wf-{w}-{t}"), history(&mut rng, OBSERVES_PER_TASK))
                })
                .collect()
        })
        .collect();
    let task_names: Vec<String> = streams
        .iter()
        .flatten()
        .map(|(t, _)| t.clone())
        .collect();

    let chaos = start(3);
    let control = start(1);
    // Alternate policies so the replication path is exercised for both
    // the KS accumulators and an alt-history model.
    for (i, t) in task_names.iter().enumerate() {
        let policy =
            if i % 2 == 0 { PredictorPolicy::KsPlus } else { PredictorPolicy::WittLr };
        chaos.client().configure(Some(t), policy);
        control.client().configure(Some(t), policy);
    }

    // Control: same folds, same per-task order, no interference.
    for (task, execs) in streams.iter().flatten() {
        for e in execs {
            control.client().observe(task, e.clone());
        }
    }

    // Chaos: writers stream while every shard dies and comes back.
    let mut writers = Vec::new();
    for per_writer in &streams {
        let cl = chaos.client();
        let mine = per_writer.clone();
        writers.push(std::thread::spawn(move || {
            // Interleave this writer's tasks round-robin; per-task order
            // is preserved, which is the invariant that matters.
            for i in 0..OBSERVES_PER_TASK {
                for (task, execs) in &mine {
                    cl.observe(task, execs[i].clone());
                }
            }
        }));
    }
    let admin = chaos.client();
    let chaos_thread = std::thread::spawn(move || {
        for id in admin.shard_ids() {
            std::thread::sleep(std::time::Duration::from_millis(15));
            admin.crash_restart_shard(id).unwrap();
        }
    });
    for w in writers {
        w.join().unwrap();
    }
    chaos_thread.join().unwrap();

    // Zero lost observations, despite three amnesia crashes.
    let issued = (WRITERS * TASKS_PER_WRITER * OBSERVES_PER_TASK) as u64;
    assert_eq!(chaos.client().stats().observations, issued);

    // And the surviving state plans exactly like the undisturbed pool.
    let chaos_plans = probe(&chaos.client(), &task_names);
    let control_plans = probe(&control.client(), &task_names);
    assert_eq!(chaos_plans, control_plans, "chaos pool diverged from control");
    for p in &chaos_plans {
        assert!(p.fallback_reason.is_none(), "trained task fell back: {p:?}");
    }
}
