//! Threaded coordinator service: dynamic batcher + request router over
//! the `ModelStore`.
//!
//! One worker thread owns the store and the numeric backend. Plan
//! requests are coalesced — a flush happens when `batch_max` requests
//! are pending or the oldest has waited `batch_delay` — so each flush
//! costs one batched predict regardless of the number of clients.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::{BackendSpec, ModelStore};
use crate::segments::StepPlan;
use crate::trace::Execution;

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Segments per task model.
    pub k: usize,
    pub capacity_gb: f64,
    /// Flush the batcher at this many pending plan requests.
    pub batch_max: usize,
    /// ... or when the oldest pending request is this old.
    pub batch_delay: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            k: 4,
            capacity_gb: 128.0,
            batch_max: 64,
            batch_delay: Duration::from_millis(1),
        }
    }
}

/// How many recent plan latencies the service retains. A long-running
/// service must not grow a sample per request forever; percentiles are
/// computed over this sliding window of the most recent requests.
pub const LATENCY_WINDOW: usize = 4096;

/// Bounded ring buffer of the most recent latency samples. Replaces an
/// unbounded `Vec<f64>` that grew by one `f64` per request for the
/// lifetime of the service.
#[derive(Debug, Clone)]
pub struct LatencyWindow {
    buf: Vec<f64>,
    cap: usize,
    /// Next overwrite position once the buffer is full.
    next: usize,
    /// Samples ever recorded (not capped).
    total: u64,
}

impl Default for LatencyWindow {
    fn default() -> Self {
        LatencyWindow::with_capacity(LATENCY_WINDOW)
    }
}

impl LatencyWindow {
    pub fn with_capacity(cap: usize) -> LatencyWindow {
        assert!(cap > 0, "latency window needs capacity");
        LatencyWindow { buf: Vec::new(), cap, next: 0, total: 0 }
    }

    pub fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % self.cap;
        self.total += 1;
    }

    /// Samples currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Samples recorded over the service lifetime.
    pub fn total_recorded(&self) -> u64 {
        self.total
    }

    /// Percentile over the retained window.
    pub fn percentile(&self, p: f64) -> f64 {
        crate::util::stats::percentile(&self.buf, p)
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.buf
    }
}

/// Service-side counters, exposed via `Client::stats`.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    pub failures_handled: u64,
    pub tasks_trained: u64,
    /// Recent plan-request latencies, microseconds (enqueue -> response
    /// send), bounded to the last `LATENCY_WINDOW` requests.
    pub latencies_us: LatencyWindow,
}

impl ServiceStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        self.latencies_us.percentile(p)
    }
}

enum Msg {
    Train {
        task: String,
        history: Vec<Execution>,
        done: mpsc::SyncSender<()>,
    },
    Plan {
        task: String,
        input_mb: f64,
        enqueued: Instant,
        resp: mpsc::SyncSender<StepPlan>,
    },
    Failure {
        prev: StepPlan,
        fail_time: f64,
        resp: mpsc::SyncSender<StepPlan>,
    },
    Stats {
        resp: mpsc::SyncSender<ServiceStats>,
    },
    Shutdown,
}

/// Handle to a running coordinator; cheap to clone via `client()`.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Client endpoint (clonable, thread-safe sender).
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

struct Pending {
    task: String,
    input_mb: f64,
    enqueued: Instant,
    resp: mpsc::SyncSender<StepPlan>,
}

impl Coordinator {
    /// Spawn the worker. The backend is *built inside* the worker thread
    /// because PJRT handles are thread-affine.
    pub fn start(cfg: CoordinatorConfig, spec: BackendSpec) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = std::thread::Builder::new()
            .name("ksplus-coordinator".into())
            .spawn(move || {
                let backend = spec.build().expect("backend construction failed");
                worker(cfg, backend, rx)
            })
            .expect("spawn coordinator");
        Coordinator { tx, handle: Some(handle) }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Client {
    /// Fit (or refit) the task's segment models; blocks until stored.
    pub fn train(&self, task: &str, history: Vec<Execution>) {
        let (done_tx, done_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Msg::Train { task: task.to_string(), history, done: done_tx })
            .expect("coordinator gone");
        let _ = done_rx.recv();
    }

    /// Request an allocation plan; blocks until the batcher flushes.
    pub fn plan(&self, task: &str, input_mb: f64) -> StepPlan {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Msg::Plan {
                task: task.to_string(),
                input_mb,
                enqueued: Instant::now(),
                resp: resp_tx,
            })
            .expect("coordinator gone");
        resp_rx.recv().expect("coordinator dropped request")
    }

    /// Report an OOM; returns the rescaled retry plan.
    pub fn report_failure(&self, prev: &StepPlan, fail_time: f64) -> StepPlan {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Msg::Failure { prev: prev.clone(), fail_time, resp: resp_tx })
            .expect("coordinator gone");
        resp_rx.recv().expect("coordinator dropped request")
    }

    pub fn stats(&self) -> ServiceStats {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.tx.send(Msg::Stats { resp: resp_tx }).expect("coordinator gone");
        resp_rx.recv().expect("coordinator dropped request")
    }
}

fn worker(cfg: CoordinatorConfig, backend: crate::coordinator::Backend, rx: mpsc::Receiver<Msg>) {
    let mut store = ModelStore::new(cfg.k, cfg.capacity_gb, backend);
    let mut stats = ServiceStats::default();
    let mut pending: Vec<Pending> = Vec::new();

    let flush = |pending: &mut Vec<Pending>, store: &ModelStore, stats: &mut ServiceStats| {
        if pending.is_empty() {
            return;
        }
        let reqs: Vec<(String, f64)> =
            pending.iter().map(|p| (p.task.clone(), p.input_mb)).collect();
        let plans = store.plan_batch(&reqs);
        stats.batches += 1;
        for (p, plan) in pending.drain(..).zip(plans) {
            stats.requests += 1;
            stats.latencies_us.push(p.enqueued.elapsed().as_secs_f64() * 1e6);
            let _ = p.resp.send(plan);
        }
    };

    // Continuous ("drain-then-flush") batching: block for the first
    // message, then greedily drain whatever else is already queued —
    // requests that arrived while the previous batch was being served
    // coalesce naturally, and an idle service answers in microseconds
    // instead of waiting out a fixed delay. `batch_delay` survives only
    // as the bound on one final linger poll used when a single request
    // is pending (cheap insurance for lock-step submitters).
    'outer: loop {
        let mut next = match rx.recv() {
            Ok(m) => Some(m),
            Err(_) => break,
        };
        // Handle one message; Plan messages start a drain cycle.
        while let Some(msg) = next.take() {
            match msg {
                Msg::Plan { task, input_mb, enqueued, resp } => {
                    pending.push(Pending { task, input_mb, enqueued, resp });
                    // Drain everything already enqueued.
                    while pending.len() < cfg.batch_max {
                        match rx.try_recv() {
                            Ok(Msg::Plan { task, input_mb, enqueued, resp }) => {
                                pending.push(Pending { task, input_mb, enqueued, resp });
                            }
                            Ok(other) => {
                                next = Some(other);
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                flush(&mut pending, &store, &mut stats);
                                break 'outer;
                            }
                        }
                    }
                    // Linger once for stragglers when the batch is tiny.
                    if next.is_none() && pending.len() == 1 && !cfg.batch_delay.is_zero() {
                        if let Ok(m) = rx.recv_timeout(cfg.batch_delay.min(
                            Duration::from_micros(100),
                        )) {
                            match m {
                                Msg::Plan { task, input_mb, enqueued, resp } => {
                                    pending.push(Pending { task, input_mb, enqueued, resp });
                                }
                                other => next = Some(other),
                            }
                        }
                    }
                    flush(&mut pending, &store, &mut stats);
                }
                Msg::Train { task, history, done } => {
                    // Train implies a model swap: flush first so
                    // in-flight requests see a consistent store.
                    flush(&mut pending, &store, &mut stats);
                    store.train(&task, &history);
                    stats.tasks_trained += 1;
                    let _ = done.send(());
                }
                Msg::Failure { prev, fail_time, resp } => {
                    stats.failures_handled += 1;
                    let _ = resp.send(store.on_failure(&prev, fail_time));
                }
                Msg::Stats { resp } => {
                    let _ = resp.send(stats.clone());
                }
                Msg::Shutdown => {
                    flush(&mut pending, &store, &mut stats);
                    break 'outer;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::ksplus::KsPlus;
    use crate::predictor::Predictor;
    use crate::util::rng::Rng;

    fn two_phase_exec(input: f64, rng: &mut Rng) -> Execution {
        let d1 = ((input * 0.01) as usize).max(2);
        let d2 = ((input * 0.003) as usize).max(1);
        let mut s = vec![input * 0.0005; d1];
        s.extend(vec![input * 0.001; d2]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new("bwa", input, 1.0, s)
    }

    fn history(seed: u64, n: usize) -> Vec<Execution> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect()
    }

    #[test]
    fn end_to_end_plan_matches_offline_predictor() {
        let hist = history(1, 30);
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        );
        let client = coord.client();
        client.train("bwa", hist.clone());
        let got = client.plan("bwa", 8000.0);
        let mut want = KsPlus::new(2, 128.0);
        want.train(&hist);
        let want = want.plan(8000.0);
        assert_eq!(got.k(), want.k());
        for i in 0..got.k() {
            assert!((got.starts[i] - want.starts[i]).abs() < 1e-9);
            assert!((got.peaks[i] - want.peaks[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn concurrent_clients_get_batched() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                k: 2,
                batch_max: 16,
                batch_delay: Duration::from_millis(4),
                ..Default::default()
            },
            BackendSpec::Native,
        );
        let client = coord.client();
        client.train("bwa", history(2, 20));
        let mut handles = Vec::new();
        for i in 0..32 {
            let c = coord.client();
            handles.push(std::thread::spawn(move || {
                c.plan("bwa", 3000.0 + i as f64 * 100.0)
            }));
        }
        let plans: Vec<StepPlan> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(plans.len(), 32);
        assert!(plans.iter().all(|p| p.is_valid()));
        let stats = client.stats();
        assert_eq!(stats.requests, 32);
        assert!(stats.batches < 32, "no batching happened: {}", stats.batches);
        assert!(stats.mean_batch_size() > 1.0);
    }

    #[test]
    fn failure_roundtrip() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        );
        let client = coord.client();
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let retry = client.report_failure(&prev, 60.0);
        assert_eq!(retry.starts, vec![0.0, 60.0]);
        assert_eq!(client.stats().failures_handled, 1);
    }

    #[test]
    fn unknown_task_served_with_fallback() {
        let coord = Coordinator::start(CoordinatorConfig::default(), BackendSpec::Native);
        let plan = coord.client().plan("never-trained", 123.0);
        assert!(plan.is_valid());
    }

    #[test]
    fn stats_latency_recorded() {
        let coord = Coordinator::start(
            CoordinatorConfig { batch_delay: Duration::from_micros(200), ..Default::default() },
            BackendSpec::Native,
        );
        let client = coord.client();
        client.train("bwa", history(3, 10));
        for _ in 0..5 {
            client.plan("bwa", 4000.0);
        }
        let stats = client.stats();
        assert_eq!(stats.latencies_us.len(), 5);
        assert!(stats.latency_percentile_us(50.0) > 0.0);
    }

    #[test]
    fn latency_window_is_bounded() {
        let mut w = LatencyWindow::with_capacity(8);
        for i in 0..100 {
            w.push(i as f64);
        }
        assert_eq!(w.len(), 8);
        assert_eq!(w.total_recorded(), 100);
        // Only the most recent 8 samples (92..=99) remain.
        assert!(w.as_slice().iter().all(|&v| v >= 92.0));
        let p50 = w.percentile(50.0);
        assert!((92.0..=99.0).contains(&p50), "p50 {p50}");
        assert_eq!(w.percentile(100.0), 99.0);
    }

    #[test]
    fn service_latencies_stay_bounded() {
        // The stats window must not grow past its capacity no matter how
        // many requests the service handles.
        let coord = Coordinator::start(
            CoordinatorConfig { batch_delay: Duration::ZERO, ..Default::default() },
            BackendSpec::Native,
        );
        let client = coord.client();
        client.train("bwa", history(5, 10));
        let n = 64;
        for _ in 0..n {
            client.plan("bwa", 4000.0);
        }
        let stats = client.stats();
        assert_eq!(stats.requests, n);
        assert_eq!(stats.latencies_us.total_recorded(), n);
        assert!(stats.latencies_us.len() <= LATENCY_WINDOW);
        assert!(stats.latency_percentile_us(99.0) > 0.0);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_end_to_end() {
        // The production path: coordinator worker owns a PJRT runtime
        // built from the AOT artifacts; plans must match the native
        // backend to f32 precision.
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let hist = history(7, 25);
        let cfg = CoordinatorConfig { k: 3, ..Default::default() };
        let pjrt = Coordinator::start(cfg.clone(), BackendSpec::Pjrt(Some(dir)));
        let native = Coordinator::start(cfg, BackendSpec::Native);
        pjrt.client().train("bwa", hist.clone());
        native.client().train("bwa", hist);
        for input in [2500.0, 6000.0, 11000.0] {
            let a = pjrt.client().plan("bwa", input);
            let b = native.client().plan("bwa", input);
            assert_eq!(a.k(), b.k(), "{a:?} vs {b:?}");
            for i in 0..a.k() {
                assert!((a.starts[i] - b.starts[i]).abs() < 0.5, "{a:?} vs {b:?}");
                assert!((a.peaks[i] - b.peaks[i]).abs() < 0.05, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn shutdown_flushes_cleanly() {
        let coord = Coordinator::start(CoordinatorConfig::default(), BackendSpec::Native);
        let client = coord.client();
        client.train("bwa", history(4, 10));
        drop(coord); // must not hang or panic
        // Client calls after shutdown fail loudly (panic) — we only
        // check drop-order safety here.
        let _ = client;
    }
}
