//! Single-threaded event-loop coordinator server.
//!
//! One loop thread owns every socket: it accepts connections, reads
//! bytes, splits them into frames on the connection's negotiated wire
//! ([`Wire::V1`] JSON lines or [`Wire::V2`] binary), and hands decoded
//! [`Request`]s to a small pool of dispatch workers so a slow shard
//! never stalls the loop. Responses come back as encoded bytes tagged
//! with a per-connection sequence number; the loop flushes them in
//! request order, which is what makes pipelining safe: a client may
//! write N requests back-to-back and read N responses in the same
//! order, even though the dispatch pool executes them in parallel.
//!
//! Readiness comes from [`poll::Poller`] (epoll/kqueue, level
//! triggered); idle connections are reaped through a coarse
//! [`TimerWheel`]. The threaded server in [`super::server`] stays as
//! the parity oracle — both front ends call the same
//! [`service::dispatch`], so behavior differences are wire bugs by
//! construction.

use std::collections::{BTreeMap, VecDeque};
use std::io::{self, Read, Write};
use std::mem;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::faults::FaultPlane;
use crate::coordinator::poll::{drain_waker, waker_pair, Event, Poller, Waker};
use crate::coordinator::protocol::{ErrorCode, Request, WireError};
use crate::coordinator::server::{dispatch_contained, encode_response_or_error, ServerConfig};
use crate::coordinator::service::{
    Client, ConnCounters, Coordinator, CoordinatorConfig, DispatchTap, Dispatched,
};
use crate::coordinator::timer::TimerWheel;
use crate::coordinator::wire::{decode_request, encode_error, FrameSplit, Wire};
use crate::coordinator::BackendSpec;
use crate::util::sync::{lock_recover, wait_recover};

const TOKEN_LISTENER: usize = 0;
const TOKEN_WAKER: usize = 1;
/// Connection tokens start here; `token - TOKEN_BASE` is the slab index.
const TOKEN_BASE: usize = 2;

/// Per-connection state owned by the loop thread.
struct Conn {
    stream: TcpStream,
    /// Slot generation at admit time. `EventLoop::gens[idx]` is bumped
    /// on every close, so completions and timer entries minted for an
    /// earlier occupant of a recycled slot carry a stale generation and
    /// are dropped on mismatch.
    gen: u64,
    /// Codec for frames *read from* this connection. Captured per
    /// request at decode time, so responses straddling a mid-pipeline
    /// `hello` upgrade still encode on the wire their request used.
    wire: Wire,
    rbuf: Vec<u8>,
    rpos: usize,
    wbuf: Vec<u8>,
    wpos: usize,
    /// Sequence number the next decoded request gets.
    next_seq: u64,
    /// Sequence number the next response to hit `wbuf` must carry.
    flush_seq: u64,
    /// Out-of-order completions parked until their turn.
    pending: BTreeMap<u64, Vec<u8>>,
    /// Peer EOF seen or a fatal protocol error queued: stop reading,
    /// flush what is owed, then close.
    draining: bool,
    last_activity: Instant,
    interest_r: bool,
    interest_w: bool,
}

/// A decoded request travelling to the dispatch pool.
struct Work {
    token: usize,
    gen: u64,
    seq: u64,
    wire: Wire,
    req: Request,
}

/// An encoded response travelling back to the loop.
struct Done {
    token: usize,
    gen: u64,
    seq: u64,
    bytes: Vec<u8>,
}

struct QueueState {
    work: VecDeque<Work>,
    stopping: bool,
}

/// State shared between the loop thread and the dispatch workers.
struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
    completions: Mutex<Vec<Done>>,
    waker: Waker,
    client: Client,
    counters: Arc<ConnCounters>,
    tap: Option<Arc<dyn DispatchTap>>,
    faults: Option<Arc<FaultPlane>>,
    /// Graceful-drain flag: the loop stops accepting connections and
    /// reading requests, but keeps flushing until everything owed is on
    /// the wire (or the drain deadline passes).
    draining: AtomicBool,
}

fn worker(shared: Arc<Shared>) {
    // Poison-recovering locks throughout: one panicking worker (already
    // contained by `dispatch_contained`, but belt and braces) must not
    // cascade into every thread touching the shared queue.
    let mut q = lock_recover(&shared.queue);
    loop {
        let work = loop {
            if let Some(w) = q.work.pop_front() {
                break w;
            }
            if q.stopping {
                return;
            }
            q = wait_recover(&shared.cv, q);
        };
        drop(q);
        let bytes = match dispatch_contained(
            work.req,
            &shared.client,
            &shared.counters,
            shared.tap.as_ref(),
            shared.faults.as_ref(),
        ) {
            Dispatched::Reply(resp) => encode_response_or_error(work.wire, &resp),
            Dispatched::Error(err) => encode_error(work.wire, &err),
            // Hellos are handled inline by the loop (the codec switch
            // must be ordered against frame parsing); if one ever lands
            // here, answer it on the request's wire without switching.
            Dispatched::Hello(resp, _) => encode_response_or_error(work.wire, &resp),
        };
        lock_recover(&shared.completions).push(Done {
            token: work.token,
            gen: work.gen,
            seq: work.seq,
            bytes,
        });
        shared.waker.wake();
        q = lock_recover(&shared.queue);
    }
}

struct EventLoop {
    poller: Poller,
    listener: TcpListener,
    waker_rx: UnixStream,
    slab: Vec<Option<Conn>>,
    /// Per-slot generation counters, parallel to `slab`. Bumped on every
    /// close so anything minted for a previous occupant is droppable.
    gens: Vec<u64>,
    free: Vec<usize>,
    live: usize,
    wheel: Option<TimerWheel>,
    cfg: ServerConfig,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
}

/// How long a graceful drain may take before `stop()` gives up on the
/// remaining in-flight work and shuts down anyway.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

impl EventLoop {
    fn run(&mut self) {
        let mut events: Vec<Event> = Vec::new();
        let mut drain_deadline: Option<Instant> = None;
        loop {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let draining = self.shared.draining.load(Ordering::SeqCst);
            if draining {
                match drain_deadline {
                    None => drain_deadline = Some(Instant::now() + DRAIN_DEADLINE),
                    Some(d) if Instant::now() >= d => break,
                    Some(_) => {}
                }
                // Everything owed is on the wire: the drain is complete.
                if self.fully_flushed() {
                    break;
                }
            }
            let timeout = if draining {
                // Bounded poll so the deadline and flush checks re-run
                // even when no event fires.
                Some(Duration::from_millis(20))
            } else {
                self.wheel
                    .as_ref()
                    .and_then(|w| w.next_wakeup(Instant::now()))
            };
            match self.poller.wait(&mut events, timeout) {
                Ok(()) => {}
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            for i in 0..events.len() {
                let ev = events[i];
                match ev.token {
                    // During a drain nothing new is admitted or read:
                    // finishing what was accepted is the whole point.
                    TOKEN_LISTENER => {
                        if !draining {
                            self.accept_ready();
                        }
                    }
                    TOKEN_WAKER => drain_waker(&self.waker_rx),
                    token => {
                        let idx = token - TOKEN_BASE;
                        if ev.readable && !draining {
                            self.conn_readable(idx);
                        }
                        if ev.writable || draining {
                            self.after_io(idx);
                        }
                    }
                }
            }
            self.drain_completions();
            self.reap_idle();
        }
    }

    /// True when no request is owed a response anywhere: the dispatch
    /// queue and completion buffer are empty and every live connection
    /// has flushed all of its responses to the socket.
    fn fully_flushed(&self) -> bool {
        if !lock_recover(&self.shared.queue).work.is_empty() {
            return false;
        }
        if !lock_recover(&self.shared.completions).is_empty() {
            return false;
        }
        self.slab.iter().flatten().all(|c| {
            c.flush_seq == c.next_seq && c.wpos >= c.wbuf.len()
        })
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => self.admit(stream),
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn admit(&mut self, stream: TcpStream) {
        if self.live >= self.cfg.max_conns {
            self.shared.counters.refused.fetch_add(1, Ordering::Relaxed);
            // Same refusal the threaded server sends, best-effort; the
            // peer has not negotiated yet, so it speaks v1.
            let err = WireError::new(
                ErrorCode::TooManyConnections,
                format!(
                    "server is at its limit of {} connections",
                    self.cfg.max_conns
                ),
            );
            let _ = stream.set_nonblocking(false);
            let mut stream = stream;
            let _ = stream.write_all(&encode_error(Wire::V1, &err));
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let idx = match self.free.pop() {
            Some(idx) => idx,
            None => {
                self.slab.push(None);
                self.gens.push(0);
                self.slab.len() - 1
            }
        };
        let token = idx + TOKEN_BASE;
        if self
            .poller
            .register(stream.as_raw_fd(), token, true, false)
            .is_err()
        {
            self.free.push(idx);
            return;
        }
        let gen = self.gens[idx];
        let now = Instant::now();
        if let (Some(wheel), Some(timeout)) = (self.wheel.as_mut(), self.cfg.read_timeout) {
            wheel.schedule(now + timeout, token, gen);
        }
        self.slab[idx] = Some(Conn {
            stream,
            gen,
            wire: Wire::V1,
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            next_seq: 0,
            flush_seq: 0,
            pending: BTreeMap::new(),
            draining: false,
            last_activity: now,
            interest_r: true,
            interest_w: false,
        });
        self.live += 1;
    }

    fn conn_readable(&mut self, idx: usize) {
        let faults = self.cfg.faults.clone();
        let mut dead = false;
        {
            let conn = match self.slab.get_mut(idx).and_then(Option::as_mut) {
                Some(c) => c,
                None => return,
            };
            if conn.draining {
                return;
            }
            let mut chunk = [0u8; 64 * 1024];
            loop {
                // `short-io` fault: read fewer bytes than the socket
                // offers, exercising partial-frame reassembly.
                let want = match &faults {
                    Some(f) => f.clamp_io(chunk.len()),
                    None => chunk.len(),
                };
                match conn.stream.read(&mut chunk[..want]) {
                    Ok(0) => {
                        conn.draining = true;
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&chunk[..n]);
                        conn.last_activity = Instant::now();
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
        }
        if dead {
            self.close(idx);
            return;
        }
        self.parse_frames(idx);
        self.after_io(idx);
    }

    /// Split the read buffer into frames on the connection's current
    /// wire, dispatching each. Hellos are handled inline so the codec
    /// switch is ordered against later frames already in the buffer.
    fn parse_frames(&mut self, idx: usize) {
        let cfg_max = self.cfg.max_frame_bytes;
        let max_queue_depth = self.cfg.max_queue_depth;
        let max_inflight = self.cfg.max_inflight;
        let shared = Arc::clone(&self.shared);
        let conn = match self.slab.get_mut(idx).and_then(Option::as_mut) {
            Some(c) => c,
            None => return,
        };
        let mut new_work = false;
        loop {
            match conn.wire.split(&conn.rbuf[conn.rpos..], cfg_max) {
                FrameSplit::Incomplete => break,
                FrameSplit::TooLarge => {
                    let seq = conn.next_seq;
                    conn.next_seq += 1;
                    let err = WireError::new(
                        ErrorCode::RequestTooLarge,
                        format!(
                            "request exceeds the {}-byte limit; closing connection",
                            cfg_max
                        ),
                    );
                    conn.pending.insert(seq, encode_error(conn.wire, &err));
                    conn.draining = true;
                    break;
                }
                FrameSplit::Frame { consumed, from, to } => {
                    let payload_from = conn.rpos + from;
                    let payload_to = conn.rpos + to;
                    conn.rpos += consumed;
                    let decoded =
                        decode_request(conn.wire, &conn.rbuf[payload_from..payload_to]);
                    match decoded {
                        Ok(None) => {} // blank v1 line: no reply
                        Ok(Some(req @ Request::Hello { .. })) => {
                            let seq = conn.next_seq;
                            conn.next_seq += 1;
                            match dispatch_contained(
                                req,
                                &shared.client,
                                &shared.counters,
                                shared.tap.as_ref(),
                                shared.faults.as_ref(),
                            ) {
                                Dispatched::Hello(resp, version) => {
                                    // STARTTLS-style: the answer travels
                                    // on the wire the hello arrived on;
                                    // everything after switches.
                                    conn.pending.insert(
                                        seq,
                                        encode_response_or_error(conn.wire, &resp),
                                    );
                                    if let Some(w) = Wire::from_version(version) {
                                        conn.wire = w;
                                    }
                                }
                                Dispatched::Reply(resp) => {
                                    conn.pending.insert(
                                        seq,
                                        encode_response_or_error(conn.wire, &resp),
                                    );
                                }
                                Dispatched::Error(err) => {
                                    conn.pending.insert(seq, encode_error(conn.wire, &err));
                                }
                            }
                        }
                        Ok(Some(req)) => {
                            let seq = conn.next_seq;
                            conn.next_seq += 1;
                            // Admission control: shed instead of queueing
                            // without bound. The request is *rejected*
                            // with a structured `overloaded` error slotted
                            // into its in-order reply position — the
                            // connection stays open and later requests
                            // are admitted again once pressure drops.
                            let inflight = conn.next_seq - conn.flush_seq;
                            let mut shed_reason = None;
                            if max_inflight > 0 && inflight > max_inflight as u64 {
                                shed_reason = Some(format!(
                                    "connection has {} requests in flight (cap {})",
                                    inflight - 1,
                                    max_inflight
                                ));
                            } else {
                                let mut q = lock_recover(&shared.queue);
                                let depth = q.work.len();
                                if max_queue_depth > 0 && depth >= max_queue_depth {
                                    shed_reason = Some(format!(
                                        "dispatch queue is full ({depth} queued, cap {max_queue_depth})"
                                    ));
                                } else {
                                    q.work.push_back(Work {
                                        token: idx + TOKEN_BASE,
                                        gen: conn.gen,
                                        seq,
                                        wire: conn.wire,
                                        req,
                                    });
                                    drop(q);
                                    shared.counters.note_queue_depth(depth as u64 + 1);
                                    new_work = true;
                                }
                            }
                            if let Some(reason) = shed_reason {
                                shared.counters.shed.fetch_add(1, Ordering::Relaxed);
                                let err = WireError::new(
                                    ErrorCode::Overloaded,
                                    format!("{reason}; retry after backoff"),
                                );
                                conn.pending.insert(seq, encode_error(conn.wire, &err));
                            }
                        }
                        Err(err) => {
                            // Malformed frame: structured error, stay open
                            // (matches the threaded server's behavior).
                            let seq = conn.next_seq;
                            conn.next_seq += 1;
                            conn.pending.insert(seq, encode_error(conn.wire, &err));
                        }
                    }
                }
            }
        }
        if conn.rpos > 0 {
            conn.rbuf.drain(..conn.rpos);
            conn.rpos = 0;
        }
        if new_work {
            shared.cv.notify_all();
        }
    }

    /// Move in-order completions into the write buffer, flush as much
    /// as the socket accepts, then settle interest/close state.
    fn after_io(&mut self, idx: usize) {
        let faults = self.cfg.faults.clone();
        let mut torn = false;
        {
            let conn = match self.slab.get_mut(idx).and_then(Option::as_mut) {
                Some(c) => c,
                None => return,
            };
            while let Some(bytes) = conn.pending.remove(&conn.flush_seq) {
                conn.flush_seq += 1;
                // `corrupt` fault: tear this response frame — write only
                // a strict prefix and sever the connection, simulating a
                // crash mid-response. The client never sees an ack, so
                // retrying the request is safe (and dedup makes a
                // retried mutation exactly-once).
                if let Some(f) = &faults {
                    if let Some(keep) = f.tear_frame(bytes.len()) {
                        conn.wbuf.extend_from_slice(&bytes[..keep]);
                        torn = true;
                        break;
                    }
                }
                conn.wbuf.extend_from_slice(&bytes);
            }
        }
        if torn {
            let _ = self.try_write(idx);
            self.close(idx);
            return;
        }
        if !self.try_write(idx) {
            self.close(idx);
            return;
        }
        let max_wbuf = self.cfg.max_wbuf_bytes;
        let overflowed = match self.slab.get(idx).and_then(Option::as_ref) {
            Some(c) => c.wbuf.len() - c.wpos > max_wbuf,
            None => return,
        };
        if overflowed {
            // A peer that pipelines requests but stops reading responses
            // would otherwise grow `wbuf` without bound. Past the cap the
            // slow reader is cut off rather than the server OOM-killed.
            self.shared
                .counters
                .overflows
                .fetch_add(1, Ordering::Relaxed);
            self.close(idx);
            return;
        }
        let (close_now, want_r, want_w, fd, token, change) = {
            let conn = match self.slab.get_mut(idx).and_then(Option::as_mut) {
                Some(c) => c,
                None => return,
            };
            let flushed = conn.wbuf.is_empty();
            let close_now = conn.draining && conn.flush_seq == conn.next_seq && flushed;
            let want_r = !conn.draining;
            let want_w = !flushed;
            let change = want_r != conn.interest_r || want_w != conn.interest_w;
            conn.interest_r = want_r;
            conn.interest_w = want_w;
            (
                close_now,
                want_r,
                want_w,
                conn.stream.as_raw_fd(),
                idx + TOKEN_BASE,
                change,
            )
        };
        if close_now {
            self.close(idx);
        } else if change {
            let _ = self.poller.reregister(fd, token, want_r, want_w);
        }
    }

    /// Returns false when the connection died mid-write.
    fn try_write(&mut self, idx: usize) -> bool {
        let faults = self.cfg.faults.clone();
        let conn = match self.slab.get_mut(idx).and_then(Option::as_mut) {
            Some(c) => c,
            None => return true,
        };
        while conn.wpos < conn.wbuf.len() {
            // `short-io` fault: offer the socket a shorter slice,
            // splitting responses across writes (the peer sees the same
            // bytes, just in more pieces).
            let avail = conn.wbuf.len() - conn.wpos;
            let want = match &faults {
                Some(f) => f.clamp_io(avail),
                None => avail,
            };
            match conn.stream.write(&conn.wbuf[conn.wpos..conn.wpos + want]) {
                Ok(0) => return false,
                Ok(n) => conn.wpos += n,
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if conn.wpos >= conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
        }
        true
    }

    fn drain_completions(&mut self) {
        let done = mem::take(&mut *lock_recover(&self.shared.completions));
        let mut touched = Vec::new();
        for d in done {
            let idx = d.token - TOKEN_BASE;
            let conn = match self.slab.get_mut(idx).and_then(Option::as_mut) {
                Some(c) => c,
                None => continue,
            };
            if conn.gen != d.gen {
                continue; // completion for a closed, recycled slot
            }
            conn.pending.insert(d.seq, d.bytes);
            if !touched.contains(&idx) {
                touched.push(idx);
            }
        }
        for idx in touched {
            self.after_io(idx);
        }
    }

    fn reap_idle(&mut self) {
        let timeout = match self.cfg.read_timeout {
            Some(t) => t,
            None => return,
        };
        let wheel = match self.wheel.as_mut() {
            Some(w) => w,
            None => return,
        };
        let now = Instant::now();
        let due = wheel.expire(now);
        let mut reap = Vec::new();
        for (token, gen) in due {
            let idx = token - TOKEN_BASE;
            let conn = match self.slab.get_mut(idx).and_then(Option::as_mut) {
                Some(c) => c,
                None => continue,
            };
            if conn.gen != gen {
                continue; // stale entry for a recycled slot
            }
            if conn.flush_seq < conn.next_seq {
                // Requests are still in the dispatch pool (or parked
                // out-of-order): the peer is waiting on us, not idle.
                // `last_activity` only moves on reads, so without this
                // guard a long dispatch under a short timeout would reap
                // a connection mid-flight and drop its responses.
                wheel.schedule(now + timeout, token, gen);
                continue;
            }
            let deadline = conn.last_activity + timeout;
            if now >= deadline {
                reap.push(idx);
            } else {
                wheel.schedule(deadline, token, gen);
            }
        }
        for idx in reap {
            // Matches the threaded server: an idle timeout counts and
            // closes without a goodbye frame.
            self.shared.counters.timeouts.fetch_add(1, Ordering::Relaxed);
            self.close(idx);
        }
    }

    fn close(&mut self, idx: usize) {
        if let Some(conn) = self.slab.get_mut(idx).and_then(Option::take) {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
            // Invalidate everything minted for this occupant before the
            // slot can be recycled.
            self.gens[idx] = self.gens[idx].wrapping_add(1);
            self.free.push(idx);
            self.live -= 1;
        }
    }
}

/// Handle to a running event-loop server. Mirrors
/// [`super::server::Server`]'s lifecycle API so call sites can swap
/// front ends without touching anything else.
pub struct EventLoopServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shared: Arc<Shared>,
    loop_handle: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl EventLoopServer {
    pub fn start(addr: impl ToSocketAddrs, client: Client) -> Result<EventLoopServer> {
        EventLoopServer::start_with_config(addr, client, ServerConfig::default())
    }

    /// Build a coordinator for `spec` and serve it, mirroring
    /// `Server::start_with_backend` so front ends swap freely.
    pub fn start_with_backend(
        addr: impl ToSocketAddrs,
        config: CoordinatorConfig,
        spec: BackendSpec,
    ) -> Result<(Coordinator, EventLoopServer)> {
        let coord = Coordinator::start(config, spec).context("start coordinator")?;
        let server = EventLoopServer::start(addr, coord.client())?;
        Ok((coord, server))
    }

    pub fn start_with_config(
        addr: impl ToSocketAddrs,
        client: Client,
        cfg: ServerConfig,
    ) -> Result<EventLoopServer> {
        let listener = TcpListener::bind(addr).context("binding event-loop listener")?;
        listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let poller = Poller::new().context("creating readiness poller")?;
        let (waker, waker_rx) = waker_pair().context("creating loop waker")?;
        poller
            .register(listener.as_raw_fd(), TOKEN_LISTENER, true, false)
            .context("registering listener")?;
        poller
            .register(waker_rx.as_raw_fd(), TOKEN_WAKER, true, false)
            .context("registering waker")?;

        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                work: VecDeque::new(),
                stopping: false,
            }),
            cv: Condvar::new(),
            completions: Mutex::new(Vec::new()),
            waker,
            client,
            counters: Arc::new(ConnCounters::default()),
            tap: cfg.tap.clone(),
            faults: cfg.faults.clone(),
            draining: AtomicBool::new(false),
        });
        let stop = Arc::new(AtomicBool::new(false));

        let n_workers = if cfg.dispatch_threads == 0 {
            thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 16)
        } else {
            cfg.dispatch_threads
        };
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let shared = Arc::clone(&shared);
            workers.push(
                thread::Builder::new()
                    .name(format!("ksplus-dispatch-{i}"))
                    .spawn(move || worker(shared))
                    .context("spawning dispatch worker")?,
            );
        }

        let wheel = cfg
            .read_timeout
            .map(|t| TimerWheel::new(t, Instant::now()));
        let mut el = EventLoop {
            poller,
            listener,
            waker_rx,
            slab: Vec::new(),
            gens: Vec::new(),
            free: Vec::new(),
            live: 0,
            wheel,
            cfg,
            shared: Arc::clone(&shared),
            stop: Arc::clone(&stop),
        };
        let loop_handle = thread::Builder::new()
            .name("ksplus-eventloop".to_string())
            .spawn(move || el.run())
            .context("spawning event loop")?;

        Ok(EventLoopServer {
            addr,
            stop,
            shared,
            loop_handle: Some(loop_handle),
            workers,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// This front end's connection counters (shed / overflow / drain
    /// totals survive `stop()`, so callers can read them afterwards).
    pub fn counters(&self) -> Arc<ConnCounters> {
        self.shared.counters.clone()
    }

    /// Gracefully drain, then stop the loop and the dispatch pool. The
    /// drain stops accepting connections and reading requests, lets the
    /// workers finish everything already queued, and flushes every owed
    /// response to the wire before tearing sockets down — an acked
    /// request is never silently discarded by a shutdown. The drain is
    /// bounded by [`DRAIN_DEADLINE`]; past it, leftover work is dropped
    /// (those clients never got an ack, so their retries are safe).
    pub fn stop(&mut self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.waker.wake();
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
            self.shared.counters.drains.fetch_add(1, Ordering::Relaxed);
        }
        self.stop.store(true, Ordering::SeqCst);
        {
            let mut q = lock_recover(&self.shared.queue);
            q.stopping = true;
            // A completed drain left this empty; only a deadline
            // overrun leaves (unacked) work to discard.
            q.work.clear();
        }
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for EventLoopServer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{Response, WIRE_V2, WIRE_VERSION};
    use crate::coordinator::wire::{
        decode_response, read_frame, try_encode_request, FrameRead, DEFAULT_MAX_FRAME_BYTES,
    };
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader};
    use std::time::Duration;

    fn start() -> (Coordinator, EventLoopServer) {
        start_cfg(ServerConfig::default())
    }

    fn start_cfg(cfg: ServerConfig) -> (Coordinator, EventLoopServer) {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let server =
            EventLoopServer::start_with_config("127.0.0.1:0", coord.client(), cfg).unwrap();
        (coord, server)
    }

    fn connect(server: &EventLoopServer) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (stream, reader)
    }

    fn roundtrip(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Json {
        writeln!(stream, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(&resp).unwrap()
    }

    fn err_code(resp: &Json) -> Option<&str> {
        resp.get("error").and_then(|e| e.get("code")).and_then(Json::as_str)
    }

    fn train_req(task: &str) -> String {
        format!(
            r#"{{"op":"train","task":"{task}","history":[{{"input_mb":100,"dt":1.0,"samples":[1.0,2.0,3.0]}},{{"input_mb":200,"dt":1.0,"samples":[2.0,4.0,6.0]}}]}}"#
        )
    }

    fn read_v2(reader: &mut BufReader<TcpStream>, op: &str) -> Result<Response, WireError> {
        match read_frame(reader, Wire::V2, 1 << 24).unwrap() {
            FrameRead::Frame(payload) => decode_response(Wire::V2, &payload, op),
            other => panic!("expected a frame for op {op}, got {other:?}"),
        }
    }

    #[test]
    fn serves_v1_json_unchanged() {
        let (_coord, server) = start();
        let (mut stream, mut reader) = connect(&server);

        let resp = roundtrip(&mut stream, &mut reader, &train_req("ingest"));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("executions").and_then(Json::as_usize), Some(2));

        // A blank line is skipped without a reply, like the threaded
        // server: the next line's response is the first thing we read.
        stream.write_all(b"\n").unwrap();
        let resp = roundtrip(
            &mut stream,
            &mut reader,
            r#"{"op":"plan","task":"ingest","input_mb":150}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("predictor").and_then(Json::as_str), Some("ksplus"));
        assert!(resp.get("plan").is_some());

        let resp = roundtrip(&mut stream, &mut reader, "not json at all");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(err_code(&resp), Some("invalid-json"));

        let resp = roundtrip(&mut stream, &mut reader, r#"{"op":"warp"}"#);
        assert_eq!(err_code(&resp), Some("unknown-op"));

        // Errors do not wedge the connection.
        let resp = roundtrip(&mut stream, &mut reader, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn hello_upgrades_to_v2_binary() {
        let (_coord, server) = start();
        let (mut stream, mut reader) = connect(&server);

        // The hello travels as JSON; its *response* is still JSON.
        let resp = roundtrip(
            &mut stream,
            &mut reader,
            r#"{"op":"hello","min_version":1,"max_version":2}"#,
        );
        assert_eq!(resp.get("version").and_then(Json::as_usize), Some(WIRE_V2));

        // Everything after is binary, both directions.
        let train = Request::parse(&train_req("etl")).unwrap();
        stream.write_all(&try_encode_request(Wire::V2, &train, DEFAULT_MAX_FRAME_BYTES).unwrap()).unwrap();
        match read_v2(&mut reader, "train").expect("train should succeed") {
            Response::Trained { executions, .. } => assert_eq!(executions, 2),
            other => panic!("unexpected response: {other:?}"),
        }

        let plan = Request::Plan { task: "etl".to_string(), input_mb: 150.0 };
        stream.write_all(&try_encode_request(Wire::V2, &plan, DEFAULT_MAX_FRAME_BYTES).unwrap()).unwrap();
        match read_v2(&mut reader, "plan").expect("plan should succeed") {
            Response::Planned(o) => {
                assert_eq!(o.predictor, "ksplus");
                assert!(o.plan.is_valid());
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn pipelined_requests_get_in_order_responses() {
        let (_coord, server) = start();
        let (mut stream, mut reader) = connect(&server);

        // Eight observes for distinct tasks written in one burst; the
        // dispatch pool may execute them in any order, but responses
        // must come back in request order.
        let mut batch = String::new();
        for i in 0..8 {
            batch.push_str(&format!(
                r#"{{"op":"observe","task":"t{i}","execution":{{"input_mb":10,"dt":1.0,"samples":[1.0,2.0]}}}}"#
            ));
            batch.push('\n');
        }
        stream.write_all(batch.as_bytes()).unwrap();
        for i in 0..8 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            assert_eq!(
                resp.get("observed").and_then(Json::as_str),
                Some(format!("t{i}")).as_deref(),
                "response {i} out of order"
            );
        }

        // Same property on the binary wire after an upgrade.
        let resp = roundtrip(&mut stream, &mut reader, r#"{"op":"hello","max_version":2}"#);
        assert_eq!(resp.get("version").and_then(Json::as_usize), Some(WIRE_V2));
        let mut batch = Vec::new();
        for i in 0..8 {
            let req = Request::Observe {
                task: format!("t{i}"),
                execution: crate::trace::Execution::new(
                    format!("t{i}"),
                    20.0,
                    1.0,
                    vec![1.0, 2.0],
                ),
                dedup: None,
            };
            batch.extend_from_slice(&try_encode_request(Wire::V2, &req, DEFAULT_MAX_FRAME_BYTES).unwrap());
        }
        stream.write_all(&batch).unwrap();
        for i in 0..8 {
            match read_v2(&mut reader, "observe")
                .unwrap_or_else(|e| panic!("observe {i} failed: {e:?}"))
            {
                Response::Observed(ack) => {
                    assert_eq!(ack.task, format!("t{i}"), "response {i} out of order");
                    assert_eq!(ack.executions, 2, "t{i} saw both observes");
                }
                other => panic!("unexpected response: {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_frame_rejected_on_both_wires() {
        let cfg = ServerConfig { max_frame_bytes: 4096, ..Default::default() };

        // v1: a line over the cap draws the structured error, then EOF.
        let (_coord, server) = start_cfg(cfg);
        let (mut stream, mut reader) = connect(&server);
        writeln!(stream, r#"{{"op":"plan","task":"{}"}}"#, "x".repeat(8192)).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(err_code(&resp), Some("request-too-large"));
        line.clear();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "connection must close after request-too-large");

        // v2: the refusal happens on the 4-byte header alone — the
        // oversized payload is never read, let alone allocated.
        let (mut stream, mut reader) = connect(&server);
        let resp = roundtrip(&mut stream, &mut reader, r#"{"op":"hello","max_version":2}"#);
        assert_eq!(resp.get("version").and_then(Json::as_usize), Some(WIRE_V2));
        stream.write_all(&(1u32 << 20).to_le_bytes()).unwrap();
        let err = read_v2(&mut reader, "plan").expect_err("expected request-too-large");
        assert_eq!(err.code, ErrorCode::RequestTooLarge);
        let mut one = [0u8; 1];
        let n = stream.read(&mut one).unwrap_or(0);
        assert_eq!(n, 0, "connection must close after the error frame");
    }

    #[test]
    fn connection_limit_refuses_with_wire_error_and_counts_it() {
        let (_coord, server) =
            start_cfg(ServerConfig { max_conns: 2, ..Default::default() });
        // Prove both slots are admitted by serving a request on each.
        let (mut s1, mut r1) = connect(&server);
        assert_eq!(
            roundtrip(&mut s1, &mut r1, r#"{"op":"stats"}"#).get("ok"),
            Some(&Json::Bool(true))
        );
        let (mut s2, mut r2) = connect(&server);
        assert_eq!(
            roundtrip(&mut s2, &mut r2, r#"{"op":"stats"}"#).get("ok"),
            Some(&Json::Bool(true))
        );

        let (_s3, mut r3) = connect(&server);
        let mut line = String::new();
        r3.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(err_code(&resp), Some("too-many-connections"));
        line.clear();
        assert_eq!(r3.read_line(&mut line).unwrap_or(0), 0, "refused conn closes");

        let resp = roundtrip(&mut s1, &mut r1, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("conns_refused").and_then(Json::as_usize), Some(1));

        // Freeing a slot admits new connections again.
        drop(s2);
        drop(r2);
        std::thread::sleep(Duration::from_millis(50));
        let (mut s4, mut r4) = connect(&server);
        assert_eq!(
            roundtrip(&mut s4, &mut r4, r#"{"op":"stats"}"#).get("ok"),
            Some(&Json::Bool(true))
        );
    }

    #[test]
    fn idle_connection_is_reaped_and_counted() {
        let (_coord, server) = start_cfg(ServerConfig {
            read_timeout: Some(Duration::from_millis(80)),
            ..Default::default()
        });
        let (_idle, mut idle_reader) = connect(&server);
        let mut buf = String::new();
        // The reaper closes us without a goodbye; read_line sees EOF.
        assert_eq!(idle_reader.read_line(&mut buf).unwrap_or(0), 0);

        let (mut s, mut r) = connect(&server);
        let resp = roundtrip(&mut s, &mut r, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("conn_timeouts").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn in_flight_dispatch_defers_the_idle_reaper() {
        let (_coord, server) = start_cfg(ServerConfig {
            dispatch_threads: 1,
            read_timeout: Some(Duration::from_millis(75)),
            ..Default::default()
        });
        let (mut stream, mut reader) = connect(&server);

        // 400 reshards through a single dispatch thread (each one spawns
        // or retires a shard worker and rebuilds replicas) take well past
        // the read timeout, and `last_activity` only moves on reads: the
        // whole batch lands in one read at t=0. Without the in-flight
        // guard the reaper cuts the connection mid-pipeline and the
        // responses below never arrive.
        let mut batch = String::new();
        for i in 0..400 {
            batch.push_str(&format!(r#"{{"op":"reshard","shards":{}}}"#, 3 - i % 2));
            batch.push('\n');
        }
        batch.push_str("{\"op\":\"stats\"}\n");
        stream.write_all(batch.as_bytes()).unwrap();

        for i in 0..400 {
            let mut line = String::new();
            let n = reader.read_line(&mut line).unwrap();
            assert!(n > 0, "connection reaped mid-pipeline at response {i}");
            let resp = Json::parse(&line).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "reshard {i} failed");
        }
        // The in-band stats response was serialized while the connection
        // still had work owed, so a mid-flight reap would show up here;
        // a reap *after* the pipeline drains is legitimate and does not.
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "stats response missing");
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("conn_timeouts").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn slow_reader_overflowing_the_write_buffer_is_cut_off() {
        let (_coord, server) = start_cfg(ServerConfig {
            max_wbuf_bytes: 256 * 1024,
            ..Default::default()
        });
        let (mut stream, mut reader) = connect(&server);

        // A retained-history policy keeps the raw executions, so the
        // snapshot response scales with what we train: ~64 executions of
        // 500 samples each make every snapshot a few hundred KB.
        let resp = roundtrip(
            &mut stream,
            &mut reader,
            r#"{"op":"configure","task":"fat","policy":"witt-lr"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let mut train = String::from(r#"{"op":"train","task":"fat","history":["#);
        for i in 0..64 {
            if i > 0 {
                train.push(',');
            }
            let samples: Vec<String> =
                (0..500).map(|s| format!("{}.5", 100 + (i * 7 + s) % 900)).collect();
            train.push_str(&format!(
                r#"{{"input_mb":{},"dt":1.0,"samples":[{}]}}"#,
                100 + i,
                samples.join(",")
            ));
        }
        train.push_str("]}");
        let resp = roundtrip(&mut stream, &mut reader, &train);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "train failed: {resp}");

        // Pipeline far more snapshot bytes than the socket buffers and
        // the 256 KB write-buffer cap can hold, and read none of them:
        // the server must cut us off instead of buffering without bound.
        let mut batch = Vec::new();
        for _ in 0..256 {
            batch.extend_from_slice(b"{\"op\":\"snapshot\"}\n");
        }
        stream.write_all(&batch).unwrap();
        let mut sink = Vec::new();
        let got = stream.read_to_end(&mut sink).unwrap_or(sink.len());
        assert!(
            got < 256 * (1 << 20),
            "server kept buffering for a reader that never drained"
        );

        let (mut s, mut r) = connect(&server);
        let resp = roundtrip(&mut s, &mut r, r#"{"op":"stats"}"#);
        let overflowed = resp.get("conns_overflowed").and_then(Json::as_usize);
        assert_eq!(overflowed, Some(1), "overflow close must be counted: {resp}");
    }

    #[test]
    fn inflight_cap_sheds_with_overloaded_and_stays_open() {
        // Cap in-flight at 4, then pipeline 8 observes in one burst. The
        // parse loop sees all 8 before anything flushes, so requests
        // 5..8 are deterministically shed — each with a structured
        // `overloaded` error in its in-order reply slot — while the
        // connection survives and keeps serving.
        let (_coord, server) =
            start_cfg(ServerConfig { max_inflight: 4, ..Default::default() });
        let (mut stream, mut reader) = connect(&server);
        let mut batch = String::new();
        for i in 0..8 {
            batch.push_str(&format!(
                r#"{{"op":"observe","task":"s{i}","execution":{{"input_mb":10,"dt":1.0,"samples":[1.0,2.0]}}}}"#
            ));
            batch.push('\n');
        }
        stream.write_all(batch.as_bytes()).unwrap();
        let mut ok = 0;
        let mut shed = 0;
        for i in 0..8 {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "response {i} missing");
            let resp = Json::parse(&line).unwrap();
            if resp.get("ok") == Some(&Json::Bool(true)) {
                ok += 1;
            } else {
                assert_eq!(err_code(&resp), Some("overloaded"), "{resp}");
                shed += 1;
            }
        }
        assert_eq!((ok, shed), (4, 4));
        // Pressure gone: the same connection is admitted again, and the
        // shed counter is visible in stats.
        let resp = roundtrip(&mut stream, &mut reader, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("shed").and_then(Json::as_usize), Some(4));
        assert_eq!(resp.get("observations").and_then(Json::as_usize), Some(4));
        assert!(resp.get("queue_depth_max").and_then(Json::as_usize).unwrap_or(0) >= 1);
    }

    #[test]
    fn full_dispatch_queue_sheds_instead_of_growing() {
        // One dispatch thread, queue capped at 1: a burst of slow
        // reshards fills the queue instantly and most of the burst is
        // shed. The overload response arrives without waiting for the
        // queue (it only waits for in-order flushing), and no request is
        // silently dropped — every one gets exactly one reply.
        let (_coord, server) = start_cfg(ServerConfig {
            dispatch_threads: 1,
            max_queue_depth: 1,
            ..Default::default()
        });
        let (mut stream, mut reader) = connect(&server);
        let mut batch = String::new();
        for i in 0..32 {
            batch.push_str(&format!(r#"{{"op":"reshard","shards":{}}}"#, 3 - i % 2));
            batch.push('\n');
        }
        stream.write_all(batch.as_bytes()).unwrap();
        let mut ok = 0;
        let mut shed = 0;
        for i in 0..32 {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "response {i} missing");
            let resp = Json::parse(&line).unwrap();
            if resp.get("ok") == Some(&Json::Bool(true)) {
                ok += 1;
            } else {
                assert_eq!(err_code(&resp), Some("overloaded"), "{resp}");
                shed += 1;
            }
        }
        assert_eq!(ok + shed, 32);
        assert!(ok >= 1, "the first request is always admitted");
        assert!(shed >= 1, "a 32-deep burst through a 1-slot queue must shed");
        let resp = roundtrip(&mut stream, &mut reader, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("shed").and_then(Json::as_usize), Some(shed));
        assert_eq!(resp.get("queue_depth_max").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn stop_drains_queued_work_instead_of_discarding_it() {
        // Pipeline slow work through one dispatch thread, then stop()
        // while most of it is still queued. The graceful drain must
        // finish and flush every admitted request's response — the old
        // behavior (clear the queue) dropped them on the floor.
        let (_coord, mut server) = start_cfg(ServerConfig {
            dispatch_threads: 1,
            ..Default::default()
        });
        let (mut stream, mut reader) = connect(&server);
        let mut batch = String::new();
        for i in 0..20 {
            batch.push_str(&format!(r#"{{"op":"reshard","shards":{}}}"#, 3 - i % 2));
            batch.push('\n');
        }
        stream.write_all(batch.as_bytes()).unwrap();
        // Give the loop a moment to admit the burst, then drain.
        std::thread::sleep(Duration::from_millis(20));
        server.stop();
        assert_eq!(server.counters().drains.load(Ordering::Relaxed), 1);
        // All 20 responses were flushed before the sockets went down.
        for i in 0..20 {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "response {i} lost in stop()");
            let resp = Json::parse(&line).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "reshard {i}: {resp}");
        }
        let mut line = String::new();
        assert_eq!(reader.read_line(&mut line).unwrap_or(0), 0, "then EOF");
    }

    #[test]
    fn stop_joins_with_a_live_connection() {
        let (_coord, mut server) = start();
        let (mut s, mut r) = connect(&server);
        let resp = roundtrip(&mut s, &mut r, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        server.stop(); // must not hang with `s` still open and idle
        let mut line = String::new();
        assert_eq!(r.read_line(&mut line).unwrap_or(0), 0);
    }

    #[test]
    fn concurrent_connections_share_the_coordinator() {
        let (_coord, server) = start();
        {
            let (mut s, mut r) = connect(&server);
            roundtrip(&mut s, &mut r, &train_req("shared"));
        }
        let addr = server.addr();
        let mut handles = Vec::new();
        for _ in 0..16 {
            handles.push(thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                for _ in 0..5 {
                    let resp = roundtrip(
                        &mut stream,
                        &mut reader,
                        r#"{"op":"plan","task":"shared","input_mb":50}"#,
                    );
                    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (mut s, mut r) = connect(&server);
        let resp = roundtrip(&mut s, &mut r, r#"{"op":"stats"}"#);
        assert_eq!(
            resp.get("requests").and_then(Json::as_usize),
            Some(80),
            "16 clients x 5 plans (train and stats are not counted)"
        );
    }

    #[test]
    fn negotiation_is_conservative_over_the_wire() {
        let (_coord, server) = start();
        let (mut stream, mut reader) = connect(&server);
        // A v1-only hello stays on v1 even though the server can do v2.
        let resp = roundtrip(
            &mut stream,
            &mut reader,
            r#"{"op":"hello","min_version":1,"max_version":1}"#,
        );
        assert_eq!(resp.get("version").and_then(Json::as_usize), Some(WIRE_VERSION));
        // Still JSON after.
        let resp = roundtrip(&mut stream, &mut reader, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }
}
