//! Threaded coordinator service: an *elastic* pool of worker shards, each
//! with a dynamic batcher + request router over its own shard-local
//! `ModelStore`, plus a warm-standby replica of its ring neighbors' tasks.
//!
//! `CoordinatorConfig::shards` sets the initial pool width (default 1,
//! which preserves the original single-worker behavior exactly); the pool
//! can then be grown and shrunk at runtime via [`Client::add_shard`] /
//! [`Client::remove_shard`]. Each worker thread owns its own `ModelStore`
//! and numeric backend — the backend is built *inside* the worker thread
//! because PJRT handles are thread-affine — and runs an independent
//! dynamic batcher: plan requests coalesce per shard, so a flush costs
//! one batched predict regardless of the number of clients on that shard.
//!
//! Routing: `Train`, `Observe`, and `Plan` go to the task's owner on a
//! consistent-hash ring ([`super::ring::HashRing`]), so a task's models
//! and all its plan traffic live on exactly one shard — an observed
//! execution is visible to the task's very next plan — and changing the
//! shard count moves only ~1/N of the tasks (their accumulators are
//! handed off through the same worker channels as regular requests).
//! `Failure` carries no task and is distributed round-robin over the
//! sorted live shard ids. `Stats` fans out to every shard and the
//! per-shard counters/latency windows are merged into one aggregate
//! `ServiceStats`.
//!
//! Replication: every state-changing task message (`Train`, `Observe`,
//! `Configure`) is *dual-sent* — a replica twin goes to the task's
//! standby shard (the next distinct shard clockwise on the ring) before
//! the primary copy goes to the owner, both under one read guard of the
//! pool lock. mpsc channels are FIFO per receiver and admin operations
//! (crash, restore, reshard) run under the pool *write* lock, so by the
//! time an admin drains a shard it has already enqueued — and therefore
//! observes — the twin of every acked update. Killing one worker
//! ([`Client::crash_shard`]) therefore loses nothing that a restore from
//! the standbys ([`Client::restore_shard`]) cannot replay bit-identically
//! (per-task fold order is preserved as long as each task has a single
//! writer, which is how workflow engines submit observations).
//!
//! Deadlock freedom: workers never take the pool lock and never block on
//! replies (every reply channel is a `sync_channel(1)` whose buffered
//! send succeeds even if the requester has vanished), so an admin
//! operation holding the write lock always terminates; plan requests
//! enqueued before an admin message are flushed from pre-change state
//! before the worker acts on it, so there is no window that serves a
//! regressed plan.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::coordinator::protocol::{
    negotiate_version, Dedup, ErrorCode, ObserveAck, Request, Response, ServerInfo, StatsSummary,
    WireError, OPS,
};
use crate::coordinator::ring::HashRing;
use crate::coordinator::snapshot::{self, TaskState};
use crate::coordinator::{
    BackendSpec, ModelStore, PlanOutcome, PlanScratch, PredictorPolicy, RetryOutcome,
};
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::json::Json;
use crate::util::sync::{lock_recover, read_recover, write_recover};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Segments per task model.
    pub k: usize,
    pub capacity_gb: f64,
    /// Flush the batcher at this many pending plan requests.
    pub batch_max: usize,
    /// ... or when the oldest pending request is this old.
    pub batch_delay: Duration,
    /// Initial worker shards. Each shard owns its own model store,
    /// backend, and batcher; tasks are routed by a consistent-hash ring.
    /// `1` reproduces the original single-worker coordinator. The pool
    /// can be resized at runtime (`Client::add_shard` / `remove_shard`),
    /// so this is the startup width, not a cap (see [`MAX_SHARDS`]).
    pub shards: usize,
    /// Predictor policy for tasks with no explicit `configure` binding;
    /// pinned per task the first time it is trained or observed.
    pub default_policy: PredictorPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            k: 4,
            capacity_gb: 128.0,
            batch_max: 64,
            batch_delay: Duration::from_millis(1),
            shards: 1,
            default_policy: PredictorPolicy::KsPlus,
        }
    }
}

/// Upper bound on live shards, enforced by `start` and `add_shard`. Each
/// shard is an OS thread with its own model store; 64 is far above any
/// sensible deployment and exists so a buggy admin loop cannot fork-bomb
/// the process.
pub const MAX_SHARDS: usize = 64;

/// How many recent plan latencies each shard retains. A long-running
/// service must not grow a sample per request forever; percentiles are
/// computed over this sliding window of the most recent requests.
pub const LATENCY_WINDOW: usize = 4096;

/// Bounded ring buffer of the most recent latency samples. Replaces an
/// unbounded `Vec<f64>` that grew by one `f64` per request for the
/// lifetime of the service.
#[derive(Debug, Clone)]
pub struct LatencyWindow {
    buf: Vec<f64>,
    cap: usize,
    /// Next overwrite position once the buffer is full.
    next: usize,
    /// Samples ever recorded (not capped).
    total: u64,
}

impl Default for LatencyWindow {
    fn default() -> Self {
        LatencyWindow::with_capacity(LATENCY_WINDOW)
    }
}

impl LatencyWindow {
    pub fn with_capacity(cap: usize) -> LatencyWindow {
        assert!(cap > 0, "latency window needs capacity");
        LatencyWindow { buf: Vec::new(), cap, next: 0, total: 0 }
    }

    pub fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % self.cap;
        self.total += 1;
    }

    /// Samples currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Samples recorded over the service lifetime.
    pub fn total_recorded(&self) -> u64 {
        self.total
    }

    /// Percentile over the retained window.
    pub fn percentile(&self, p: f64) -> f64 {
        crate::util::stats::percentile(&self.buf, p)
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.buf
    }

    /// Retained samples in arrival order (oldest first). The ring stores
    /// samples in overwrite order once wrapped; this re-linearizes.
    pub fn chronological(&self) -> Vec<f64> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut v = Vec::with_capacity(self.buf.len());
            v.extend_from_slice(&self.buf[self.next..]);
            v.extend_from_slice(&self.buf[..self.next]);
            v
        }
    }

    /// Absorb another window. The merged window keeps *every* retained
    /// sample from both sides (capacity grows as needed), so aggregating
    /// N shards never silently drops samples any one shard retained, and
    /// percentiles over the merge are exact over the union.
    pub fn merge(&mut self, other: &LatencyWindow) {
        let mut all = self.chronological();
        all.extend(other.chronological());
        let cap = self.cap.max(all.len()).max(1);
        let next = all.len() % cap;
        let total = self.total + other.total;
        *self = LatencyWindow { buf: all, cap, next, total };
    }
}

/// Service-side counters, exposed via `Client::stats`. For a sharded
/// coordinator this is either one shard's view (`Client::shard_stats`) or
/// the merge across all shards (`Client::stats`).
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    pub failures_handled: u64,
    pub tasks_trained: u64,
    /// Single executions folded in via the incremental `Observe` path.
    pub observations: u64,
    /// Plans served by the untrained flat default (counted whenever a
    /// `PlanOutcome` carries a `fallback_reason`). Before this counter,
    /// silent fallbacks were indistinguishable from real predictions in
    /// every metric.
    pub fallbacks: u64,
    /// Connections the wire server refused because the configured
    /// max-connections limit was reached. Workers leave this at 0; the
    /// server folds its own counter in before reporting.
    pub conns_refused: u64,
    /// Server connections closed because the peer went idle past the
    /// configured read timeout. Workers leave this at 0 as well.
    pub conn_timeouts: u64,
    /// Recent plan-request latencies, microseconds (enqueue -> response
    /// send), bounded to the last `LATENCY_WINDOW` requests per shard.
    pub latencies_us: LatencyWindow,
}

impl ServiceStats {
    /// Fold another shard's counters and latency window into this one.
    /// After merging, `mean_batch_size` and `latency_percentile_us` are
    /// computed over the union (summed counters, concatenated windows).
    pub fn merge(&mut self, other: &ServiceStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.failures_handled += other.failures_handled;
        self.tasks_trained += other.tasks_trained;
        self.observations += other.observations;
        self.fallbacks += other.fallbacks;
        self.conns_refused += other.conns_refused;
        self.conn_timeouts += other.conn_timeouts;
        self.latencies_us.merge(&other.latencies_us);
    }

    /// Aggregate view over a set of per-shard stats.
    pub fn merged(parts: &[ServiceStats]) -> ServiceStats {
        let mut out = ServiceStats::default();
        for p in parts {
            out.merge(p);
        }
        out
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        self.latencies_us.percentile(p)
    }
}

enum Msg {
    Configure {
        /// `None` sets the shard's default policy for unbound tasks
        /// (primary *and* replica stores, so a restored task keeps it).
        task: Option<String>,
        policy: PredictorPolicy,
        done: mpsc::SyncSender<()>,
    },
    Train {
        task: String,
        history: Vec<Execution>,
        done: mpsc::SyncSender<()>,
    },
    Observe {
        task: String,
        execution: Execution,
        /// Replies with the task's total observation count and the
        /// policy the execution was folded under.
        done: mpsc::SyncSender<(u64, &'static str)>,
    },
    Plan {
        task: String,
        input_mb: f64,
        enqueued: Instant,
        resp: mpsc::SyncSender<PlanOutcome>,
    },
    Failure {
        /// Route the retry through this task's bound policy; a task-less
        /// report uses the KS+ strategy.
        task: Option<String>,
        prev: StepPlan,
        fail_time: f64,
        resp: mpsc::SyncSender<RetryOutcome>,
    },
    Stats {
        resp: mpsc::SyncSender<ServiceStats>,
    },
    /// Replica twin of `Observe`: fold into the standby store. Fire and
    /// forget — the client already blocks on the primary's ack, and FIFO
    /// ordering guarantees the twin is enqueued by then.
    ReplObserve { task: String, execution: Execution },
    /// Replica twin of `Train`.
    ReplTrain { task: String, history: Vec<Execution> },
    /// Replica twin of a per-task `Configure`.
    ReplConfigure { task: String, policy: PredictorPolicy },
    /// Resharding handoff: export-and-remove every primary task that the
    /// given ring routes to a shard other than `me`.
    TakeTasks {
        ring: HashRing,
        me: usize,
        resp: mpsc::SyncSender<Vec<TaskState>>,
    },
    /// Export the primary store in full (snapshotting, replica rebuild).
    DumpPrimary {
        resp: mpsc::SyncSender<(PredictorPolicy, Vec<TaskState>)>,
    },
    /// Export the replica entries that the given ring routes to `owner` —
    /// the recovery source after `owner` crashed.
    DumpReplicaOwned {
        ring: HashRing,
        owner: usize,
        resp: mpsc::SyncSender<Vec<TaskState>>,
    },
    /// Import task states into the primary (resharding/restore) or the
    /// replica (replica rebuild) store.
    InjectTasks {
        tasks: Vec<TaskState>,
        into_replica: bool,
        done: mpsc::SyncSender<Result<(), String>>,
    },
    /// Drop the replica store (rebuilt from primaries afterwards).
    ClearReplica { done: mpsc::SyncSender<()> },
    /// Chaos hook: amnesia-crash this worker — wipe the primary and
    /// replica stores as a kill would, but keep the thread, its channel,
    /// its default policy (redeployed from static config in a real
    /// restart), and its counters (so lost-observe accounting stays
    /// exact across the crash).
    Crash { done: mpsc::SyncSender<()> },
    Shutdown,
}

/// One live worker: its request channel and join handle.
struct Shard {
    tx: mpsc::Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Shared, lock-guarded pool state: the live shards and the routing
/// ring over their ids. Request routing takes a read guard; membership
/// changes (add/remove/crash/restore/restore-snapshot) take the write
/// guard for their full duration, so routing never observes a half-moved
/// task.
struct Pool {
    cfg: CoordinatorConfig,
    spec: BackendSpec,
    shards: BTreeMap<usize, Shard>,
    ring: HashRing,
    /// Next shard id to assign; monotone, never reused, so a ring
    /// snapshot inside an in-flight message can never alias a new shard.
    next_id: usize,
    /// Counters inherited from removed shards, folded into the
    /// aggregate `Client::stats` so retiring a worker never makes the
    /// service-lifetime totals go backwards.
    retired: ServiceStats,
}

impl Pool {
    fn tx(&self, id: usize) -> &mpsc::Sender<Msg> {
        &self.shards[&id].tx
    }
}

/// Handle to a running coordinator pool; cheap to clone via `client()`.
/// Dropping it shuts down and joins every worker.
pub struct Coordinator {
    pool: Arc<RwLock<Pool>>,
    /// Round-robin cursor for task-less messages (`Failure`).
    rr: Arc<AtomicUsize>,
    /// Exactly-once cache for retried mutating requests (see
    /// [`DedupTable`]). Shared by every client of this coordinator, so a
    /// retry landing on a different connection still deduplicates.
    dedup: Arc<Mutex<DedupTable>>,
}

/// Client endpoint (clonable, thread-safe). Routing reads the shared
/// ring, so every client observes membership changes immediately.
#[derive(Clone)]
pub struct Client {
    pool: Arc<RwLock<Pool>>,
    rr: Arc<AtomicUsize>,
    dedup: Arc<Mutex<DedupTable>>,
}

struct Pending {
    task: String,
    input_mb: f64,
    enqueued: Instant,
    resp: mpsc::SyncSender<PlanOutcome>,
}

/// Spawn one worker shard and wait for its backend to build. The backend
/// is built *inside* the worker thread because PJRT handles are
/// thread-affine, but build failures are reported back over a readiness
/// channel so the caller gets an `Err` here instead of clients later
/// dying on a dead channel ("coordinator gone").
fn spawn_shard(cfg: &CoordinatorConfig, spec: &BackendSpec, id: usize) -> anyhow::Result<Shard> {
    let (tx, rx) = mpsc::channel::<Msg>();
    let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<(), String>>(1);
    let shard_cfg = cfg.clone();
    let shard_spec = spec.clone();
    let handle = std::thread::Builder::new()
        .name(format!("ksplus-coordinator-{id}"))
        .spawn(move || {
            let backend = match shard_spec.build() {
                Ok(b) => {
                    let _ = ready_tx.send(Ok(()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            worker(shard_cfg, backend, rx)
        })
        .with_context(|| format!("spawn coordinator shard {id}"))?;
    let built = ready_rx
        .recv()
        .unwrap_or_else(|_| Err("worker died before reporting readiness".into()));
    if let Err(msg) = built {
        let _ = handle.join();
        return Err(anyhow::anyhow!("coordinator shard {id}: {msg}"));
    }
    Ok(Shard { tx, handle: Some(handle) })
}

impl Coordinator {
    /// Spawn `cfg.shards` workers (ids `0..shards` on the ring).
    pub fn start(cfg: CoordinatorConfig, spec: BackendSpec) -> anyhow::Result<Coordinator> {
        anyhow::ensure!(cfg.shards >= 1, "coordinator needs at least one shard");
        anyhow::ensure!(
            cfg.shards <= MAX_SHARDS,
            "coordinator supports at most {MAX_SHARDS} shards"
        );
        let mut shards = BTreeMap::new();
        for i in 0..cfg.shards {
            match spawn_shard(&cfg, &spec, i) {
                Ok(s) => {
                    shards.insert(i, s);
                }
                Err(e) => {
                    // Wind down whatever did start before surfacing it.
                    for (_, mut s) in shards {
                        let _ = s.tx.send(Msg::Shutdown);
                        if let Some(h) = s.handle.take() {
                            let _ = h.join();
                        }
                    }
                    return Err(e);
                }
            }
        }
        let ring = HashRing::new(0..cfg.shards);
        let next_id = cfg.shards;
        Ok(Coordinator {
            pool: Arc::new(RwLock::new(Pool {
                cfg,
                spec,
                shards,
                ring,
                next_id,
                retired: ServiceStats::default(),
            })),
            rr: Arc::new(AtomicUsize::new(0)),
            dedup: Arc::new(Mutex::new(DedupTable::default())),
        })
    }

    pub fn client(&self) -> Client {
        Client { pool: self.pool.clone(), rr: self.rr.clone(), dedup: self.dedup.clone() }
    }

    /// Live shard count (changes under resharding).
    pub fn shards(&self) -> usize {
        read_recover(&self.pool).ring.len()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let mut handles = Vec::new();
        {
            let mut pool = write_recover(&self.pool);
            let ids: Vec<usize> = pool.shards.keys().copied().collect();
            for id in ids {
                if let Some(mut s) = pool.shards.remove(&id) {
                    let _ = s.tx.send(Msg::Shutdown);
                    if let Some(h) = s.handle.take() {
                        handles.push(h);
                    }
                }
            }
        }
        // Join outside the lock so a worker mid-reply can't deadlock us.
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Client {
    fn read(&self) -> std::sync::RwLockReadGuard<'_, Pool> {
        // Poison-recovering: a panicking dispatch thread must not wedge
        // every other connection's routing (see `util::sync`).
        read_recover(&self.pool)
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, Pool> {
        write_recover(&self.pool)
    }

    /// Live shard count.
    pub fn shards(&self) -> usize {
        self.read().ring.len()
    }

    /// Sorted live shard ids.
    pub fn shard_ids(&self) -> Vec<usize> {
        self.read().ring.shard_ids().to_vec()
    }

    /// The shard currently owning a task (for tests and diagnostics).
    pub fn owner_of(&self, task: &str) -> usize {
        self.read().ring.route(task)
    }

    /// Bind a task to a predictor policy — or, with `task: None`, set
    /// every shard's default policy for tasks not yet pinned to one.
    /// Blocks until the binding is visible (all shards, for a default).
    /// Per-task bindings are replicated to the task's standby shard.
    pub fn configure(&self, task: Option<&str>, policy: PredictorPolicy) {
        match task {
            Some(t) => {
                let (done_tx, done_rx) = mpsc::sync_channel(1);
                {
                    let pool = self.read();
                    let (primary, standby) = pool.ring.route2(t);
                    if let Some(sb) = standby {
                        pool.tx(sb)
                            .send(Msg::ReplConfigure { task: t.to_string(), policy })
                            .expect("coordinator gone");
                    }
                    pool.tx(primary)
                        .send(Msg::Configure {
                            task: Some(t.to_string()),
                            policy,
                            done: done_tx,
                        })
                        .expect("coordinator gone");
                }
                let _ = done_rx.recv();
            }
            None => {
                // Fan out to every shard, pipelined like `shard_stats`.
                let pending: Vec<mpsc::Receiver<()>> = {
                    let pool = self.read();
                    pool.shards
                        .values()
                        .map(|s| {
                            let (done_tx, done_rx) = mpsc::sync_channel(1);
                            s.tx.send(Msg::Configure { task: None, policy, done: done_tx })
                                .expect("coordinator gone");
                            done_rx
                        })
                        .collect()
                };
                for rx in pending {
                    let _ = rx.recv();
                }
            }
        }
    }

    /// Fit (or refit) the task's models under its bound policy; blocks
    /// until stored. The same history is replicated to the standby.
    pub fn train(&self, task: &str, history: Vec<Execution>) {
        let (done_tx, done_rx) = mpsc::sync_channel(1);
        {
            let pool = self.read();
            let (primary, standby) = pool.ring.route2(task);
            if let Some(sb) = standby {
                pool.tx(sb)
                    .send(Msg::ReplTrain { task: task.to_string(), history: history.clone() })
                    .expect("coordinator gone");
            }
            pool.tx(primary)
                .send(Msg::Train { task: task.to_string(), history, done: done_tx })
                .expect("coordinator gone");
        }
        let _ = done_rx.recv();
    }

    /// Fold one finished execution into the task's models — the O(k)
    /// incremental update on the shard that owns the task (same ring
    /// route as `train`/`plan`, so the updated models serve the task's
    /// very next plan request). Returns the task's total observation
    /// count; blocks until the model swap is visible.
    pub fn observe(&self, task: &str, execution: Execution) -> u64 {
        self.observe_detailed(task, execution).0
    }

    /// `observe` plus provenance: (total observation count, name of the
    /// policy the execution was folded under). The replica twin is sent
    /// *before* the primary under one routing-snapshot guard: once the
    /// primary's ack arrives, the standby's copy is already enqueued, so
    /// a crash after the ack can always be replayed.
    pub fn observe_detailed(&self, task: &str, execution: Execution) -> (u64, &'static str) {
        let (done_tx, done_rx) = mpsc::sync_channel(1);
        {
            let pool = self.read();
            let (primary, standby) = pool.ring.route2(task);
            if let Some(sb) = standby {
                pool.tx(sb)
                    .send(Msg::ReplObserve {
                        task: task.to_string(),
                        execution: execution.clone(),
                    })
                    .expect("coordinator gone");
            }
            pool.tx(primary)
                .send(Msg::Observe { task: task.to_string(), execution, done: done_tx })
                .expect("coordinator gone");
        }
        done_rx.recv().expect("coordinator dropped request")
    }

    /// Request an allocation plan; blocks until the shard's batcher
    /// flushes.
    pub fn plan(&self, task: &str, input_mb: f64) -> StepPlan {
        self.plan_detailed(task, input_mb).plan
    }

    /// `plan` plus provenance: which policy served it, its model
    /// version, and whether it was an untrained fallback.
    pub fn plan_detailed(&self, task: &str, input_mb: f64) -> PlanOutcome {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        {
            let pool = self.read();
            pool.tx(pool.ring.route(task))
                .send(Msg::Plan {
                    task: task.to_string(),
                    input_mb,
                    enqueued: Instant::now(),
                    resp: resp_tx,
                })
                .expect("coordinator gone");
        }
        resp_rx.recv().expect("coordinator dropped request")
    }

    /// Report an OOM; returns the rescaled retry plan (KS+ strategy).
    /// Task-less and stateless, so any shard serves it.
    pub fn report_failure(&self, prev: &StepPlan, fail_time: f64) -> StepPlan {
        self.report_failure_for(None, prev, fail_time).plan
    }

    /// Report an OOM for a specific task: the retry runs that task's
    /// bound policy's strategy on its owning shard. A task-less report
    /// round-robins over the sorted live shard ids and uses the KS+
    /// strategy.
    pub fn report_failure_for(
        &self,
        task: Option<&str>,
        prev: &StepPlan,
        fail_time: f64,
    ) -> RetryOutcome {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        {
            let pool = self.read();
            let id = match task {
                Some(t) => pool.ring.route(t),
                None => {
                    let ids = pool.ring.shard_ids();
                    ids[self.rr.fetch_add(1, Ordering::Relaxed) % ids.len()]
                }
            };
            pool.tx(id)
                .send(Msg::Failure {
                    task: task.map(str::to_string),
                    prev: prev.clone(),
                    fail_time,
                    resp: resp_tx,
                })
                .expect("coordinator gone");
        }
        resp_rx.recv().expect("coordinator dropped request")
    }

    /// Aggregate counters across every live shard, plus the counters
    /// inherited from shards removed by resharding.
    pub fn stats(&self) -> ServiceStats {
        let (mut out, pending) = {
            let pool = self.read();
            let pending: Vec<mpsc::Receiver<ServiceStats>> = pool
                .shards
                .values()
                .map(|s| {
                    let (resp_tx, resp_rx) = mpsc::sync_channel(1);
                    s.tx.send(Msg::Stats { resp: resp_tx }).expect("coordinator gone");
                    resp_rx
                })
                .collect();
            (pool.retired.clone(), pending)
        };
        for rx in pending {
            out.merge(&rx.recv().expect("coordinator dropped request"));
        }
        out
    }

    /// Per-shard counters, in sorted shard-id order. The fan-out is
    /// pipelined — every shard is queried before any reply is awaited —
    /// so the aggregate costs the slowest shard's queue delay, not the
    /// sum.
    pub fn shard_stats(&self) -> Vec<ServiceStats> {
        let pending: Vec<mpsc::Receiver<ServiceStats>> = {
            let pool = self.read();
            pool.shards
                .values()
                .map(|s| {
                    let (resp_tx, resp_rx) = mpsc::sync_channel(1);
                    s.tx.send(Msg::Stats { resp: resp_tx }).expect("coordinator gone");
                    resp_rx
                })
                .collect()
        };
        pending
            .into_iter()
            .map(|rx| rx.recv().expect("coordinator dropped request"))
            .collect()
    }

    // ----- admin: elastic resharding -------------------------------------

    /// Grow the pool by one shard. Spawns a fresh worker, hands it the
    /// ~1/N of tasks the new ring assigns to it (the ring guarantees
    /// every moved task moves *to* the new shard), and rebuilds the
    /// standby replicas for the new topology. Returns the new shard id.
    pub fn add_shard(&self) -> anyhow::Result<usize> {
        let mut pool = self.write();
        anyhow::ensure!(
            pool.shards.len() < MAX_SHARDS,
            "coordinator already at the {MAX_SHARDS}-shard limit"
        );
        let id = pool.next_id;
        let shard = spawn_shard(&pool.cfg, &pool.spec, id)?;
        pool.next_id += 1;
        let mut new_ring = pool.ring.clone();
        new_ring.add(id);
        // Drain the moving tasks from the old owners *before* the ring
        // swap: the drain runs under the write lock, so no request can
        // route against the half-moved state.
        let moving = take_tasks(&pool, &new_ring);
        pool.shards.insert(id, shard);
        pool.ring = new_ring;
        inject(&pool, id, moving, false)?;
        rebuild_replicas(&pool)?;
        Ok(id)
    }

    /// Shrink the pool: drain every task off the shard (each moves to
    /// its new ring owner — only the victim's tasks move), retire the
    /// worker, and rebuild replicas for the new topology.
    pub fn remove_shard(&self, id: usize) -> anyhow::Result<()> {
        let mut pool = self.write();
        anyhow::ensure!(pool.ring.contains(id), "no such shard: {id}");
        anyhow::ensure!(pool.ring.len() > 1, "cannot remove the last shard");
        let mut new_ring = pool.ring.clone();
        new_ring.remove(id);
        // With `id` absent from the ring every task routes elsewhere, so
        // this drains the victim completely.
        let (tx, rx) = mpsc::sync_channel(1);
        pool.tx(id)
            .send(Msg::TakeTasks { ring: new_ring.clone(), me: id, resp: tx })
            .expect("coordinator gone");
        let moving = rx.recv().expect("coordinator dropped request");
        pool.ring = new_ring;
        let mut by_owner: BTreeMap<usize, Vec<TaskState>> = BTreeMap::new();
        for st in moving {
            by_owner.entry(pool.ring.route(&st.task)).or_default().push(st);
        }
        for (owner, tasks) in by_owner {
            inject(&pool, owner, tasks, false)?;
        }
        // Inherit the victim's counters before retiring it, so the
        // aggregate stats never go backwards when the pool shrinks.
        let (stx, srx) = mpsc::sync_channel(1);
        pool.tx(id).send(Msg::Stats { resp: stx }).expect("coordinator gone");
        let victim_stats = srx.recv().expect("coordinator dropped request");
        pool.retired.merge(&victim_stats);
        if let Some(mut shard) = pool.shards.remove(&id) {
            let _ = shard.tx.send(Msg::Shutdown);
            if let Some(h) = shard.handle.take() {
                let _ = h.join();
            }
        }
        rebuild_replicas(&pool)?;
        Ok(())
    }

    /// Resize to exactly `target` live shards (adding fresh ids or
    /// removing the highest ones). Returns the resulting shard ids.
    pub fn set_shards(&self, target: usize) -> anyhow::Result<Vec<usize>> {
        anyhow::ensure!(
            (1..=MAX_SHARDS).contains(&target),
            "shard count must be in 1..={MAX_SHARDS}"
        );
        loop {
            let (n, highest) = {
                let pool = self.read();
                (pool.ring.len(), pool.ring.shard_ids().last().copied())
            };
            if n == target {
                break;
            }
            if n < target {
                self.add_shard()?;
            } else {
                self.remove_shard(highest.expect("live pool has a highest shard"))?;
            }
        }
        Ok(self.shard_ids())
    }

    // ----- admin: chaos + recovery ---------------------------------------

    /// Chaos hook: amnesia-crash one worker, wiping its primary and
    /// replica stores (counters and default policy survive, like a
    /// restarted process redeployed from static config). Training owned
    /// by the shard is lost until [`Client::restore_shard`] replays it
    /// from the standbys.
    pub fn crash_shard(&self, id: usize) -> anyhow::Result<()> {
        let pool = self.write();
        anyhow::ensure!(pool.ring.contains(id), "no such shard: {id}");
        let (tx, rx) = mpsc::sync_channel(1);
        pool.tx(id).send(Msg::Crash { done: tx }).expect("coordinator gone");
        rx.recv().expect("coordinator dropped request");
        Ok(())
    }

    /// Recover a crashed shard from the warm standbys: every other shard
    /// contributes the replica entries the ring assigns to `id`, the
    /// merged set is injected back as `id`'s primary state, and all
    /// replicas are rebuilt. Returns the number of tasks restored.
    pub fn restore_shard(&self, id: usize) -> anyhow::Result<usize> {
        let pool = self.write();
        anyhow::ensure!(pool.ring.contains(id), "no such shard: {id}");
        restore_locked(&pool, id)
    }

    /// Crash one shard and immediately restore it from its standbys,
    /// under a single write guard — the chaos test's kill-and-restart
    /// primitive. Requires a second shard to hold the standby copies.
    pub fn crash_restart_shard(&self, id: usize) -> anyhow::Result<usize> {
        let pool = self.write();
        anyhow::ensure!(pool.ring.contains(id), "no such shard: {id}");
        anyhow::ensure!(
            pool.ring.len() >= 2,
            "crash-restarting the only shard has no standby to restore from"
        );
        let (tx, rx) = mpsc::sync_channel(1);
        pool.tx(id).send(Msg::Crash { done: tx }).expect("coordinator gone");
        rx.recv().expect("coordinator dropped request");
        restore_locked(&pool, id)
    }

    // ----- admin: persistence --------------------------------------------

    /// Export the full trained state of the pool as a versioned snapshot
    /// document ([`snapshot::SNAPSHOT_SCHEMA`]): store settings, the
    /// default policy, and every task's accumulators/history, sorted by
    /// task name so equal states serialize to equal documents.
    pub fn snapshot_json(&self) -> Json {
        let (k, capacity_gb, pending) = {
            let pool = self.read();
            let pending: Vec<mpsc::Receiver<(PredictorPolicy, Vec<TaskState>)>> = pool
                .shards
                .values()
                .map(|s| {
                    let (tx, rx) = mpsc::sync_channel(1);
                    s.tx.send(Msg::DumpPrimary { resp: tx }).expect("coordinator gone");
                    rx
                })
                .collect();
            (pool.cfg.k, pool.cfg.capacity_gb, pending)
        };
        let mut default = PredictorPolicy::KsPlus;
        let mut tasks: Vec<TaskState> = Vec::new();
        for (i, rx) in pending.into_iter().enumerate() {
            let (dp, mut ts) = rx.recv().expect("coordinator dropped request");
            if i == 0 {
                default = dp;
            }
            tasks.append(&mut ts);
        }
        tasks.sort_by(|a, b| a.task.cmp(&b.task));
        snapshot::snapshot_to_json(k, capacity_gb, default, &tasks)
    }

    /// Load a snapshot document into the running pool: strict `k` /
    /// `capacity_gb` match, then each task is routed to its ring owner
    /// and imported, and replicas are rebuilt. Tasks already live and
    /// absent from the snapshot are left alone (merge semantics, same
    /// as `ModelStore::restore`). Returns the number of tasks restored.
    pub fn restore_snapshot(&self, doc: &Json) -> anyhow::Result<usize> {
        let parsed = snapshot::parse_snapshot(doc)?;
        let pool = self.write();
        anyhow::ensure!(
            parsed.k == pool.cfg.k,
            "snapshot has k={} but this coordinator runs k={}",
            parsed.k,
            pool.cfg.k
        );
        anyhow::ensure!(
            parsed.capacity_gb == pool.cfg.capacity_gb,
            "snapshot has capacity_gb={} but this coordinator runs capacity_gb={}",
            parsed.capacity_gb,
            pool.cfg.capacity_gb
        );
        let pending: Vec<mpsc::Receiver<()>> = pool
            .shards
            .values()
            .map(|s| {
                let (tx, rx) = mpsc::sync_channel(1);
                s.tx.send(Msg::Configure {
                    task: None,
                    policy: parsed.default_policy,
                    done: tx,
                })
                .expect("coordinator gone");
                rx
            })
            .collect();
        for rx in pending {
            let _ = rx.recv();
        }
        let n = parsed.tasks.len();
        let mut by_owner: BTreeMap<usize, Vec<TaskState>> = BTreeMap::new();
        for st in parsed.tasks {
            by_owner.entry(pool.ring.route(&st.task)).or_default().push(st);
        }
        for (owner, tasks) in by_owner {
            inject(&pool, owner, tasks, false)?;
        }
        rebuild_replicas(&pool)?;
        Ok(n)
    }
}

/// Pipelined `TakeTasks` fan-out: collect every primary task that
/// `new_ring` routes away from its current shard.
fn take_tasks(pool: &Pool, new_ring: &HashRing) -> Vec<TaskState> {
    let pending: Vec<mpsc::Receiver<Vec<TaskState>>> = pool
        .shards
        .iter()
        .map(|(&id, s)| {
            let (tx, rx) = mpsc::sync_channel(1);
            s.tx.send(Msg::TakeTasks { ring: new_ring.clone(), me: id, resp: tx })
                .expect("coordinator gone");
            rx
        })
        .collect();
    let mut out = Vec::new();
    for rx in pending {
        out.extend(rx.recv().expect("coordinator dropped request"));
    }
    out
}

/// Import task states into one shard's primary or replica store.
fn inject(pool: &Pool, id: usize, tasks: Vec<TaskState>, into_replica: bool) -> anyhow::Result<()> {
    if tasks.is_empty() {
        return Ok(());
    }
    let (tx, rx) = mpsc::sync_channel(1);
    pool.tx(id)
        .send(Msg::InjectTasks { tasks, into_replica, done: tx })
        .expect("coordinator gone");
    rx.recv()
        .expect("coordinator dropped request")
        .map_err(|e| anyhow::anyhow!("shard {id} import: {e}"))
}

/// Clear every replica store and re-derive each task's standby copy from
/// its primary. Called after any membership change: standby assignments
/// are a function of the ring, so they all may have shifted.
fn rebuild_replicas(pool: &Pool) -> anyhow::Result<()> {
    let pending: Vec<mpsc::Receiver<()>> = pool
        .shards
        .values()
        .map(|s| {
            let (tx, rx) = mpsc::sync_channel(1);
            s.tx.send(Msg::ClearReplica { done: tx }).expect("coordinator gone");
            rx
        })
        .collect();
    for rx in pending {
        let _ = rx.recv();
    }
    if pool.ring.len() < 2 {
        return Ok(());
    }
    let pending: Vec<mpsc::Receiver<(PredictorPolicy, Vec<TaskState>)>> = pool
        .shards
        .values()
        .map(|s| {
            let (tx, rx) = mpsc::sync_channel(1);
            s.tx.send(Msg::DumpPrimary { resp: tx }).expect("coordinator gone");
            rx
        })
        .collect();
    let mut by_standby: BTreeMap<usize, Vec<TaskState>> = BTreeMap::new();
    for rx in pending {
        let (_, tasks) = rx.recv().expect("coordinator dropped request");
        for st in tasks {
            if let Some(sb) = pool.ring.standby(&st.task) {
                by_standby.entry(sb).or_default().push(st);
            }
        }
    }
    for (sb, tasks) in by_standby {
        inject(pool, sb, tasks, true)?;
    }
    Ok(())
}

/// Restore a crashed shard's primary state from every other shard's
/// replica entries, then rebuild all replicas. Caller holds the write
/// guard.
fn restore_locked(pool: &Pool, victim: usize) -> anyhow::Result<usize> {
    let pending: Vec<mpsc::Receiver<Vec<TaskState>>> = pool
        .shards
        .iter()
        .filter(|(&id, _)| id != victim)
        .map(|(_, s)| {
            let (tx, rx) = mpsc::sync_channel(1);
            s.tx.send(Msg::DumpReplicaOwned {
                ring: pool.ring.clone(),
                owner: victim,
                resp: tx,
            })
            .expect("coordinator gone");
            rx
        })
        .collect();
    // Merge by task name: after a reshard a stale copy could linger on a
    // former standby, and the BTreeMap keeps exactly one state per task.
    let mut merged: BTreeMap<String, TaskState> = BTreeMap::new();
    for rx in pending {
        for st in rx.recv().expect("coordinator dropped request") {
            merged.insert(st.task.clone(), st);
        }
    }
    let tasks: Vec<TaskState> = merged.into_values().collect();
    let n = tasks.len();
    inject(pool, victim, tasks, false)?;
    rebuild_replicas(pool)?;
    Ok(n)
}

/// Serve every pending plan request in one batched predict. Task names
/// are *borrowed* from the pending queue and the intermediate numeric
/// buffers live in the worker's reusable `scratch`, so a steady-state
/// flush performs no per-request `String` clones (one `Vec` of borrowed
/// request tuples is still built per flush — it cannot outlive the
/// pending queue it borrows from).
fn flush(
    pending: &mut Vec<Pending>,
    store: &ModelStore,
    stats: &mut ServiceStats,
    scratch: &mut PlanScratch,
) {
    if pending.is_empty() {
        return;
    }
    let reqs: Vec<(&str, f64)> =
        pending.iter().map(|p| (p.task.as_str(), p.input_mb)).collect();
    store.plan_batch_into(&reqs, scratch);
    drop(reqs);
    stats.batches += 1;
    for (p, outcome) in pending.drain(..).zip(scratch.plans.drain(..)) {
        stats.requests += 1;
        if outcome.fallback_reason.is_some() {
            stats.fallbacks += 1;
        }
        stats.latencies_us.push(p.enqueued.elapsed().as_secs_f64() * 1e6);
        let _ = p.resp.send(outcome);
    }
}

fn worker(cfg: CoordinatorConfig, backend: crate::coordinator::Backend, rx: mpsc::Receiver<Msg>) {
    // Keep a backend handle for store rebuilds (crash, replica clear)
    // before the original moves into the primary store.
    let backend_src = backend.clone();
    let mut store = ModelStore::new(cfg.k, cfg.capacity_gb, backend);
    store.set_default_policy(cfg.default_policy);
    // Warm standby for tasks whose primary lives on the preceding ring
    // arc: fed by `Repl*` twins of every acked update, drained by
    // `DumpReplicaOwned` when the primary crashes. Never serves plans.
    let mut replica = ModelStore::new(cfg.k, cfg.capacity_gb, backend_src.clone());
    replica.set_default_policy(cfg.default_policy);
    let mut stats = ServiceStats::default();
    let mut pending: Vec<Pending> = Vec::new();
    let mut scratch = PlanScratch::default();

    // Continuous ("drain-then-flush") batching: block for the first
    // message, then greedily drain whatever else is already queued —
    // requests that arrived while the previous batch was being served
    // coalesce naturally, and an idle service answers in microseconds
    // instead of waiting out a fixed delay. `batch_delay` survives only
    // as the bound on one final linger poll used when a single request
    // is pending (cheap insurance for lock-step submitters).
    'outer: loop {
        let mut next = match rx.recv() {
            Ok(m) => Some(m),
            Err(_) => break,
        };
        // Handle one message; Plan messages start a drain cycle.
        while let Some(msg) = next.take() {
            match msg {
                Msg::Plan { task, input_mb, enqueued, resp } => {
                    pending.push(Pending { task, input_mb, enqueued, resp });
                    // Drain everything already enqueued.
                    while pending.len() < cfg.batch_max {
                        match rx.try_recv() {
                            Ok(Msg::Plan { task, input_mb, enqueued, resp }) => {
                                pending.push(Pending { task, input_mb, enqueued, resp });
                            }
                            Ok(other) => {
                                next = Some(other);
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                flush(&mut pending, &store, &mut stats, &mut scratch);
                                break 'outer;
                            }
                        }
                    }
                    // Linger once for stragglers when the batch is tiny.
                    if next.is_none() && pending.len() == 1 && !cfg.batch_delay.is_zero() {
                        if let Ok(m) = rx.recv_timeout(cfg.batch_delay.min(
                            Duration::from_micros(100),
                        )) {
                            match m {
                                Msg::Plan { task, input_mb, enqueued, resp } => {
                                    pending.push(Pending { task, input_mb, enqueued, resp });
                                }
                                other => next = Some(other),
                            }
                        }
                    }
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                }
                Msg::Train { task, history, done } => {
                    // Train implies a model swap: flush first so
                    // in-flight requests see a consistent store.
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    store.train(&task, &history);
                    stats.tasks_trained += 1;
                    let _ = done.send(());
                }
                Msg::Configure { task, policy, done } => {
                    // A policy swap is a model swap: flush first so
                    // in-flight requests see a consistent routing.
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    match task {
                        Some(t) => {
                            store.configure(&t, policy);
                        }
                        None => {
                            store.set_default_policy(policy);
                            replica.set_default_policy(policy);
                        }
                    }
                    let _ = done.send(());
                }
                Msg::Observe { task, execution, done } => {
                    // Also a model swap, just an O(k) incremental one.
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    // The store decides what counts as folded (e.g.
                    // sample-less executions are no-ops); the counter
                    // follows its verdict so the two can never drift.
                    let (folded, count) = store.observe(&task, &execution);
                    if folded {
                        stats.observations += 1;
                    }
                    let _ = done.send((count, store.policy_of(&task).name()));
                }
                Msg::Failure { task, prev, fail_time, resp } => {
                    stats.failures_handled += 1;
                    let _ = resp.send(store.on_failure_for(task.as_deref(), &prev, fail_time));
                }
                Msg::Stats { resp } => {
                    let _ = resp.send(stats.clone());
                }
                Msg::ReplObserve { task, execution } => {
                    // Standby fold: same per-task order as the primary
                    // (FIFO twins of acked observes), no stats, no plans.
                    let _ = replica.observe(&task, &execution);
                }
                Msg::ReplTrain { task, history } => {
                    replica.train(&task, &history);
                }
                Msg::ReplConfigure { task, policy } => {
                    replica.configure(&task, policy);
                }
                Msg::TakeTasks { ring, me, resp } => {
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    let mut out = Vec::new();
                    for task in store.stateful_tasks() {
                        if ring.route(&task) != me {
                            if let Some(st) = store.export_task(&task) {
                                store.remove_task(&task);
                                out.push(st);
                            }
                        }
                    }
                    let _ = resp.send(out);
                }
                Msg::DumpPrimary { resp } => {
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    let tasks: Vec<TaskState> = store
                        .stateful_tasks()
                        .iter()
                        .filter_map(|t| store.export_task(t))
                        .collect();
                    let _ = resp.send((store.default_policy(), tasks));
                }
                Msg::DumpReplicaOwned { ring, owner, resp } => {
                    let tasks: Vec<TaskState> = replica
                        .stateful_tasks()
                        .iter()
                        .filter(|t| ring.route(t) == owner)
                        .filter_map(|t| replica.export_task(t))
                        .collect();
                    let _ = resp.send(tasks);
                }
                Msg::InjectTasks { tasks, into_replica, done } => {
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    let target = if into_replica { &mut replica } else { &mut store };
                    let mut result: Result<(), String> = Ok(());
                    for st in tasks {
                        if let Err(e) = target.import_task(st) {
                            result = Err(format!("{e:#}"));
                            break;
                        }
                    }
                    let _ = done.send(result);
                }
                Msg::ClearReplica { done } => {
                    let dp = replica.default_policy();
                    replica = ModelStore::new(cfg.k, cfg.capacity_gb, backend_src.clone());
                    replica.set_default_policy(dp);
                    let _ = done.send(());
                }
                Msg::Crash { done } => {
                    // Amnesia-crash: answer queued plans from pre-crash
                    // state (they were enqueued before the kill), then
                    // wipe both stores. Defaults and counters survive —
                    // a restarted process gets its policy from static
                    // config, and keeping the counters makes lost-work
                    // accounting exact across the crash.
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    let dp = store.default_policy();
                    store = ModelStore::new(cfg.k, cfg.capacity_gb, backend_src.clone());
                    store.set_default_policy(dp);
                    let rdp = replica.default_policy();
                    replica = ModelStore::new(cfg.k, cfg.capacity_gb, backend_src.clone());
                    replica.set_default_policy(rdp);
                    let _ = done.send(());
                }
                Msg::Shutdown => {
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    break 'outer;
                }
            }
        }
    }
}

// ---- shared request dispatch ---------------------------------------------
//
// Every server front end — the threaded parity oracle and the event
// loop — turns a decoded `protocol::Request` into a reply through this
// one function, so the two cores cannot drift in semantics. The front
// ends own only framing and connection lifecycle; everything from
// version negotiation to shard routing lives here.

/// How many distinct retry sessions (nonces) the dedup cache retains.
/// Beyond the cap the oldest nonce is evicted FIFO — a client that went
/// silent for 1024 sessions' worth of traffic has long since given up on
/// its retry.
pub const DEDUP_NONCE_CAP: usize = 1024;

struct DedupEntry {
    /// Highest sequence number applied under this nonce.
    seq: u64,
    /// The response that sequence number produced, replayed verbatim to
    /// retries.
    cached: Response,
}

/// Server-side exactly-once cache for retried mutating requests.
///
/// A self-healing client that retries `configure`/`train`/`observe`
/// attaches a [`Dedup`] marker: a per-session `nonce` plus a sequence
/// number that increments per *logical* operation (not per attempt). The
/// table keeps, per nonce, the last applied sequence and its response:
/// a replay of the same `(nonce, seq)` — e.g. the ack was lost to a
/// severed connection — returns the cached response without touching the
/// model store, so the operation applies exactly once; a `seq` below the
/// last applied is a protocol error (`invalid-field`), since the client
/// must retry in order.
#[derive(Default)]
pub struct DedupTable {
    entries: BTreeMap<String, DedupEntry>,
    /// Insertion order of nonces, for FIFO eviction at the cap.
    order: VecDeque<String>,
}

impl DedupTable {
    /// Serve one deduplicated operation: replay the cached response for
    /// a duplicate, reject a stale sequence, otherwise apply and cache.
    /// The table lock is held across `apply`, so two racing attempts at
    /// the same `(nonce, seq)` cannot both reach the model store.
    fn serve(&mut self, d: &Dedup, apply: impl FnOnce() -> Response) -> Result<Response, WireError> {
        if let Some(entry) = self.entries.get(&d.nonce) {
            if d.seq == entry.seq {
                return Ok(entry.cached.clone());
            }
            if d.seq < entry.seq {
                return Err(WireError::new(
                    ErrorCode::InvalidField,
                    format!(
                        "'seq' {} is stale for nonce '{}' (last applied {})",
                        d.seq, d.nonce, entry.seq
                    ),
                ));
            }
        }
        let resp = apply();
        match self.entries.get_mut(&d.nonce) {
            Some(entry) => {
                entry.seq = d.seq;
                entry.cached = resp.clone();
            }
            None => {
                if self.entries.len() >= DEDUP_NONCE_CAP {
                    if let Some(oldest) = self.order.pop_front() {
                        self.entries.remove(&oldest);
                    }
                }
                self.order.push_back(d.nonce.clone());
                self.entries
                    .insert(d.nonce.clone(), DedupEntry { seq: d.seq, cached: resp.clone() });
            }
        }
        Ok(resp)
    }
}

/// Connection counters owned by a server front end. The shard workers
/// know nothing about sockets, so refusals and idle-timeout closes are
/// counted at the front end and folded into `stats` replies by
/// [`dispatch`].
#[derive(Default)]
pub struct ConnCounters {
    /// Connections refused at the `max_conns` limit.
    pub refused: AtomicU64,
    /// Connections closed by the idle/read timeout.
    pub timeouts: AtomicU64,
    /// Connections closed because their buffered-but-unsent responses
    /// exceeded `max_wbuf_bytes` (event-loop front end; a slow or
    /// non-reading pipelining peer).
    pub overflows: AtomicU64,
    /// Requests rejected with `overloaded` by the admission control
    /// (dispatch queue at `max_queue_depth`, or a connection at its
    /// in-flight cap). The connection stays open.
    pub shed: AtomicU64,
    /// High-water mark of the dispatch queue depth.
    pub queue_depth_max: AtomicU64,
    /// Graceful drains completed by `stop()`.
    pub drains: AtomicU64,
}

impl ConnCounters {
    /// Fold a queue-depth observation into the high-water mark
    /// (lock-free atomic max).
    pub fn note_queue_depth(&self, depth: u64) {
        let _ = self
            .queue_depth_max
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                (depth > cur).then_some(depth)
            });
    }
}

/// Outcome of dispatching one request.
pub enum Dispatched {
    Reply(Response),
    Error(WireError),
    /// A successful `hello`: the response plus the negotiated wire
    /// version. The front end writes the response on the wire the hello
    /// arrived on, then switches the connection's codec — the
    /// STARTTLS-style upgrade point.
    Hello(Response, usize),
}

/// Serve one parsed request. Infallible after parsing, except version
/// negotiation and the admin ops — the coordinator itself never errors
/// on a well-formed data-path request.
pub fn dispatch(req: Request, client: &Client, counters: &ConnCounters) -> Dispatched {
    match req {
        Request::Hello { min_version, max_version, .. } => {
            match negotiate_version(min_version, max_version) {
                Err(e) => Dispatched::Error(e),
                Ok(version) => Dispatched::Hello(
                    Response::Hello(ServerInfo {
                        version,
                        ops: OPS.iter().map(|s| s.to_string()).collect(),
                        policies: PredictorPolicy::names()
                            .iter()
                            .map(|s| s.to_string())
                            .collect(),
                        shards: client.shards(),
                    }),
                    version,
                ),
            }
        }
        Request::Configure { task, policy, dedup } => with_dedup(client, dedup, || {
            client.configure(task.as_deref(), policy);
            Response::Configured { task, policy }
        }),
        Request::Train { task, history, dedup } => with_dedup(client, dedup, || {
            let executions = history.len() as u64;
            client.train(&task, history);
            Response::Trained { task, executions }
        }),
        Request::Observe { task, execution, dedup } => with_dedup(client, dedup, || {
            let (executions, predictor) = client.observe_detailed(&task, execution);
            Response::Observed(ObserveAck { task, executions, predictor })
        }),
        Request::Plan { task, input_mb } => {
            Dispatched::Reply(Response::Planned(client.plan_detailed(&task, input_mb)))
        }
        Request::Failure { task, plan, fail_time } => Dispatched::Reply(Response::Retry(
            client.report_failure_for(task.as_deref(), &plan, fail_time),
        )),
        Request::Stats => {
            let s = client.stats();
            Dispatched::Reply(Response::Stats(StatsSummary {
                shards: client.shards(),
                requests: s.requests,
                batches: s.batches,
                failures_handled: s.failures_handled,
                tasks_trained: s.tasks_trained,
                observations: s.observations,
                fallbacks: s.fallbacks,
                conns_refused: s.conns_refused + counters.refused.load(Ordering::Relaxed),
                conn_timeouts: s.conn_timeouts + counters.timeouts.load(Ordering::Relaxed),
                conns_overflowed: counters.overflows.load(Ordering::Relaxed),
                shed: counters.shed.load(Ordering::Relaxed),
                queue_depth_max: counters.queue_depth_max.load(Ordering::Relaxed),
                drains: counters.drains.load(Ordering::Relaxed),
                latency_p50_us: s.latency_percentile_us(50.0),
                latency_p99_us: s.latency_percentile_us(99.0),
            }))
        }
        Request::Snapshot => {
            Dispatched::Reply(Response::Snapshot { doc: client.snapshot_json() })
        }
        Request::Reshard { shards } => {
            if shards < 1 || shards > MAX_SHARDS {
                return Dispatched::Error(WireError::new(
                    ErrorCode::InvalidField,
                    format!("'shards' must be between 1 and {MAX_SHARDS}"),
                ));
            }
            match client.set_shards(shards) {
                Ok(shard_ids) => Dispatched::Reply(Response::Resharded { shard_ids }),
                Err(e) => {
                    Dispatched::Error(WireError::new(ErrorCode::Internal, format!("reshard: {e:#}")))
                }
            }
        }
    }
}

/// Route one mutating operation through the coordinator's dedup table
/// when the request carries a [`Dedup`] marker; apply it directly when
/// it does not (the common, non-retrying case pays nothing).
fn with_dedup(
    client: &Client,
    dedup: Option<Dedup>,
    apply: impl FnOnce() -> Response,
) -> Dispatched {
    match dedup {
        None => Dispatched::Reply(apply()),
        Some(d) => match lock_recover(&client.dedup).serve(&d, apply) {
            Ok(resp) => Dispatched::Reply(resp),
            Err(e) => Dispatched::Error(e),
        },
    }
}

/// Observer installed at the dispatch seam — the one point every front
/// end funnels through, so a tap sees exactly the request/outcome pairs
/// the server acted on (negotiation outcomes and structured errors
/// included). `repro record` installs one to capture session traces.
/// Called synchronously on the dispatching thread; implementations must
/// be cheap or buffer.
pub trait DispatchTap: Send + Sync {
    fn observe(&self, req: &Request, out: &Dispatched);
}

/// [`dispatch`] with an optional [`DispatchTap`]. With no tap installed
/// this is exactly `dispatch` — the clone only happens when someone is
/// recording.
pub fn dispatch_tapped(
    req: Request,
    client: &Client,
    counters: &ConnCounters,
    tap: Option<&Arc<dyn DispatchTap>>,
) -> Dispatched {
    match tap {
        None => dispatch(req, client, counters),
        Some(tap) => {
            let out = dispatch(req.clone(), client, counters);
            tap.observe(&req, &out);
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::ksplus::KsPlus;
    use crate::predictor::Predictor;
    use crate::util::rng::Rng;

    fn two_phase_exec(input: f64, rng: &mut Rng) -> Execution {
        let d1 = ((input * 0.01) as usize).max(2);
        let d2 = ((input * 0.003) as usize).max(1);
        let mut s = vec![input * 0.0005; d1];
        s.extend(vec![input * 0.001; d2]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new("bwa", input, 1.0, s)
    }

    fn history(seed: u64, n: usize) -> Vec<Execution> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect()
    }

    /// Two task names guaranteed to route to different shards.
    fn two_tasks_on_distinct_shards(shards: usize) -> (String, String) {
        assert!(shards > 1, "needs at least two shards to find distinct routes");
        let ring = HashRing::new(0..shards);
        let a = "task-a".to_string();
        let sa = ring.route(&a);
        let mut i = 0u64;
        loop {
            let b = format!("task-b{i}");
            if ring.route(&b) != sa {
                return (a, b);
            }
            i += 1;
        }
    }

    #[test]
    fn end_to_end_plan_matches_offline_predictor() {
        let hist = history(1, 30);
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", hist.clone());
        let got = client.plan("bwa", 8000.0);
        let mut want = KsPlus::new(2, 128.0);
        want.train(&hist);
        let want = want.plan(8000.0);
        assert_eq!(got.k(), want.k());
        for i in 0..got.k() {
            assert!((got.starts[i] - want.starts[i]).abs() < 1e-9);
            assert!((got.peaks[i] - want.peaks[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn concurrent_clients_get_batched() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                k: 2,
                batch_max: 16,
                batch_delay: Duration::from_millis(4),
                ..Default::default()
            },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", history(2, 20));
        let mut handles = Vec::new();
        for i in 0..32 {
            let c = coord.client();
            handles.push(std::thread::spawn(move || {
                c.plan("bwa", 3000.0 + i as f64 * 100.0)
            }));
        }
        let plans: Vec<StepPlan> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(plans.len(), 32);
        assert!(plans.iter().all(|p| p.is_valid()));
        let stats = client.stats();
        assert_eq!(stats.requests, 32);
        assert!(stats.batches < 32, "no batching happened: {}", stats.batches);
        assert!(stats.mean_batch_size() > 1.0);
    }

    #[test]
    fn failure_roundtrip() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let retry = client.report_failure(&prev, 60.0);
        assert_eq!(retry.starts, vec![0.0, 60.0]);
        assert_eq!(client.stats().failures_handled, 1);
    }

    #[test]
    fn unknown_task_served_with_fallback() {
        let coord =
            Coordinator::start(CoordinatorConfig::default(), BackendSpec::Native).unwrap();
        let plan = coord.client().plan("never-trained", 123.0);
        assert!(plan.is_valid());
    }

    #[test]
    fn stats_latency_recorded() {
        let coord = Coordinator::start(
            CoordinatorConfig { batch_delay: Duration::from_micros(200), ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", history(3, 10));
        for _ in 0..5 {
            client.plan("bwa", 4000.0);
        }
        let stats = client.stats();
        assert_eq!(stats.latencies_us.len(), 5);
        assert!(stats.latency_percentile_us(50.0) > 0.0);
    }

    #[test]
    fn latency_window_is_bounded() {
        let mut w = LatencyWindow::with_capacity(8);
        for i in 0..100 {
            w.push(i as f64);
        }
        assert_eq!(w.len(), 8);
        assert_eq!(w.total_recorded(), 100);
        // Only the most recent 8 samples (92..=99) remain.
        assert!(w.as_slice().iter().all(|&v| v >= 92.0));
        let p50 = w.percentile(50.0);
        assert!((92.0..=99.0).contains(&p50), "p50 {p50}");
        assert_eq!(w.percentile(100.0), 99.0);
    }

    #[test]
    fn service_latencies_stay_bounded() {
        // The stats window must not grow past its capacity no matter how
        // many requests the service handles.
        let coord = Coordinator::start(
            CoordinatorConfig { batch_delay: Duration::ZERO, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", history(5, 10));
        let n = 64;
        for _ in 0..n {
            client.plan("bwa", 4000.0);
        }
        let stats = client.stats();
        assert_eq!(stats.requests, n);
        assert_eq!(stats.latencies_us.total_recorded(), n);
        assert!(stats.latencies_us.len() <= LATENCY_WINDOW);
        assert!(stats.latency_percentile_us(99.0) > 0.0);
    }

    #[test]
    fn latency_window_merge_exact_percentiles() {
        // Merging two windows of known samples must yield the exact
        // percentiles of the union (linear interpolation over 1..=8).
        let mut a = LatencyWindow::with_capacity(8);
        for v in [1.0, 2.0, 3.0, 4.0] {
            a.push(v);
        }
        let mut b = LatencyWindow::with_capacity(8);
        for v in [5.0, 6.0, 7.0, 8.0] {
            b.push(v);
        }
        a.merge(&b);
        assert_eq!(a.len(), 8);
        assert_eq!(a.total_recorded(), 8);
        assert_eq!(a.percentile(0.0), 1.0);
        assert_eq!(a.percentile(100.0), 8.0);
        // rank 0.5 * 7 = 3.5 -> 4 + 0.5 * (5 - 4) = 4.5
        assert_eq!(a.percentile(50.0), 4.5);
        // rank 0.25 * 7 = 1.75 -> 2 + 0.75 * (3 - 2) = 2.75
        assert_eq!(a.percentile(25.0), 2.75);
    }

    #[test]
    fn latency_window_merge_preserves_order_after_wrap() {
        let mut a = LatencyWindow::with_capacity(4);
        for i in 0..6 {
            a.push(i as f64);
        }
        assert_eq!(a.chronological(), vec![2.0, 3.0, 4.0, 5.0]);
        let mut b = LatencyWindow::with_capacity(2);
        for i in 0..5 {
            b.push(10.0 + i as f64);
        }
        assert_eq!(b.chronological(), vec![13.0, 14.0]);
        a.merge(&b);
        assert_eq!(a.len(), 6);
        assert_eq!(a.total_recorded(), 11);
        assert_eq!(a.chronological(), vec![2.0, 3.0, 4.0, 5.0, 13.0, 14.0]);
        // The merged window stays a well-formed ring: more pushes rotate
        // out the oldest sample first.
        a.push(99.0);
        assert_eq!(a.chronological(), vec![3.0, 4.0, 5.0, 13.0, 14.0, 99.0]);
    }

    #[test]
    fn service_stats_merge_counters_and_mean_batch() {
        let mut a = ServiceStats::default();
        a.requests = 10;
        a.batches = 2;
        a.failures_handled = 1;
        a.tasks_trained = 3;
        a.observations = 5;
        a.fallbacks = 2;
        a.conns_refused = 1;
        a.conn_timeouts = 2;
        a.latencies_us.push(100.0);
        let mut b = ServiceStats::default();
        b.requests = 30;
        b.batches = 8;
        b.tasks_trained = 1;
        b.observations = 7;
        b.fallbacks = 4;
        b.conns_refused = 2;
        b.conn_timeouts = 0;
        b.latencies_us.push(300.0);
        let m = ServiceStats::merged(&[a, b]);
        assert_eq!(m.requests, 40);
        assert_eq!(m.batches, 10);
        assert_eq!(m.failures_handled, 1);
        assert_eq!(m.tasks_trained, 4);
        assert_eq!(m.observations, 12);
        assert_eq!(m.fallbacks, 6);
        assert_eq!(m.conns_refused, 3);
        assert_eq!(m.conn_timeouts, 2);
        // Mean batch size comes from the merged counters, not an average
        // of per-shard means: (10 + 30) / (2 + 8).
        assert_eq!(m.mean_batch_size(), 4.0);
        assert_eq!(m.latencies_us.len(), 2);
        assert_eq!(m.latency_percentile_us(50.0), 200.0);
    }

    #[test]
    fn trained_task_never_gets_fallback_on_any_shard() {
        // Because train and plan route by the same ring, a plan after a
        // train on the same task must always find the model — for every
        // task name, whichever shard it routes to.
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 4, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        for i in 0..64u64 {
            let task = format!("task-{i}");
            let before = client.plan(&task, 5000.0);
            assert_eq!(before.k(), 1, "untrained task must get the flat fallback");
            client.train(&task, history(100 + i, 12));
            // Plan through a *clone* of the client: routing must agree
            // across client handles, not just within one.
            let after = client.clone().plan(&task, 5000.0);
            assert!(
                !(after.starts == before.starts && after.peaks == before.peaks),
                "{task} still served the untrained fallback after train()"
            );
        }
        let stats = client.stats();
        assert_eq!(stats.tasks_trained, 64);
        assert_eq!(stats.requests, 128);
    }

    #[test]
    fn observe_stream_matches_scratch_retrained_predictor() {
        // Satellite: interleaved observe/plan on the live coordinator
        // must match a KsPlus predictor retrained from scratch on the
        // same prefix, within 1e-9.
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let hist = history(11, 24);
        for (i, e) in hist.iter().enumerate() {
            let n = client.observe("bwa", e.clone());
            assert_eq!(n, i as u64 + 1);
            let got = client.plan("bwa", 6000.0);
            let mut scratch = KsPlus::new(2, 128.0);
            scratch.train(&hist[..=i]);
            let want = scratch.plan(6000.0);
            assert_eq!(got.k(), want.k(), "after {} observations", i + 1);
            for j in 0..got.k() {
                assert!((got.starts[j] - want.starts[j]).abs() < 1e-9, "{got:?} vs {want:?}");
                assert!((got.peaks[j] - want.peaks[j]).abs() < 1e-9, "{got:?} vs {want:?}");
            }
        }
        let stats = client.stats();
        assert_eq!(stats.observations, 24);
        assert_eq!(stats.requests, 24);
        assert_eq!(stats.tasks_trained, 0);
    }

    #[test]
    fn observe_routes_to_the_training_shard() {
        // Observe must land on the shard that owns the task's models —
        // for every task name, whichever shard it routes to.
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 4, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        for i in 0..32u64 {
            let task = format!("task-{i}");
            let before = client.plan(&task, 5000.0);
            assert_eq!(before.k(), 1, "unobserved task must get the flat fallback");
            for e in history(300 + i, 6) {
                client.observe(&task, e);
            }
            let after = client.clone().plan(&task, 5000.0);
            assert!(
                !(after.starts == before.starts && after.peaks == before.peaks),
                "{task} still served the untrained fallback after observe()"
            );
        }
        let stats = client.stats();
        assert_eq!(stats.observations, 32 * 6);
        // Observations spread over multiple shards like training does.
        let per = client.shard_stats();
        assert!(per.iter().filter(|s| s.observations > 0).count() > 1, "{per:?}");
    }

    #[test]
    fn per_task_policies_route_plans_observes_and_failures() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 4, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.configure(Some("ks-task"), PredictorPolicy::KsPlus);
        client.configure(Some("wt-task"), PredictorPolicy::WittLr);
        client.train("ks-task", history(41, 15));
        client.train("wt-task", history(42, 15));
        let ks = client.plan_detailed("ks-task", 5000.0);
        assert_eq!(ks.predictor, "ksplus");
        assert_eq!(ks.model_version, 15);
        assert_eq!(ks.fallback_reason, None);
        assert!(ks.plan.k() >= 1);
        let wt = client.plan_detailed("wt-task", 5000.0);
        assert_eq!(wt.predictor, "witt-lr");
        assert_eq!(wt.model_version, 15);
        assert_eq!(wt.plan.k(), 1, "witt serves flat peak plans");
        // Observe provenance follows the binding.
        let mut rng = Rng::new(43);
        let (n, p) = client.observe_detailed("wt-task", two_phase_exec(4000.0, &mut rng));
        assert_eq!((n, p), (16, "witt-lr"));
        let (n, p) = client.observe_detailed("ks-task", two_phase_exec(4000.0, &mut rng));
        assert_eq!((n, p), (16, "ksplus"));
        // Failure retries run the bound policy's strategy on the owning
        // shard.
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let r = client.report_failure_for(Some("wt-task"), &prev, 60.0);
        assert_eq!(r.predictor, "witt-lr");
        assert_eq!(r.plan, StepPlan::flat(16.0));
        let r = client.report_failure_for(Some("ks-task"), &prev, 60.0);
        assert_eq!(r.predictor, "ksplus");
        assert_eq!(r.plan.starts, vec![0.0, 60.0]);
        assert_eq!(client.stats().failures_handled, 2);
    }

    #[test]
    fn service_default_policy_fans_out_to_every_shard() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 3, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.configure(None, PredictorPolicy::TovarPpm);
        // Whatever shard each task routes to, training now lands on the
        // tovar policy.
        for i in 0..12u64 {
            let task = format!("task-{i}");
            client.train(&task, history(500 + i, 10));
            let out = client.plan_detailed(&task, 4000.0);
            assert_eq!(out.predictor, "tovar-ppm", "{task}");
            assert_eq!(out.plan.k(), 1);
        }
    }

    #[test]
    fn fallbacks_counted_and_merged_across_shards() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 4, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("trained", history(51, 10));
        // 6 untrained plans spread across shards + 2 trained plans.
        for i in 0..6u64 {
            let out = client.plan_detailed(&format!("mystery-{i}"), 100.0);
            assert_eq!(out.fallback_reason, Some(crate::coordinator::FALLBACK_UNTRAINED));
            assert_eq!(out.predictor, "default-limits");
            assert_eq!(out.model_version, 0);
        }
        client.plan("trained", 4000.0);
        client.plan("trained", 8000.0);
        let stats = client.stats();
        assert_eq!(stats.requests, 8);
        assert_eq!(stats.fallbacks, 6);
        // The merge is the sum of the per-shard counters.
        let per = client.shard_stats();
        assert_eq!(per.iter().map(|s| s.fallbacks).sum::<u64>(), 6);
    }

    #[test]
    fn stats_fan_out_and_merge_across_shards() {
        let shards = 3;
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        assert_eq!(client.shards(), shards);
        let n_tasks = 12u64;
        for i in 0..n_tasks {
            let task = format!("task-{i}");
            client.train(&task, history(200 + i, 10));
            client.plan(&task, 4000.0);
            client.plan(&task, 8000.0);
        }
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        client.report_failure(&prev, 60.0);
        let per = client.shard_stats();
        assert_eq!(per.len(), shards);
        let merged = client.stats();
        assert_eq!(merged.requests, 2 * n_tasks);
        assert_eq!(merged.tasks_trained, n_tasks);
        assert_eq!(merged.failures_handled, 1);
        // The aggregate is exactly the sum of the per-shard views.
        assert_eq!(per.iter().map(|s| s.requests).sum::<u64>(), merged.requests);
        assert_eq!(per.iter().map(|s| s.tasks_trained).sum::<u64>(), merged.tasks_trained);
        assert_eq!(
            per.iter().map(|s| s.latencies_us.len()).sum::<usize>(),
            merged.latencies_us.len()
        );
        // With 12 distinct tasks over 3 shards, more than one shard must
        // have seen traffic (the ring spreads these names).
        assert!(per.iter().filter(|s| s.requests > 0).count() > 1);
    }

    #[test]
    fn per_shard_batchers_run_independently() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                k: 2,
                batch_max: 16,
                batch_delay: Duration::from_millis(4),
                shards: 2,
                ..Default::default()
            },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let (t0, t1) = two_tasks_on_distinct_shards(2);
        client.train(&t0, history(2, 20));
        client.train(&t1, history(3, 20));
        let mut handles = Vec::new();
        for i in 0..32usize {
            let c = coord.client();
            let task = if i % 2 == 0 { t0.clone() } else { t1.clone() };
            handles.push(std::thread::spawn(move || {
                c.plan(&task, 3000.0 + i as f64 * 100.0)
            }));
        }
        let plans: Vec<StepPlan> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(plans.iter().all(|p| p.is_valid()));
        let per = client.shard_stats();
        assert_eq!(per.len(), 2);
        // Both shards saw their half of the traffic and batched it
        // themselves.
        assert!(per.iter().all(|s| s.requests == 16), "{per:?}");
        assert_eq!(client.stats().requests, 32);
    }

    #[test]
    fn failure_round_robin_spreads_across_shards() {
        let shards = 4;
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        for _ in 0..shards * 3 {
            let retry = client.report_failure(&prev, 60.0);
            assert!(retry.is_valid());
        }
        let per = client.shard_stats();
        assert!(per.iter().all(|s| s.failures_handled == 3), "{per:?}");
    }

    #[test]
    fn zero_shards_is_a_startup_error() {
        let err = Coordinator::start(
            CoordinatorConfig { shards: 0, ..Default::default() },
            BackendSpec::Native,
        )
        .err()
        .expect("zero shards must not start");
        assert!(format!("{err:#}").contains("shard"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_start_errors_instead_of_panicking_worker() {
        // The startup seam: a backend that cannot be built in this binary
        // must surface as Err from start(), not as a detached worker
        // thread panic that clients discover via "coordinator gone".
        for shards in [1, 4] {
            let err = Coordinator::start(
                CoordinatorConfig { shards, ..Default::default() },
                BackendSpec::Pjrt(None),
            )
            .err()
            .expect("pjrt spec must not start in a native-only build");
            let msg = format!("{err:#}");
            assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_end_to_end() {
        // The production path: coordinator worker owns a PJRT runtime
        // built from the AOT artifacts; plans must match the native
        // backend to f32 precision.
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let hist = history(7, 25);
        let cfg = CoordinatorConfig { k: 3, ..Default::default() };
        let pjrt = Coordinator::start(cfg.clone(), BackendSpec::Pjrt(Some(dir))).unwrap();
        let native = Coordinator::start(cfg, BackendSpec::Native).unwrap();
        pjrt.client().train("bwa", hist.clone());
        native.client().train("bwa", hist);
        for input in [2500.0, 6000.0, 11000.0] {
            let a = pjrt.client().plan("bwa", input);
            let b = native.client().plan("bwa", input);
            assert_eq!(a.k(), b.k(), "{a:?} vs {b:?}");
            for i in 0..a.k() {
                assert!((a.starts[i] - b.starts[i]).abs() < 0.5, "{a:?} vs {b:?}");
                assert!((a.peaks[i] - b.peaks[i]).abs() < 0.05, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn shutdown_flushes_cleanly() {
        let coord = Coordinator::start(
            CoordinatorConfig { shards: 3, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", history(4, 10));
        drop(coord); // must not hang or panic, across all shards
        // Client calls after shutdown fail loudly (panic) — we only
        // check drop-order safety here.
        let _ = client;
    }

    // ----- elastic resharding / crash recovery / snapshot ----------------

    /// Train a mixed-policy corpus and return every task's current plan
    /// outcome, so membership changes can be checked for bit-identity.
    fn seed_corpus(client: &Client, n: u64) -> Vec<(String, PlanOutcome)> {
        for i in 0..n {
            let task = format!("task-{i}");
            if i % 3 == 0 {
                client.configure(Some(&task), PredictorPolicy::WittLr);
            }
            client.train(&task, history(700 + i, 12));
            // A couple of incremental observes on top of the batch fit.
            let mut rng = Rng::new(900 + i);
            for _ in 0..3 {
                client.observe(&task, two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng));
            }
        }
        (0..n)
            .map(|i| {
                let task = format!("task-{i}");
                let out = client.plan_detailed(&task, 6000.0);
                (task, out)
            })
            .collect()
    }

    fn assert_plans_unchanged(client: &Client, want: &[(String, PlanOutcome)], when: &str) {
        for (task, before) in want {
            let after = client.plan_detailed(task, 6000.0);
            assert_eq!(&after, before, "{task} plan changed {when}");
        }
    }

    #[test]
    fn add_and_remove_shards_preserve_plans_bit_identically() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let want = seed_corpus(&client, 24);
        assert_eq!(client.shard_ids(), vec![0, 1]);
        let id = client.add_shard().unwrap();
        assert_eq!(id, 2);
        assert_eq!(client.shard_ids(), vec![0, 1, 2]);
        assert_plans_unchanged(&client, &want, "after add_shard");
        // The new shard actually owns some of the corpus.
        assert!(
            want.iter().any(|(t, _)| client.owner_of(t) == id),
            "no task moved to the new shard"
        );
        // Shrinking hands the departing shard's tasks back losslessly.
        client.remove_shard(0).unwrap();
        assert_eq!(client.shard_ids(), vec![1, 2]);
        assert_plans_unchanged(&client, &want, "after remove_shard");
        // Counters follow the handoff: nothing trained was double
        // counted or lost.
        assert_eq!(client.stats().tasks_trained, 24);
    }

    #[test]
    fn set_shards_reaches_target_and_keeps_plans() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 1, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let want = seed_corpus(&client, 12);
        assert_eq!(client.set_shards(4).unwrap(), vec![0, 1, 2, 3]);
        assert_plans_unchanged(&client, &want, "after growing 1 -> 4");
        assert_eq!(client.set_shards(2).unwrap(), vec![0, 1]);
        assert_plans_unchanged(&client, &want, "after shrinking 4 -> 2");
        assert!(client.set_shards(0).is_err());
        assert!(client.set_shards(MAX_SHARDS + 1).is_err());
    }

    #[test]
    fn remove_shard_error_cases() {
        let coord = Coordinator::start(
            CoordinatorConfig { shards: 1, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let err = client.remove_shard(0).err().expect("removing the last shard must fail");
        assert!(format!("{err:#}").contains("last shard"));
        let err = client.remove_shard(9).err().expect("unknown shard must fail");
        assert!(format!("{err:#}").contains("no such shard"));
    }

    #[test]
    fn crash_restart_restores_every_shard_from_its_standbys() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 3, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let want = seed_corpus(&client, 18);
        let observations = client.stats().observations;
        for id in client.shard_ids() {
            let restored = client.crash_restart_shard(id).unwrap();
            assert!(restored > 0, "shard {id} had nothing to restore");
            assert_plans_unchanged(&client, &want, &format!("after crash-restarting shard {id}"));
        }
        // Crash preserves the counters, so lost-work accounting is
        // exact: nothing was lost, nothing was re-counted.
        assert_eq!(client.stats().observations, observations);
    }

    #[test]
    fn crash_without_restore_loses_training_then_restore_recovers_it() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let want = seed_corpus(&client, 8);
        let victim = client.owner_of("task-0");
        client.crash_shard(victim).unwrap();
        let lost = client.plan_detailed("task-0", 6000.0);
        assert_eq!(
            lost.fallback_reason,
            Some(crate::coordinator::FALLBACK_UNTRAINED),
            "a crashed shard must serve the fallback, not stale state"
        );
        let restored = client.restore_shard(victim).unwrap();
        assert!(restored > 0);
        assert_plans_unchanged(&client, &want, "after restore_shard");
    }

    #[test]
    fn replication_covers_train_configure_and_observe_provenance() {
        // The restored task must keep its policy binding and model
        // version, not just its plan numbers.
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.configure(Some("wt"), PredictorPolicy::WittLr);
        client.train("wt", history(61, 10));
        let mut rng = Rng::new(62);
        for _ in 0..5 {
            client.observe("wt", two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng));
        }
        let before = client.plan_detailed("wt", 5000.0);
        assert_eq!(before.predictor, "witt-lr");
        assert_eq!(before.model_version, 15);
        client.crash_restart_shard(client.owner_of("wt")).unwrap();
        let after = client.plan_detailed("wt", 5000.0);
        assert_eq!(after, before);
        // And the stream keeps counting where it left off.
        let (n, p) = client.observe_detailed("wt", two_phase_exec(4000.0, &mut rng));
        assert_eq!((n, p), (16, "witt-lr"));
    }

    #[test]
    fn snapshot_restores_into_a_pool_of_different_width() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.configure(None, PredictorPolicy::KsPlus);
        let want = seed_corpus(&client, 16);
        let doc = client.snapshot_json();
        drop(coord);

        // Restore into a *three*-shard pool: the snapshot is routing
        // agnostic, so the width does not have to match.
        let coord2 = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 3, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client2 = coord2.client();
        let restored = client2.restore_snapshot(&doc).unwrap();
        assert_eq!(restored as u64, 16);
        assert_plans_unchanged(&client2, &want, "after restore into a 3-shard pool");
        // Replicas were rebuilt too: a crash right after restore loses
        // nothing.
        client2.crash_restart_shard(0).unwrap();
        assert_plans_unchanged(&client2, &want, "after post-restore crash-restart");

        // Mismatched hyperparameters are refused outright.
        let coord3 = Coordinator::start(
            CoordinatorConfig { k: 3, shards: 1, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let err = coord3.client().restore_snapshot(&doc).err().expect("k mismatch must fail");
        assert!(format!("{err:#}").contains("k="));
    }

    #[test]
    fn concurrent_traffic_survives_live_resharding_and_crashes() {
        // Smoke the lock discipline: writers hammer observe/plan while
        // the admin thread grows, shrinks, and crash-restarts shards.
        // Each task has a single writer so replica folds stay ordered.
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let n_writers = 4u64;
        let per_writer = 40u64;
        let mut handles = Vec::new();
        for w in 0..n_writers {
            let c = coord.client();
            handles.push(std::thread::spawn(move || {
                let task = format!("writer-{w}");
                let mut rng = Rng::new(1000 + w);
                for _ in 0..per_writer {
                    c.observe(&task, two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng));
                    let plan = c.plan(&task, 5000.0);
                    assert!(plan.is_valid());
                }
            }));
        }
        let admin = coord.client();
        let added = admin.add_shard().unwrap();
        admin.crash_restart_shard(0).unwrap();
        admin.remove_shard(added).unwrap();
        admin.crash_restart_shard(1).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        let stats = coord.client().stats();
        // Zero lost observes: every acked fold is counted exactly once
        // (crash preserves counters; handoff moves accumulators, not
        // counters).
        assert_eq!(stats.observations, n_writers * per_writer);
        // And the surviving state is the full fold: each writer's task
        // serves a real prediction, not a fallback.
        for w in 0..n_writers {
            let out = coord.client().plan_detailed(&format!("writer-{w}"), 5000.0);
            assert_eq!(out.fallback_reason, None, "writer-{w}");
            assert_eq!(out.model_version, per_writer);
        }
    }

    #[test]
    fn deduped_observe_applies_exactly_once() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let counters = ConnCounters::default();
        let mut rng = Rng::new(31);
        let exec = two_phase_exec(5000.0, &mut rng);
        let req = Request::Observe {
            task: "bwa".into(),
            execution: exec,
            dedup: Some(Dedup { nonce: "sess-a".into(), seq: 1 }),
        };
        // First attempt applies; the replayed attempt (lost ack) must
        // return the identical cached response without re-folding.
        let first = match dispatch(req.clone(), &client, &counters) {
            Dispatched::Reply(r) => r,
            _ => panic!("expected reply"),
        };
        let replay = match dispatch(req, &client, &counters) {
            Dispatched::Reply(r) => r,
            _ => panic!("expected reply"),
        };
        assert_eq!(first, replay);
        assert_eq!(client.stats().observations, 1, "replay must not re-apply");
        match first {
            Response::Observed(ack) => assert_eq!(ack.executions, 1),
            other => panic!("unexpected response {other:?}"),
        }
        // The next logical op under the same nonce applies normally.
        let next = Request::Observe {
            task: "bwa".into(),
            execution: two_phase_exec(6000.0, &mut rng),
            dedup: Some(Dedup { nonce: "sess-a".into(), seq: 2 }),
        };
        match dispatch(next, &client, &counters) {
            Dispatched::Reply(Response::Observed(ack)) => assert_eq!(ack.executions, 2),
            _ => panic!("seq 2 must apply normally"),
        }
        assert_eq!(client.stats().observations, 2);
        // A stale sequence is a structured protocol error, not a re-apply.
        let stale = Request::Observe {
            task: "bwa".into(),
            execution: two_phase_exec(7000.0, &mut rng),
            dedup: Some(Dedup { nonce: "sess-a".into(), seq: 1 }),
        };
        match dispatch(stale, &client, &counters) {
            Dispatched::Error(e) => assert_eq!(e.code, ErrorCode::InvalidField),
            _ => panic!("stale seq must be rejected"),
        }
        assert_eq!(client.stats().observations, 2);
    }

    #[test]
    fn dedup_table_evicts_oldest_nonce_at_cap() {
        let mut table = DedupTable::default();
        let mut applies = 0u64;
        let apply = |t: &mut DedupTable, nonce: &str, seq: u64, applies: &mut u64| {
            t.serve(&Dedup { nonce: nonce.into(), seq }, || {
                *applies += 1;
                Response::Trained { task: nonce.into(), executions: seq }
            })
            .unwrap()
        };
        for i in 0..DEDUP_NONCE_CAP {
            apply(&mut table, &format!("n{i}"), 1, &mut applies);
        }
        assert_eq!(applies, DEDUP_NONCE_CAP as u64);
        // A replay inside the window is still served from cache...
        apply(&mut table, &format!("n{}", DEDUP_NONCE_CAP - 1), 1, &mut applies);
        assert_eq!(applies, DEDUP_NONCE_CAP as u64);
        // ...a new nonce evicts the oldest (n0), whose replay re-applies.
        apply(&mut table, "fresh", 1, &mut applies);
        assert_eq!(applies, DEDUP_NONCE_CAP as u64 + 1);
        apply(&mut table, "n0", 1, &mut applies);
        assert_eq!(applies, DEDUP_NONCE_CAP as u64 + 2);
        assert!(table.entries.len() <= DEDUP_NONCE_CAP);
        assert_eq!(table.entries.len(), table.order.len());
    }

    #[test]
    fn queue_depth_high_water_mark_is_a_max() {
        let c = ConnCounters::default();
        for depth in [3, 9, 4, 9, 1] {
            c.note_queue_depth(depth);
        }
        assert_eq!(c.queue_depth_max.load(Ordering::Relaxed), 9);
    }
}
