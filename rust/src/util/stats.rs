//! Small statistics toolkit shared by predictors, metrics, and reports.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0.0 for fewer than 2 points.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copy + sort).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, p in [0, 100]; 0.0 for empty input.
///
/// NaN samples are ignored (a NaN latency from a degenerate timestamp
/// must not poison — or, worse, abort — the service stats path), and the
/// sort uses `total_cmp`, which is total over all floats, instead of
/// `partial_cmp(..).unwrap()`, which panics on NaN.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    if v.len() == 1 {
        return v[0];
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

/// OLS closed form from sufficient statistics (n, Σx, Σy, Σx², Σxy):
/// returns (slope, intercept).
///
/// This is THE closed form of the crate: `ols` sums its inputs and
/// delegates here, the incremental `OlsStats` accumulators fit through
/// here, and the L1 Pallas `fit` kernel mirrors the same expression
/// (including the degenerate fallbacks) so native and PJRT backends
/// agree. Keeping one implementation is what makes batch training and
/// incremental observation bit-identical.
pub fn ols_from_sums(n: f64, sx: f64, sy: f64, sxx: f64, sxy: f64) -> (f64, f64) {
    if n == 0.0 {
        return (0.0, 0.0);
    }
    let denom = n * sxx - sx * sx;
    if n < 2.0 || denom.abs() < 1e-12 {
        return (0.0, sy / n);
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    (slope, intercept)
}

/// Simple OLS over (x, y) pairs: returns (slope, intercept).
pub fn ols(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    ols_from_sums(xs.len() as f64, sx, sy, sxx, sxy)
}

/// Residuals y - (a*x + b).
pub fn residuals(xs: &[f64], ys: &[f64], slope: f64, intercept: f64) -> Vec<f64> {
    xs.iter().zip(ys).map(|(x, y)| y - (slope * x + intercept)).collect()
}

/// Coefficient of determination R^2; 1.0 when total variance is zero.
pub fn r_squared(xs: &[f64], ys: &[f64], slope: f64, intercept: f64) -> f64 {
    let m = mean(ys);
    let ss_tot: f64 = ys.iter().map(|y| (y - m) * (y - m)).sum();
    if ss_tot < 1e-12 {
        return 1.0;
    }
    let ss_res: f64 =
        residuals(xs, ys, slope, intercept).iter().map(|r| r * r).sum();
    1.0 - ss_res / ss_tot
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn percentile_ignores_nan_without_panicking() {
        // Regression: partial_cmp(..).unwrap() aborted on NaN input.
        let xs = [3.0, f64::NAN, 1.0, 2.0, f64::NAN];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert!(median(&xs).is_finite());
        // All-NaN behaves like empty input.
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
        // Infinities are legitimate values and still sort.
        assert_eq!(percentile(&[f64::INFINITY, 1.0], 0.0), 1.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ols_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x - 1.0).collect();
        let (a, b) = ols(&xs, &ys);
        assert!((a - 2.5).abs() < 1e-9);
        assert!((b + 1.0).abs() < 1e-9);
    }

    #[test]
    fn ols_degenerate_single_point() {
        let (a, b) = ols(&[4.0], &[12.0]);
        assert_eq!((a, b), (0.0, 12.0));
    }

    #[test]
    fn ols_degenerate_constant_x() {
        let (a, b) = ols(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]);
        assert_eq!(a, 0.0);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ols_empty() {
        assert_eq!(ols(&[], &[]), (0.0, 0.0));
        assert_eq!(ols_from_sums(0.0, 0.0, 0.0, 0.0, 0.0), (0.0, 0.0));
    }

    #[test]
    fn ols_from_sums_matches_ols_bitwise() {
        // The pairwise form and the sufficient-statistics form must agree
        // bit for bit when the sums are accumulated in the same order.
        let xs = [3.0, 7.5, 1.25, 9.0, 2.0];
        let ys = [1.0, -2.0, 4.5, 0.25, 8.0];
        let (mut n, mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for (&x, &y) in xs.iter().zip(&ys) {
            n += 1.0;
            sx += x;
            sy += y;
            sxx += x * x;
            sxy += x * y;
        }
        assert_eq!(ols(&xs, &ys), ols_from_sums(n, sx, sy, sxx, sxy));
    }

    #[test]
    fn r_squared_perfect_and_flat() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        let (a, b) = ols(&xs, &ys);
        assert!((r_squared(&xs, &ys, a, b) - 1.0).abs() < 1e-9);
        let flat = [5.0, 5.0, 5.0];
        let (a2, b2) = ols(&xs, &flat);
        assert_eq!(r_squared(&xs, &flat, a2, b2), 1.0);
    }
}
