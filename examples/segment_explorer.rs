//! Segment explorer: visualise Algorithm 1 on a BWA trace as ASCII art,
//! comparing k values and greedy vs optimal segmentation.
//!
//! ```sh
//! cargo run --release --example segment_explorer -- 4
//! ```

use ksplus::segments::algorithm::{get_segments, optimal_segments};
use ksplus::trace::workflow::Workflow;

const WIDTH: usize = 100;
const HEIGHT: usize = 16;

fn render(samples: &[f64], plan_peaks: &[(usize, f64)], peak: f64) -> String {
    // plan_peaks: (start sample, level) pairs.
    let mut grid = vec![vec![' '; WIDTH]; HEIGHT];
    let n = samples.len();
    for col in 0..WIDTH {
        let idx = col * n / WIDTH;
        let h = ((samples[idx] / peak) * (HEIGHT - 1) as f64).round() as usize;
        for row in 0..=h.min(HEIGHT - 1) {
            grid[HEIGHT - 1 - row][col] = '.';
        }
    }
    // Overlay the plan as '#'.
    for col in 0..WIDTH {
        let idx = col * n / WIDTH;
        let level = plan_peaks
            .iter()
            .take_while(|(s, _)| *s <= idx)
            .last()
            .map(|(_, p)| *p)
            .unwrap_or(0.0);
        let h = ((level / peak) * (HEIGHT - 1) as f64).round() as usize;
        grid[HEIGHT - 1 - h.min(HEIGHT - 1)][col] = '#';
    }
    grid.into_iter().map(|row| row.into_iter().collect::<String>()).collect::<Vec<_>>().join("\n")
}

fn main() {
    let k: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let trace = Workflow::eager().generate(42, 200);
    let e = &trace.task("bwa").unwrap().executions[0];
    let peak = e.peak() * 1.05;

    println!(
        "BWA execution: {:.0} s, peak {:.1} GB, {} samples ('.' usage, '#' allocation)\n",
        e.duration(),
        e.peak(),
        e.samples.len()
    );

    for (name, seg) in [
        (format!("greedy k={k}"), get_segments(&e.samples, k)),
        (format!("optimal k={k}"), optimal_segments(&e.samples, k)),
    ] {
        let offsets = seg.start_offsets();
        let overlay: Vec<(usize, f64)> =
            offsets.iter().copied().zip(seg.peaks.iter().copied()).collect();
        println!("--- {name}: {} segments, envelope error {:.1} GB-samples ---",
            seg.peaks.len(),
            seg.envelope_error(&e.samples));
        println!("{}\n", render(&e.samples, &overlay, peak));
    }

    // Wastage vs k table.
    println!("wastage of the greedy plan vs k (this execution only):");
    for kk in 1..=8 {
        let seg = get_segments(&e.samples, kk);
        let plan = seg.to_plan(e.dt);
        println!("  k={kk}: {:>7.1} GBs", plan.wastage_gbs(e));
    }
}
