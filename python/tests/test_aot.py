"""AOT pipeline tests: bucket emission, manifest contents, and HLO-text
round-trip properties of every artifact `make artifacts` produces."""

from __future__ import annotations

import json
import os
import tempfile

import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ols


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Lower a miniature artifact set once (small buckets: fast)."""
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_all(out, b=8, n=16, pb=8)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return out, manifest


def test_manifest_buckets(built):
    _, m = built
    assert m["buckets"]["fit_b"] == 8
    assert m["buckets"]["fit_n"] == 16
    assert m["buckets"]["predict_b"] == 8
    assert m["buckets"]["plan_k"] == ols.PLAN_K
    assert m["buckets"]["fit_n_small"] == min(ols.FIT_N_SMALL, 16)
    assert m["block_b"] == ols.BLOCK_B


def test_all_entries_written(built):
    out, m = built
    names = {e["name"] for e in m["entries"]}
    # fit/fit_predict at both buckets + predict + wastage + plan_wastage
    assert any(n.startswith("fit_b8_n16") for n in names)
    assert any(n.startswith("fit_predict_b8") for n in names)
    assert any(n.startswith("predict_b8") for n in names)
    assert any(n.startswith("wastage_b8") for n in names)
    assert any(n.startswith("plan_wastage_b8") for n in names)
    for e in m["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), e["file"]
        text = open(path).read()
        assert text.startswith("HloModule"), e["file"]
        # ENTRY computation present and shapes plausible.
        assert "ENTRY" in text


def test_hlo_text_has_no_serialized_proto_markers(built):
    # The 64-bit-id proto problem only affects binary serialization; the
    # text must be plain ASCII HLO.
    out, m = built
    for e in m["entries"]:
        text = open(os.path.join(out, e["file"]), "rb").read()
        assert all(b < 128 for b in text[:1000]), "non-ASCII in HLO text"


def test_entry_shapes_recorded(built):
    _, m = built
    fit = next(e for e in m["entries"] if e["name"] == "fit_b8_n16")
    assert fit["inputs"] == [{"shape": [8, 16]}] * 3
    assert fit["outputs"] == [{"shape": [8, 2]}]


def test_small_bucket_matches_big_bucket_numerics():
    """The two observation buckets must compute identical coefficients
    for data that fits both."""
    import numpy as np

    rng = np.random.default_rng(1)
    b = 8
    xs = rng.uniform(0, 100, size=(b, 12)).astype(np.float32)
    ys = (3.0 * xs + 2.0).astype(np.float32)
    m = np.ones((b, 12), np.float32)

    def pad(arr, n):
        out = np.zeros((b, n), np.float32)
        out[:, :12] = arr
        return out

    small = model.fit_model(pad(xs, 16), pad(ys, 16), pad(m, 16))[0]
    big = model.fit_model(pad(xs, 64), pad(ys, 64), pad(m, 64))[0]
    # f32 reduction order differs between padded widths.
    np.testing.assert_allclose(np.asarray(small), np.asarray(big), rtol=1e-4, atol=1e-3)


def test_lowering_is_deterministic():
    spec = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    a = aot.to_hlo_text(jax.jit(model.fit_model).lower(spec, spec, spec))
    b = aot.to_hlo_text(jax.jit(model.fit_model).lower(spec, spec, spec))
    assert a == b
