//! Descriptive figures 1-5: the motivation and mechanism illustrations.

use anyhow::{Context, Result};

use crate::experiments::{report, ExpConfig, ExpOutput};
use crate::predictor::ksplus::KsPlus;
use crate::predictor::Predictor;
use crate::segments::algorithm::{get_segments, monotone_envelope};
use crate::trace::workflow::{summarize, Workflow};
use crate::trace::TaskTraces;
use crate::util::json::Json;
use crate::util::stats;

fn bwa_traces(cfg: &ExpConfig) -> Result<TaskTraces> {
    let wf = Workflow::eager();
    let trace = wf.generate(cfg.trace_seed, cfg.target_samples);
    trace.task("bwa").cloned().context("no bwa traces")
}

/// Fig 1a: distribution of BWA peak memory across executions.
pub fn fig1a(cfg: &ExpConfig) -> Result<ExpOutput> {
    let traces = bwa_traces(cfg)?;
    let peaks = traces.peaks();
    let mut table = report::Table::new(&["stat", "GB"]);
    let percentiles = [5.0, 25.0, 50.0, 75.0, 95.0];
    for p in percentiles {
        table.row(vec![format!("p{p:.0}"), report::f(stats::percentile(&peaks, p))]);
    }
    table.row(vec!["mean".into(), report::f(stats::mean(&peaks))]);
    let text = table.render("Fig 1a: BWA peak memory distribution")
        + &format!(
            "  median {:.1} GB (paper: ~10.6 GB); allocating the median would fail ~half the tasks\n\n",
            stats::median(&peaks)
        );
    Ok(ExpOutput {
        text,
        json: Json::obj(vec![("fig1a_peaks_gb", Json::arr_f64(&peaks))]),
    })
}

/// Fig 1b: a single BWA execution's memory over time.
pub fn fig1b(cfg: &ExpConfig) -> Result<ExpOutput> {
    let traces = bwa_traces(cfg)?;
    let e = &traces.executions[0];
    let peak = e.peak();
    let below70 =
        e.samples.iter().filter(|&&s| s < 0.7 * peak).count() as f64 / e.samples.len() as f64;
    // The green "wasted" area of the figure: flat peak allocation minus use.
    let flat_waste = crate::segments::StepPlan::flat(peak).wastage_gbs(e);
    let text = format!(
        "== Fig 1b: BWA memory over time (one execution) ==\n\
         duration {:.0} s, peak {:.1} GB, {:.0}% of runtime below 70% of peak\n\
         flat-peak allocation would waste {:.0} GBs on this run alone\n\n",
        e.duration(),
        peak,
        below70 * 100.0,
        flat_waste
    );
    Ok(ExpOutput {
        text,
        json: Json::obj(vec![
            ("dt", e.dt.into()),
            ("samples_gb", Json::arr_f64(&e.samples)),
            ("flat_waste_gbs", flat_waste.into()),
        ]),
    })
}

/// Fig 2: uniform vs variable two-segment model of one BWA execution.
pub fn fig2(cfg: &ExpConfig) -> Result<ExpOutput> {
    let traces = bwa_traces(cfg)?;
    let e = &traces.executions[0];
    // Variable segments (KS+, Algorithm 1).
    let seg = get_segments(&e.samples, 2);
    let variable = seg.to_plan(e.dt);
    // Uniform segments (k-Segments): equal halves, running-max peaks.
    let n = e.samples.len();
    let half_peak1 = e.samples[..n / 2].iter().cloned().fold(0.0, f64::max);
    let half_peak2 = e.samples[n / 2..].iter().cloned().fold(half_peak1, f64::max);
    let uniform = crate::segments::StepPlan::new(
        vec![0.0, (n / 2) as f64 * e.dt],
        vec![half_peak1, half_peak2],
    );
    let wu = uniform.wastage_gbs(e);
    let wv = variable.wastage_gbs(e);
    let text = format!(
        "== Fig 2: two-segment models of one BWA execution ==\n\
         uniform segments : boundary {:.0} s, peaks [{:.1}, {:.1}] GB, wastage {:.0} GBs\n\
         variable segments: boundary {:.0} s, peaks [{:.1}, {:.1}] GB, wastage {:.0} GBs\n\
         variable reduces single-run wastage by {:.0}%\n\n",
        uniform.starts[1],
        uniform.peaks[0],
        uniform.peaks[1],
        wu,
        variable.starts.get(1).copied().unwrap_or(0.0),
        variable.peaks[0],
        variable.peaks.get(1).copied().unwrap_or(variable.peaks[0]),
        wv,
        crate::metrics::relative_reduction(wv, wu) * 100.0
    );
    Ok(ExpOutput {
        text,
        json: Json::obj(vec![
            ("uniform_wastage_gbs", wu.into()),
            ("variable_wastage_gbs", wv.into()),
        ]),
    })
}

/// Fig 3: second-segment start time vs input size across BWA executions,
/// with the OLS estimate and the strongest "ran much faster" outlier.
pub fn fig3(cfg: &ExpConfig) -> Result<ExpOutput> {
    let traces = bwa_traces(cfg)?;
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for e in &traces.executions {
        let seg = get_segments(&e.samples, 2);
        if seg.sizes.len() == 2 {
            xs.push(e.input_mb);
            ys.push(seg.sizes[0] as f64 * e.dt);
        }
    }
    let (slope, intercept) = stats::ols(&xs, &ys);
    let r2 = stats::r_squared(&xs, &ys, slope, intercept);
    let resid = stats::residuals(&xs, &ys, slope, intercept);
    // Heteroscedasticity: residual spread by input-size tercile.
    let mut order: Vec<usize> = (0..xs.len()).collect();
    order.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let third = order.len() / 3;
    let spread = |idx: &[usize]| {
        stats::stddev(&idx.iter().map(|&i| resid[i]).collect::<Vec<_>>())
    };
    let lo = spread(&order[..third]);
    let hi = spread(&order[order.len() - third..]);
    // The red cross: most negative relative residual (much faster run).
    let outlier = (0..xs.len())
        .min_by(|&a, &b| (resid[a] / ys[a]).total_cmp(&(resid[b] / ys[b])))
        .unwrap();
    let trend = if hi > lo { "grows with input, as in the paper" } else { "noisy at this n" };
    let text = format!(
        "== Fig 3: 2nd-segment start vs input size (BWA) ==\n\
         OLS: start = {slope:.4} * input + {intercept:.1}  (R^2 = {r2:.3}, n = {})\n\
         residual sigma: smallest-inputs tercile {lo:.1} s, largest {hi:.1} s ({trend})\n\
         outlier: input {:.0} MB ran at {:.0} s vs predicted {:.0} s ({}% faster)\n\n",
        xs.len(),
        xs[outlier],
        ys[outlier],
        slope * xs[outlier] + intercept,
        (-100.0 * resid[outlier] / (slope * xs[outlier] + intercept)) as i64,
    );
    Ok(ExpOutput {
        text,
        json: Json::obj(vec![
            ("inputs_mb", Json::arr_f64(&xs)),
            ("second_segment_start_s", Json::arr_f64(&ys)),
            ("slope", slope.into()),
            ("intercept", intercept.into()),
            ("r2", r2.into()),
            ("outlier_index", outlier.into()),
        ]),
    })
}

/// Fig 4: the retry strategy on the Fig 3 outlier — the predicted plan
/// fails because the second phase arrives early; the rescaled retry
/// covers it.
pub fn fig4(cfg: &ExpConfig) -> Result<ExpOutput> {
    let traces = bwa_traces(cfg)?;
    // Train KS+ on all executions, then find a test execution whose plan
    // fails mid-run (reaching the demanding segment early).
    let mut pred = KsPlus::new(2, cfg.capacity_gb);
    pred.train(&traces.executions);
    let mut chosen = None;
    for e in &traces.executions {
        let plan = pred.plan(e.input_mb);
        if let Some((t, u)) = plan.first_oom(e) {
            if plan.segment_at(t) + 1 < plan.k() {
                chosen = Some((e, plan, t, u));
                break;
            }
        }
    }
    let Some((e, plan, t_fail, _)) = chosen else {
        return Ok(ExpOutput {
            text: "== Fig 4: no mid-run failure found (offsets covered everything) ==\n\n"
                .into(),
            json: Json::obj(vec![("fig4", Json::Null)]),
        });
    };
    // Apply the retry strategy as the simulator would, until covered.
    let mut retry = pred.on_failure(&plan, t_fail, 1);
    let mut retries = 1;
    while let Some((t, _)) = retry.first_oom(e) {
        if retries >= 10 {
            break;
        }
        retry = pred.on_failure(&retry, t, retries + 1);
        retries += 1;
    }
    let covered = retry.covers(e);
    let text = format!(
        "== Fig 4: KS+ retry on an early-phase-change execution ==\n\
         first plan : starts {:?} peaks {:?}\n\
         OOM at {t_fail:.0} s (segment boundary predicted at {:.0} s)\n\
         retry plan : starts {:?} peaks {:?}  -> covers execution: {covered}\n\n",
        plan.starts.iter().map(|s| (s * 10.0).round() / 10.0).collect::<Vec<_>>(),
        plan.peaks.iter().map(|p| (p * 10.0).round() / 10.0).collect::<Vec<_>>(),
        plan.starts.get(1).copied().unwrap_or(0.0),
        retry.starts.iter().map(|s| (s * 10.0).round() / 10.0).collect::<Vec<_>>(),
        retry.peaks.iter().map(|p| (p * 10.0).round() / 10.0).collect::<Vec<_>>(),
    );
    Ok(ExpOutput {
        text,
        json: Json::obj(vec![
            ("fail_time_s", t_fail.into()),
            ("first_plan_starts", Json::arr_f64(&plan.starts)),
            ("retry_plan_starts", Json::arr_f64(&retry.starts)),
            ("retry_covers", covered.into()),
        ]),
    })
}

/// Fig 5: workflow overview — instances and peak statistics per task.
/// Under `--trace` the table describes the ingested CSV instead of the
/// synthetic workflows (no paper reference value in that case).
pub fn fig5(cfg: &ExpConfig) -> Result<ExpOutput> {
    let mut text = String::new();
    let mut json_rows = Vec::new();
    for (_wf, trace, label) in crate::experiments::eval_traces(cfg)? {
        let mut table =
            report::Table::new(&["task", "instances", "mean peak", "median", "max"]);
        for s in summarize(&trace) {
            table.row(vec![
                s.task.clone(),
                s.instances.to_string(),
                report::f(s.mean_peak_gb),
                report::f(s.median_peak_gb),
                report::f(s.max_peak_gb),
            ]);
            json_rows.push(Json::obj(vec![
                ("workflow", label.into()),
                ("task", s.task.clone().into()),
                ("instances", s.instances.into()),
                ("mean_peak_gb", s.mean_peak_gb.into()),
            ]));
        }
        text.push_str(&table.render(&format!("Fig 5 ({label})")));
        let paper = match label {
            "eager" => " (paper: 2.31 GB)",
            "sarek" => " (paper: 1.67 GB)",
            _ => "",
        };
        text.push_str(&format!(
            "  {} instances total, workflow mean peak {:.2} GB{paper}\n\n",
            trace.total_instances(),
            trace.mean_peak(),
        ));
    }
    Ok(ExpOutput { text, json: Json::obj(vec![("fig5", Json::Arr(json_rows))]) })
}

/// Helper used by fig2/fig3 tests: envelope area of a series.
pub fn envelope_area(samples: &[f64], dt: f64) -> f64 {
    monotone_envelope(samples).iter().sum::<f64>() * dt
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExpConfig {
        ExpConfig::default()
    }

    #[test]
    fn fig1a_median_near_paper() {
        let out = fig1a(&cfg()).unwrap();
        assert!(out.text.contains("Fig 1a"));
        let peaks = out.json.get("fig1a_peaks_gb").unwrap().as_arr().unwrap();
        assert_eq!(peaks.len(), 60);
        let vals: Vec<f64> = peaks.iter().map(|j| j.as_f64().unwrap()).collect();
        let med = stats::median(&vals);
        assert!((med - 10.6).abs() < 1.8, "median {med}");
    }

    #[test]
    fn fig1b_shows_plateau() {
        let out = fig1b(&cfg()).unwrap();
        assert!(out.text.contains("below 70% of peak"));
        assert!(out.json.get("flat_waste_gbs").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn fig2_variable_beats_uniform() {
        let out = fig2(&cfg()).unwrap();
        let wu = out.json.get("uniform_wastage_gbs").unwrap().as_f64().unwrap();
        let wv = out.json.get("variable_wastage_gbs").unwrap().as_f64().unwrap();
        assert!(wv <= wu, "variable {wv} > uniform {wu}");
    }

    #[test]
    fn fig3_regression_positive_slope() {
        let out = fig3(&cfg()).unwrap();
        assert!(out.json.get("slope").unwrap().as_f64().unwrap() > 0.0);
        assert!(out.json.get("r2").unwrap().as_f64().unwrap() > 0.3);
    }

    #[test]
    fn fig4_retry_covers() {
        let out = fig4(&cfg()).unwrap();
        // Either no failure was found (fine) or the retry must cover.
        if let Some(c) = out.json.get("retry_covers") {
            assert_eq!(c.as_bool(), Some(true));
        }
    }

    #[test]
    fn fig5_statistics_near_paper() {
        let out = fig5(&cfg()).unwrap();
        assert!(out.text.contains("Fig 5 (eager)"));
        assert!(out.text.contains("Fig 5 (sarek)"));
    }
}
