//! Trace substrate: memory-over-time observations of workflow task
//! executions.
//!
//! The paper's evaluation consumes traces of two nf-core workflows (eager,
//! sarek) published with the original k-Segments paper. Those traces are
//! not shipped here, so `synth` provides parametric generators whose
//! archetypes reproduce the statistics the paper reports (see DESIGN.md
//! Section 5 for the substitution argument). Everything downstream —
//! segmentation, predictors, simulator, experiments — only sees the types
//! in this module and is agnostic to trace provenance; `io` can load
//! externally recorded traces in the same CSV shape.

pub mod io;
pub mod nextflow;
pub mod synth;
pub mod workflow;

/// Units used throughout the crate:
/// memory = GB, time = seconds, input size = MB, wastage = GB*s.
pub const GB: f64 = 1.0;

/// One monitored execution of one task instance: a fixed-interval memory
/// time series plus the aggregated input file size that drives prediction.
#[derive(Debug, Clone, PartialEq)]
pub struct Execution {
    /// Task type name (e.g. "bwa").
    pub task: String,
    /// Aggregated size of all input files, MB.
    pub input_mb: f64,
    /// Sampling interval, seconds.
    pub dt: f64,
    /// Memory usage in GB at t = i * dt.
    pub samples: Vec<f64>,
}

impl Execution {
    pub fn new(task: impl Into<String>, input_mb: f64, dt: f64, samples: Vec<f64>) -> Self {
        Execution { task: task.into(), input_mb, dt, samples }
    }

    /// Copy `src` into `self`, reusing the existing `task`/`samples`
    /// buffers. High-volume replay loops (the scenario engine) use this
    /// so a million copies allocate nothing after warm-up.
    pub fn copy_from(&mut self, src: &Execution) {
        self.task.clear();
        self.task.push_str(&src.task);
        self.input_mb = src.input_mb;
        self.dt = src.dt;
        self.samples.clear();
        self.samples.extend_from_slice(&src.samples);
    }

    /// Wall-clock duration covered by the samples.
    pub fn duration(&self) -> f64 {
        self.samples.len() as f64 * self.dt
    }

    /// Peak memory over the whole execution, GB.
    pub fn peak(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    /// Memory usage at time `t` (seconds); clamps to the series bounds.
    pub fn usage_at(&self, t: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let idx = (t / self.dt).floor() as usize;
        self.samples[idx.min(self.samples.len() - 1)]
    }

    /// Integral of usage over the execution, GB*s.
    pub fn used_gbs(&self) -> f64 {
        self.samples.iter().sum::<f64>() * self.dt
    }
}

/// All recorded executions of one task type.
#[derive(Debug, Clone, Default)]
pub struct TaskTraces {
    pub task: String,
    pub executions: Vec<Execution>,
}

impl TaskTraces {
    pub fn peaks(&self) -> Vec<f64> {
        self.executions.iter().map(|e| e.peak()).collect()
    }

    pub fn input_sizes(&self) -> Vec<f64> {
        self.executions.iter().map(|e| e.input_mb).collect()
    }
}

/// A full workflow trace: one `TaskTraces` per task type.
#[derive(Debug, Clone, Default)]
pub struct WorkflowTrace {
    pub name: String,
    pub tasks: Vec<TaskTraces>,
}

impl WorkflowTrace {
    pub fn task(&self, name: &str) -> Option<&TaskTraces> {
        self.tasks.iter().find(|t| t.task == name)
    }

    pub fn total_instances(&self) -> usize {
        self.tasks.iter().map(|t| t.executions.len()).sum()
    }

    /// Mean peak memory over all task instances (the Fig 5 statistic).
    pub fn mean_peak(&self) -> f64 {
        let peaks: Vec<f64> =
            self.tasks.iter().flat_map(|t| t.peaks()).collect();
        crate::util::stats::mean(&peaks)
    }
}

/// Deterministic train/test split of one task's executions.
///
/// `train_frac` in (0,1); mirrors the paper's 25/50/75 % splits with a
/// fresh shuffle per seed (10 seeds per experiment).
pub fn split_train_test(
    traces: &TaskTraces,
    train_frac: f64,
    rng: &mut crate::util::rng::Rng,
) -> (Vec<Execution>, Vec<Execution>) {
    let n = traces.executions.len();
    let n_train = ((n as f64 * train_frac).round() as usize).clamp(1, n.saturating_sub(1).max(1));
    let idx = rng.sample_indices(n, n);
    let mut train = Vec::with_capacity(n_train);
    let mut test = Vec::with_capacity(n - n_train);
    for (pos, &i) in idx.iter().enumerate() {
        if pos < n_train {
            train.push(traces.executions[i].clone());
        } else {
            test.push(traces.executions[i].clone());
        }
    }
    (train, test)
}

/// Load a trace CSV of either supported shape, sniffing the header line:
/// the nf-core long-form monitoring export (`nextflow::HEADER`) or the
/// crate's internal per-execution format (`io::CSV_HEADER`).
pub fn load_csv_auto(path: &std::path::Path, name: &str) -> anyhow::Result<WorkflowTrace> {
    use anyhow::Context;
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut first = String::new();
    std::io::BufRead::read_line(&mut std::io::BufReader::new(f), &mut first)
        .with_context(|| format!("read {}", path.display()))?;
    let first = first.trim();
    if first == nextflow::HEADER {
        nextflow::read_long_csv(path, name)
    } else if first == io::CSV_HEADER {
        io::read_csv(path, name)
    } else {
        anyhow::bail!(
            "unrecognised trace header in {}: '{first}' (expected '{}' or '{}')",
            path.display(),
            nextflow::HEADER,
            io::CSV_HEADER
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn exec(samples: Vec<f64>, dt: f64) -> Execution {
        Execution::new("t", 100.0, dt, samples)
    }

    #[test]
    fn duration_and_peak() {
        let e = exec(vec![1.0, 2.0, 5.0, 3.0], 2.0);
        assert_eq!(e.duration(), 8.0);
        assert_eq!(e.peak(), 5.0);
    }

    #[test]
    fn usage_at_clamps() {
        let e = exec(vec![1.0, 2.0, 3.0], 1.0);
        assert_eq!(e.usage_at(0.0), 1.0);
        assert_eq!(e.usage_at(1.5), 2.0);
        assert_eq!(e.usage_at(99.0), 3.0);
    }

    #[test]
    fn used_gbs_integral() {
        let e = exec(vec![2.0, 2.0, 4.0], 0.5);
        assert!((e.used_gbs() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_execution_safe() {
        let e = exec(vec![], 1.0);
        assert_eq!(e.peak(), 0.0);
        assert_eq!(e.usage_at(3.0), 0.0);
        assert_eq!(e.used_gbs(), 0.0);
    }

    #[test]
    fn copy_from_reuses_buffers() {
        let src = Execution::new("bwa", 8000.0, 0.5, vec![1.0, 2.0, 3.0]);
        let mut dst = Execution::new("longer-name-than-bwa", 1.0, 1.0, vec![9.0; 64]);
        let cap_before = dst.samples.capacity();
        dst.copy_from(&src);
        assert_eq!(dst, src);
        assert_eq!(dst.samples.capacity(), cap_before, "copy must reuse the sample buffer");
    }

    #[test]
    fn load_csv_auto_rejects_unknown_header() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ksplus_auto_hdr_{}.csv", std::process::id()));
        std::fs::write(&path, "who,knows\n1,2\n").unwrap();
        let err = load_csv_auto(&path, "x").unwrap_err().to_string();
        assert!(err.contains("unrecognised trace header"), "{err}");
        std::fs::remove_file(&path).ok();
        assert!(load_csv_auto(std::path::Path::new("/nonexistent/x.csv"), "x").is_err());
    }

    #[test]
    fn split_sizes() {
        let traces = TaskTraces {
            task: "t".into(),
            executions: (0..40).map(|i| exec(vec![i as f64], 1.0)).collect(),
        };
        let mut rng = Rng::new(1);
        let (train, test) = split_train_test(&traces, 0.25, &mut rng);
        assert_eq!(train.len(), 10);
        assert_eq!(test.len(), 30);
    }

    #[test]
    fn split_partitions_without_overlap() {
        let traces = TaskTraces {
            task: "t".into(),
            executions: (0..20).map(|i| exec(vec![i as f64], 1.0)).collect(),
        };
        let mut rng = Rng::new(5);
        let (train, test) = split_train_test(&traces, 0.5, &mut rng);
        let mut all: Vec<f64> =
            train.iter().chain(&test).map(|e| e.samples[0]).collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, (0..20).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn split_differs_across_seeds() {
        let traces = TaskTraces {
            task: "t".into(),
            executions: (0..30).map(|i| exec(vec![i as f64], 1.0)).collect(),
        };
        let (a, _) = split_train_test(&traces, 0.5, &mut Rng::new(1));
        let (b, _) = split_train_test(&traces, 0.5, &mut Rng::new(2));
        let av: Vec<f64> = a.iter().map(|e| e.samples[0]).collect();
        let bv: Vec<f64> = b.iter().map(|e| e.samples[0]).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn mean_peak_aggregates() {
        let wf = WorkflowTrace {
            name: "w".into(),
            tasks: vec![
                TaskTraces { task: "a".into(), executions: vec![exec(vec![1.0], 1.0)] },
                TaskTraces { task: "b".into(), executions: vec![exec(vec![3.0], 1.0)] },
            ],
        };
        assert_eq!(wf.mean_peak(), 2.0);
        assert_eq!(wf.total_instances(), 2);
    }
}
