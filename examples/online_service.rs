//! End-to-end driver (EXPERIMENTS.md §E2E): the full three-layer stack
//! on a realistic serving workload.
//!
//! 1. Build traces for both nf-core workflows (the "historical runs").
//! 2. Start the coordinator with the **PJRT backend**: batched plan
//!    prediction executes the AOT-compiled Pallas kernels
//!    (`artifacts/*.hlo.txt`) — Python is never invoked. (Training is
//!    incremental sufficient-statistics OLS and always runs in-process.)
//! 3. Train models for all 21 task types.
//! 4. Replay both workflows in DAG order from 8 concurrent submitter
//!    threads: request a plan per instance, simulate the execution
//!    against its trace, report OOMs back, retry until success, then
//!    `observe` the finished execution back into the models.
//! 5. Report end-to-end latency percentiles, plan throughput, batching
//!    efficiency, and total wastage vs a peak-only strategy.
//!
//! ```sh
//! make artifacts && cargo run --release --example online_service
//! ```
//! (Falls back to the native backend when artifacts are missing.)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
use ksplus::coordinator::{BackendSpec, PredictorPolicy};
use ksplus::trace::workflow::Workflow;
use ksplus::trace::Execution;

/// PJRT when compiled in and artifacts exist, else the native backend.
#[cfg(feature = "pjrt")]
fn backend_spec() -> BackendSpec {
    let dir = ksplus::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        println!("backend: PJRT (artifacts from {})", dir.display());
        BackendSpec::Pjrt(Some(dir))
    } else {
        println!("backend: native (artifacts not built; run `make artifacts`)");
        BackendSpec::Native
    }
}

#[cfg(not(feature = "pjrt"))]
fn backend_spec() -> BackendSpec {
    println!("backend: native (built without the 'pjrt' feature)");
    BackendSpec::Native
}

fn main() -> anyhow::Result<()> {
    // --- 1. historical traces + live workload ---------------------------
    let workflows = [Workflow::eager(), Workflow::sarek()];
    let history: Vec<_> = workflows.iter().map(|wf| wf.generate(42, 200)).collect();
    let live: Vec<_> = workflows.iter().map(|wf| wf.generate(1337, 200)).collect();

    // --- 2. coordinator with the best available backend -----------------
    // KSPLUS_SHARDS widens the worker pool (default 1); backend build
    // errors surface here instead of killing a detached worker thread.
    let shards: usize = match std::env::var("KSPLUS_SHARDS") {
        Err(_) => 1,
        Ok(s) => s
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid KSPLUS_SHARDS value '{s}'"))?,
    };
    // KSPLUS_POLICY picks the predictor policy every task trains under
    // (default ksplus) — the same seam `repro serve --policy` exposes.
    let policy = match std::env::var("KSPLUS_POLICY") {
        Err(_) => PredictorPolicy::KsPlus,
        Ok(s) => PredictorPolicy::parse(s.trim()).ok_or_else(|| {
            anyhow::anyhow!(
                "invalid KSPLUS_POLICY '{s}' (valid: {})",
                PredictorPolicy::names().join(", ")
            )
        })?,
    };
    println!("coordinator shards: {shards}, predictor policy: {}", policy.name());
    let coord = Coordinator::start(
        CoordinatorConfig { shards, default_policy: policy, ..Default::default() },
        backend_spec(),
    )?;
    let client = coord.client();

    // --- 3. train all task types ----------------------------------------
    let t0 = Instant::now();
    let mut n_models = 0;
    for hist in &history {
        for t in &hist.tasks {
            client.train(&t.task, t.executions.clone());
            n_models += 1;
        }
    }
    println!("trained {n_models} task models in {:.0} ms", t0.elapsed().as_secs_f64() * 1e3);

    // --- 4. replay the live workload in DAG order -----------------------
    // Tasks of each workflow are submitted stage by stage (topological
    // order), all instances of a stage in parallel across 8 threads.
    let oom_reports = Arc::new(AtomicUsize::new(0));
    let mut wastage_ks = 0.0f64;
    let mut served = 0usize;
    let t0 = Instant::now();
    for (wf, lv) in workflows.iter().zip(&live) {
        for stage in wf.topo_order() {
            let execs: Vec<Execution> = lv.task(stage).unwrap().executions.clone();
            let chunks: Vec<Vec<Execution>> = execs
                .chunks(execs.len().div_ceil(8).max(1))
                .map(|c| c.to_vec())
                .collect();
            let mut handles = Vec::new();
            for chunk in chunks {
                let c = coord.client();
                let ooms = oom_reports.clone();
                handles.push(std::thread::spawn(move || {
                    let mut wastage = 0.0f64;
                    for e in &chunk {
                        // Plan -> simulate -> report failures until done.
                        let mut plan = c.plan(&e.task, e.input_mb);
                        let mut attempts = 0;
                        loop {
                            attempts += 1;
                            match plan.first_oom(e) {
                                None => {
                                    wastage += plan.wastage_gbs(e);
                                    break;
                                }
                                Some((t_fail, _)) => {
                                    wastage += plan.alloc_gbs(t_fail.max(e.dt));
                                    ooms.fetch_add(1, Ordering::Relaxed);
                                    if attempts > 10 {
                                        break;
                                    }
                                    // Route the retry through the task's
                                    // bound policy (KS+ rescaling by
                                    // default, doubling for witt-lr, ...).
                                    plan = c
                                        .report_failure_for(Some(&e.task), &plan, t_fail)
                                        .plan;
                                }
                            }
                        }
                        // Close the loop: the execution is finished and
                        // fully monitored — fold it into the task's
                        // models (O(k) incremental update), exactly what
                        // a workflow engine does as tasks complete.
                        c.observe(&e.task, e.clone());
                    }
                    wastage
                }));
            }
            for h in handles {
                wastage_ks += h.join().unwrap();
            }
            served += execs.len();
        }
    }
    let serve_wall = t0.elapsed();

    // --- 5. report -------------------------------------------------------
    let stats = client.stats();
    println!("\n== end-to-end results ==");
    println!("instances served    : {served}");
    println!("wall time           : {:.2} s", serve_wall.as_secs_f64());
    println!(
        "plan throughput     : {:.0} plans/s",
        stats.requests as f64 / serve_wall.as_secs_f64()
    );
    println!(
        "batching            : {} batches, mean size {:.1}",
        stats.batches,
        stats.mean_batch_size()
    );
    println!(
        "plan latency        : p50 {:.0} us  p95 {:.0} us  p99 {:.0} us",
        stats.latency_percentile_us(50.0),
        stats.latency_percentile_us(95.0),
        stats.latency_percentile_us(99.0)
    );
    println!("OOM reports handled : {}", oom_reports.load(Ordering::Relaxed));
    println!("observations folded : {}", stats.observations);
    println!("fallback plans      : {}", stats.fallbacks);
    println!("KS+ wastage         : {wastage_ks:.0} GBs");

    // Baseline comparison: peak-only (max historic peak + 10 %).
    let mut wastage_flat = 0.0f64;
    for (hist, lv) in history.iter().zip(&live) {
        for t in &lv.tasks {
            let peak = hist
                .task(&t.task)
                .map(|h| h.peaks().iter().cloned().fold(0.0, f64::max))
                .unwrap_or(4.0);
            let plan = ksplus::segments::StepPlan::flat((peak * 1.1).min(128.0));
            for e in &t.executions {
                wastage_flat += if plan.covers(e) {
                    plan.wastage_gbs(e)
                } else {
                    plan.alloc_gbs(e.duration()) + 128.0 * e.duration()
                };
            }
        }
    }
    println!("flat-peak wastage   : {wastage_flat:.0} GBs");
    println!(
        "reduction           : {:.0}%",
        (1.0 - wastage_ks / wastage_flat) * 100.0
    );
    Ok(())
}
