//! Regression backend for the segment predictors.
//!
//! `FitEngine` abstracts where the batched OLS runs: `NativeFit` computes
//! the closed form in-process (always available; used by the offline
//! experiment harness and native-only builds); with the `pjrt` cargo
//! feature, `runtime::PjrtFitEngine` executes the AOT Pallas kernel
//! instead. Both implement the *same* closed form — `runtime::tests`
//! asserts parity when artifacts exist.
//!
//! Two shapes matter for the hot paths:
//!   * `fit_shared` — KS+ fits 2k regressions over ONE shared x-column
//!     (the input sizes); the shared x-statistics are computed once
//!     instead of cloning the column per row.
//!   * `OlsStats` — per-regression sufficient statistics
//!     (n, Σx, Σy, Σx², Σxy) that make training *incremental*: folding a
//!     new observation is O(1) and refitting is O(1), so the coordinator
//!     can `observe` one execution in O(k) without touching history.

use crate::util::stats;

/// One fitted affine model y = slope * x + intercept.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinModel {
    pub slope: f64,
    pub intercept: f64,
}

impl LinModel {
    pub fn predict(&self, x: f64) -> f64 {
        self.slope * x + self.intercept
    }

    pub fn fit(xs: &[f64], ys: &[f64]) -> LinModel {
        let (slope, intercept) = stats::ols(xs, ys);
        LinModel { slope, intercept }
    }

    /// Fit from accumulated sufficient statistics. Because the sums are
    /// folded in observation order and the closed form
    /// (`stats::ols_from_sums`) is shared with `fit`, a fold of
    /// `OlsStats::push` over a history produces a bit-identical model to
    /// one batch `fit` over the same history.
    pub fn from_stats(s: &OlsStats) -> LinModel {
        let (slope, intercept) = stats::ols_from_sums(s.n, s.sx, s.sy, s.sxx, s.sxy);
        LinModel { slope, intercept }
    }
}

/// Sufficient statistics of one OLS problem: everything the closed form
/// needs, in O(1) space regardless of how many observations were folded.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OlsStats {
    pub n: f64,
    pub sx: f64,
    pub sy: f64,
    pub sxx: f64,
    pub sxy: f64,
}

impl OlsStats {
    /// Fold one observation. O(1).
    pub fn push(&mut self, x: f64, y: f64) {
        self.n += 1.0;
        self.sx += x;
        self.sy += y;
        self.sxx += x * x;
        self.sxy += x * y;
    }

    /// Closed-form fit of the accumulated statistics. O(1).
    pub fn fit(&self) -> LinModel {
        LinModel::from_stats(self)
    }
}

/// A batch of independent OLS problems.
///
/// Deliberately NOT `Send`/`Sync`: the PJRT engine wraps thread-affine
/// FFI handles; the coordinator owns its engine on one worker thread.
pub trait FitEngine {
    /// General form: each row is an independent (xs, ys) problem.
    fn fit_batch(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Vec<LinModel>;

    /// Many regressions sharing ONE x-column (KS+: 2k rows over the same
    /// input sizes). The default materializes owned rows for engines
    /// that need the per-row layout (PJRT buckets); `NativeFit`
    /// overrides it to compute the shared x-statistics exactly once.
    fn fit_shared(&self, xs: &[f64], ys: &[Vec<f64>]) -> Vec<LinModel> {
        let rows: Vec<(Vec<f64>, Vec<f64>)> =
            ys.iter().map(|col| (xs.to_vec(), col.clone())).collect();
        self.fit_batch(&rows)
    }
}

/// In-process closed-form OLS.
#[derive(Debug, Default, Clone)]
pub struct NativeFit;

impl FitEngine for NativeFit {
    fn fit_batch(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Vec<LinModel> {
        rows.iter().map(|(xs, ys)| LinModel::fit(xs, ys)).collect()
    }

    fn fit_shared(&self, xs: &[f64], ys: &[Vec<f64>]) -> Vec<LinModel> {
        // Shared x-statistics once, per-column y-statistics per model.
        // Sum order matches `stats::ols` exactly, so results are
        // bit-identical to fitting each (xs, col) pair independently.
        let n = xs.len() as f64;
        let sx: f64 = xs.iter().sum();
        let sxx: f64 = xs.iter().map(|x| x * x).sum();
        ys.iter()
            .map(|col| {
                debug_assert_eq!(col.len(), xs.len());
                let sy: f64 = col.iter().sum();
                let sxy: f64 = xs.iter().zip(col).map(|(x, y)| x * y).sum();
                let (slope, intercept) = stats::ols_from_sums(n, sx, sy, sxx, sxy);
                LinModel { slope, intercept }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn fit_exact_line() {
        let xs = [1.0, 2.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| -0.5 * x + 3.0).collect();
        let m = LinModel::fit(&xs, &ys);
        assert!((m.slope + 0.5).abs() < 1e-9);
        assert!((m.intercept - 3.0).abs() < 1e-9);
        assert!((m.predict(10.0) + 2.0).abs() < 1e-9);
    }

    #[test]
    fn batch_matches_individual() {
        let rows = vec![
            (vec![1.0, 2.0, 3.0], vec![2.0, 4.0, 6.0]),
            (vec![0.0, 1.0], vec![5.0, 5.0]),
            (vec![7.0], vec![3.0]),
        ];
        let batch = NativeFit.fit_batch(&rows);
        for (i, (xs, ys)) in rows.iter().enumerate() {
            assert_eq!(batch[i], LinModel::fit(xs, ys));
        }
    }

    #[test]
    fn shared_matches_per_row_bitwise() {
        // fit_shared must be indistinguishable from fitting each column
        // against the shared xs independently — bit for bit.
        let xs = vec![10.0, 25.0, 3.5, 40.0, 17.0, 8.25];
        let cols: Vec<Vec<f64>> = vec![
            xs.iter().map(|x| 2.0 * x + 1.0).collect(),
            xs.iter().map(|x| -0.25 * x + 9.0).collect(),
            vec![4.0; xs.len()],
            xs.iter().map(|x| x * x * 0.01).collect(),
        ];
        let shared = NativeFit.fit_shared(&xs, &cols);
        assert_eq!(shared.len(), cols.len());
        for (m, col) in shared.iter().zip(&cols) {
            assert_eq!(*m, LinModel::fit(&xs, col));
        }
    }

    #[test]
    fn shared_default_impl_matches_override() {
        // An engine relying on the trait's default fit_shared (row
        // materialization) must agree with NativeFit's override.
        struct ViaRows;
        impl FitEngine for ViaRows {
            fn fit_batch(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Vec<LinModel> {
                NativeFit.fit_batch(rows)
            }
        }
        let xs = vec![1.0, 4.0, 9.0, 16.0];
        let cols = vec![vec![1.0, 2.0, 3.0, 4.0], vec![0.5, 0.5, 0.5, 0.5]];
        assert_eq!(ViaRows.fit_shared(&xs, &cols), NativeFit.fit_shared(&xs, &cols));
    }

    #[test]
    fn stats_fold_matches_batch_fit_bitwise() {
        run_prop("ols_stats_fold", 150, |rng| {
            let n = rng.below(40);
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 5000.0)).collect();
            let ys: Vec<f64> =
                xs.iter().map(|x| 0.003 * x + rng.normal_ms(2.0, 1.0)).collect();
            let mut st = OlsStats::default();
            for (&x, &y) in xs.iter().zip(&ys) {
                st.push(x, y);
            }
            // Exact equality: same sums in the same order, same closed form.
            assert_eq!(st.fit(), LinModel::fit(&xs, &ys));
        });
    }

    #[test]
    fn stats_degenerate_cases_match_fit() {
        // Empty, single point, constant x — every degenerate branch of
        // the closed form must agree between the two entry points.
        let cases: Vec<(Vec<f64>, Vec<f64>)> = vec![
            (vec![], vec![]),
            (vec![4.0], vec![12.0]),
            (vec![3.0, 3.0, 3.0], vec![1.0, 2.0, 3.0]),
        ];
        for (xs, ys) in cases {
            let mut st = OlsStats::default();
            for (&x, &y) in xs.iter().zip(&ys) {
                st.push(x, y);
            }
            assert_eq!(st.fit(), LinModel::fit(&xs, &ys), "case {xs:?}");
        }
    }

    #[test]
    fn prop_fit_residuals_sum_to_zero() {
        // OLS with intercept has zero mean residual.
        run_prop("ols_residual_zero", 150, |rng| {
            let n = 2 + rng.below(30);
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 100.0)).collect();
            let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + rng.normal_ms(0.0, 5.0)).collect();
            let m = LinModel::fit(&xs, &ys);
            let mean_resid = xs
                .iter()
                .zip(&ys)
                .map(|(x, y)| y - m.predict(*x))
                .sum::<f64>()
                / n as f64;
            assert!(mean_resid.abs() < 1e-6, "mean residual {mean_resid}");
        });
    }
}
