//! `RemoteClient`: typed TCP client for the coordinator wire — the
//! counterpart of the in-process `service::Client`, sharing the exact
//! `Request`/`Response` types of `coordinator::protocol` with the
//! server, so client and server cannot drift.
//!
//! Every connection starts on wire v1 (newline-delimited JSON).
//! [`RemoteClient::negotiate`] offers the server a higher version; when
//! the server grants wire v2, the connection switches to the
//! length-prefixed binary framing of `coordinator::wire` for everything
//! after the hello response. Either way the typed surface is identical
//! — the codec is connection state, not API.
//!
//! One request/response pair per call, or [`RemoteClient::pipeline`]
//! to ship a batch of requests in one write and collect their responses
//! in order. Server-side errors surface as the structured `WireError`
//! (`code: message` via its `Display`) wrapped in `anyhow::Error`.
//!
//! ```no_run
//! # use ksplus::coordinator::remote::RemoteClient;
//! # use ksplus::coordinator::PredictorPolicy;
//! # fn main() -> anyhow::Result<()> {
//! let mut rc = RemoteClient::connect("127.0.0.1:7070")?;
//! let info = rc.negotiate(2)?; // binary wire when the server has it
//! rc.configure(Some("bwa"), PredictorPolicy::WittLr)?;
//! let out = rc.plan("bwa", 8000.0)?;
//! println!("served by {} (v{})", out.predictor, out.model_version);
//! # Ok(())
//! # }
//! ```

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::protocol::{
    Dedup, ErrorCode, ObserveAck, Request, Response, ServerInfo, StatsSummary, WireError,
    WIRE_VERSION,
};
use crate::coordinator::wire::{
    decode_response, read_frame, try_encode_request, FrameRead, Wire, DEFAULT_MAX_FRAME_BYTES,
};
use crate::coordinator::{PlanOutcome, PredictorPolicy, RetryOutcome};
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Client-side cap on one response frame. Far above the server's
/// request cap because a `snapshot` response carries the whole model
/// store inline.
pub const CLIENT_MAX_FRAME_BYTES: usize = 1 << 26;

pub struct RemoteClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    wire: Wire,
    /// Outbound request cap, mirroring the server's `--max-frame-bytes`.
    /// An over-cap request is refused *before* any byte is written — the
    /// server would answer `request-too-large` and close; refusing
    /// client-side keeps the connection usable.
    max_request_bytes: usize,
}

impl RemoteClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<RemoteClient> {
        let stream = TcpStream::connect(addr).context("connect to coordinator")?;
        RemoteClient::from_stream(stream)
    }

    /// Like [`connect`](RemoteClient::connect), but bounds the TCP
    /// connect and every subsequent read *and* write by `timeout` — a
    /// hung or unreachable coordinator fails the call instead of
    /// blocking the workflow engine forever. (Writes block too once the
    /// socket's send buffer fills against a stalled peer; bounding only
    /// reads was a hole.)
    pub fn connect_with_timeout<A: ToSocketAddrs>(
        addr: A,
        timeout: Duration,
    ) -> Result<RemoteClient> {
        let resolved = addr
            .to_socket_addrs()
            .context("resolve coordinator address")?
            .next()
            .ok_or_else(|| anyhow::anyhow!("coordinator address resolved to nothing"))?;
        let stream = TcpStream::connect_timeout(&resolved, timeout)
            .with_context(|| format!("connect to coordinator at {resolved}"))?;
        let mut rc = RemoteClient::from_stream(stream)?;
        rc.set_read_timeout(Some(timeout))?;
        rc.set_write_timeout(Some(timeout))?;
        Ok(rc)
    }

    fn from_stream(stream: TcpStream) -> Result<RemoteClient> {
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().context("clone coordinator stream")?;
        Ok(RemoteClient {
            reader: BufReader::new(stream),
            writer,
            wire: Wire::V1,
            max_request_bytes: DEFAULT_MAX_FRAME_BYTES,
        })
    }

    /// Set the outbound request cap (use the value the server was given
    /// with `--max-frame-bytes`). Requests that encode over the cap come
    /// back as a structured `request-too-large` without touching the
    /// wire, so the connection survives.
    pub fn set_max_request_bytes(&mut self, max: usize) {
        self.max_request_bytes = max;
    }

    /// The wire this connection currently speaks.
    pub fn wire(&self) -> Wire {
        self.wire
    }

    /// Bound every response read. A read that times out leaves the
    /// connection mid-frame — treat the client as dead and reconnect.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout).context("set read timeout")
    }

    /// Bound every request write (a stalled server eventually fills the
    /// socket's send buffer; an unbounded write then blocks forever).
    /// Same caveat as reads: a timed-out write leaves the connection
    /// mid-frame.
    pub fn set_write_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_write_timeout(timeout).context("set write timeout")
    }

    /// Send one raw v1 line and parse the reply as JSON. Escape hatch
    /// for conformance tests that need to ship intentionally malformed
    /// requests; typed callers use the op methods. Only meaningful on a
    /// wire-v1 connection — after a v2 upgrade raw line bytes would
    /// corrupt the binary framing, so this refuses.
    pub fn raw(&mut self, line: &str) -> Result<Json> {
        anyhow::ensure!(
            self.wire == Wire::V1,
            "raw lines are a wire-v1 escape hatch; this connection negotiated {}",
            self.wire.name()
        );
        writeln!(self.writer, "{line}").context("write request")?;
        match read_frame(&mut self.reader, Wire::V1, CLIENT_MAX_FRAME_BYTES)
            .context("read response")?
        {
            FrameRead::Frame(payload) => {
                let text = String::from_utf8_lossy(&payload);
                Json::parse(&text).map_err(|e| anyhow::anyhow!("unparseable response: {e}"))
            }
            FrameRead::Eof => anyhow::bail!("server closed the connection"),
            FrameRead::TooLong => anyhow::bail!("response exceeded the client frame cap"),
            FrameRead::TimedOut => anyhow::bail!("response read timed out"),
        }
    }

    /// Read one framed response off the connection and decode it for
    /// `op`, separating transport failures (`Err`) from structured
    /// server-side errors (`Ok(Err(_))`).
    fn read_response(&mut self, op: &str) -> Result<Result<Response, WireError>> {
        match read_frame(&mut self.reader, self.wire, CLIENT_MAX_FRAME_BYTES)
            .context("read response")?
        {
            FrameRead::Frame(payload) => match decode_response(self.wire, &payload, op) {
                Ok(resp) => Ok(Ok(resp)),
                Err(e) => Ok(Err(e)),
            },
            FrameRead::Eof => anyhow::bail!("server closed the connection"),
            FrameRead::TooLong => anyhow::bail!("response exceeded the client frame cap"),
            FrameRead::TimedOut => anyhow::bail!("response read timed out"),
        }
    }

    /// Send one typed request and return the server's verdict with the
    /// structured error preserved: `Err` is a transport/decoding
    /// failure, `Ok(Err(WireError))` a well-formed server-side
    /// rejection. The parity suite uses this to compare error codes and
    /// messages across wires; ordinary callers use the op methods.
    pub fn call_raw(&mut self, req: &Request) -> Result<Result<Response, WireError>> {
        let bytes = match try_encode_request(self.wire, req, self.max_request_bytes) {
            Ok(b) => b,
            // Nothing was written, so the stream is still in sync; the
            // refusal is the same structured error the server would send
            // (followed by a close, which this path avoids).
            Err(e) => return Ok(Err(e)),
        };
        self.writer.write_all(&bytes).context("write request")?;
        self.read_response(req.op())
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        self.call_raw(req)?.map_err(report_wire_error)
    }

    /// Ship every request in one write, then collect their responses in
    /// order — request pipelining. Each slot is that request's verdict
    /// (`Err(WireError)` for structured rejections); a transport
    /// failure aborts the whole batch. `hello` must not ride a pipeline
    /// (its response can switch the codec mid-stream); negotiate first.
    pub fn pipeline(&mut self, reqs: &[Request]) -> Result<Vec<Result<Response, WireError>>> {
        anyhow::ensure!(
            !reqs.iter().any(|r| matches!(r, Request::Hello { .. })),
            "hello cannot be pipelined; use negotiate() before the batch"
        );
        // Encode the whole batch before writing anything: if one request
        // is over the cap, the batch is refused with nothing on the wire
        // (a partial pipeline would desynchronize request/response
        // pairing).
        let mut batch = Vec::new();
        for req in reqs {
            let bytes = try_encode_request(self.wire, req, self.max_request_bytes)
                .map_err(|e| anyhow::anyhow!("pipelined {} request: {e}", req.op()))?;
            batch.extend_from_slice(&bytes);
        }
        self.writer.write_all(&batch).context("write pipelined batch")?;
        reqs.iter().map(|req| self.read_response(req.op())).collect()
    }

    /// Version/capability negotiation. Offers the server versions
    /// `1..=max_version`; the connection switches to whatever the
    /// server grants (the hello response itself still arrives on the
    /// wire the hello was sent on). Negotiation is conservative: a
    /// server that predates wire v2 — or this one, when `max_version`
    /// is 1 — leaves the connection on v1.
    pub fn negotiate(&mut self, max_version: usize) -> Result<ServerInfo> {
        match self.call(&Request::Hello {
            client: Some("ksplus-remote-client".into()),
            min_version: Some(WIRE_VERSION),
            max_version: Some(max_version),
        })? {
            Response::Hello(info) => {
                if let Some(w) = Wire::from_version(info.version) {
                    self.wire = w;
                }
                Ok(info)
            }
            other => anyhow::bail!("unexpected response to hello: {other:?}"),
        }
    }

    /// Version/capability negotiation pinned to wire v1. Call once
    /// after connecting; fails if the server cannot speak wire v1.
    pub fn hello(&mut self) -> Result<ServerInfo> {
        self.negotiate(WIRE_VERSION)
    }

    /// Bind a task (or, with `None`, the service-wide default) to a
    /// predictor policy.
    pub fn configure(&mut self, task: Option<&str>, policy: PredictorPolicy) -> Result<()> {
        match self.call(&Request::Configure {
            task: task.map(str::to_string),
            policy,
            dedup: None,
        })? {
            Response::Configured { .. } => Ok(()),
            other => anyhow::bail!("unexpected response to configure: {other:?}"),
        }
    }

    /// Batch-train the task; returns the number of executions shipped.
    pub fn train(&mut self, task: &str, history: &[Execution]) -> Result<u64> {
        match self.call(&Request::Train {
            task: task.to_string(),
            history: history.to_vec(),
            dedup: None,
        })? {
            Response::Trained { executions, .. } => Ok(executions),
            other => anyhow::bail!("unexpected response to train: {other:?}"),
        }
    }

    /// Fold one finished execution into the task's models.
    pub fn observe(&mut self, task: &str, execution: &Execution) -> Result<ObserveAck> {
        match self.call(&Request::Observe {
            task: task.to_string(),
            execution: execution.clone(),
            dedup: None,
        })? {
            Response::Observed(ack) => Ok(ack),
            other => anyhow::bail!("unexpected response to observe: {other:?}"),
        }
    }

    /// Request an allocation plan; the outcome carries provenance.
    pub fn plan(&mut self, task: &str, input_mb: f64) -> Result<PlanOutcome> {
        match self.call(&Request::Plan { task: task.to_string(), input_mb })? {
            Response::Planned(out) => Ok(out),
            other => anyhow::bail!("unexpected response to plan: {other:?}"),
        }
    }

    /// Report an OOM. With `task`, the retry uses that task's bound
    /// policy; without, the KS+ segment-rescaling strategy.
    pub fn report_failure(
        &mut self,
        task: Option<&str>,
        plan: &StepPlan,
        fail_time: f64,
    ) -> Result<RetryOutcome> {
        match self.call(&Request::Failure {
            task: task.map(str::to_string),
            plan: plan.clone(),
            fail_time,
        })? {
            Response::Retry(r) => Ok(r),
            other => anyhow::bail!("unexpected response to failure: {other:?}"),
        }
    }

    /// Merged service counters across every shard.
    pub fn stats(&mut self) -> Result<StatsSummary> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => anyhow::bail!("unexpected response to stats: {other:?}"),
        }
    }

    /// Dump the server's full model state as a restorable snapshot
    /// document (admin op; check `hello().ops` for `"snapshot"`).
    pub fn snapshot(&mut self) -> Result<Json> {
        match self.call(&Request::Snapshot)? {
            Response::Snapshot { doc } => Ok(doc),
            other => anyhow::bail!("unexpected response to snapshot: {other:?}"),
        }
    }

    /// Resize the server's worker pool to `shards` workers; returns the
    /// live shard ids after the resize (admin op; check `hello().ops`
    /// for `"reshard"`).
    pub fn reshard(&mut self, shards: usize) -> Result<Vec<usize>> {
        match self.call(&Request::Reshard { shards })? {
            Response::Resharded { shard_ids } => Ok(shard_ids),
            other => anyhow::bail!("unexpected response to reshard: {other:?}"),
        }
    }
}

fn report_wire_error(e: WireError) -> anyhow::Error {
    // The blanket std-error conversion keeps "{code}: {message}".
    anyhow::Error::from(e)
}

// ---- self-healing client -------------------------------------------------

/// Knobs for [`ResilientClient`]. The defaults are conservative: retry
/// only what is provably safe, back off exponentially, and trip the
/// circuit breaker after a run of transport failures.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Attempts per logical call, including the first. At least 1.
    pub max_attempts: u32,
    /// First backoff; doubles per attempt (with seeded jitter) up to
    /// [`max_backoff`](RetryPolicy::max_backoff).
    pub base_backoff: Duration,
    pub max_backoff: Duration,
    /// Opt in to retrying mutating ops (`configure`/`train`/`observe`)
    /// across transport failures. Safe only because every such op then
    /// carries a [`Dedup`] marker — the server replays the cached ack
    /// instead of applying twice. Off by default: against a pre-dedup
    /// server the marker is ignored and a retry could double-apply.
    pub retry_mutations: bool,
    /// Consecutive transport failures that open the circuit breaker.
    pub breaker_threshold: u32,
    /// How long an open breaker fails fast before allowing one probe.
    pub breaker_cooldown: Duration,
    /// Seeds backoff jitter *and* the dedup session nonce, so a chaos
    /// run replays bit-identically. Give every client a distinct seed:
    /// two clients sharing a seed share a dedup session and would
    /// swallow each other's mutations as replays.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(1),
            retry_mutations: false,
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_secs(1),
            seed: 0x5eed,
        }
    }
}

/// What the resilience layer has had to do, for reporting (loadgen puts
/// these next to the server's `shed` counter in its bench JSON).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientCounters {
    /// Attempts beyond the first (overload backoff + transport retries).
    pub retries: u64,
    /// Successful connections after the first one.
    pub reconnects: u64,
    /// Times the circuit breaker tripped open.
    pub circuit_opens: u64,
}

/// A [`RemoteClient`] wrapped in a self-healing layer: exponential
/// backoff with seeded jitter, automatic reconnect (with wire
/// re-negotiation), retries, and a circuit breaker.
///
/// Retry rules, from safest to most opt-in:
///
/// - An `overloaded` rejection is always retried (until
///   `max_attempts`): the server sheds *before* executing, so nothing
///   was applied, and the connection stays open — only backoff is
///   needed.
/// - A transport failure (reset, timeout, torn frame) drops the
///   connection and retries **idempotent** ops (`plan`/`stats`/
///   `snapshot`) on a fresh one.
/// - Mutating ops (`configure`/`train`/`observe`) are retried across
///   transport failures only with
///   [`retry_mutations`](RetryPolicy::retry_mutations): each logical op
///   is then stamped once with a per-session `(nonce, seq)` and every
///   resend carries the same stamp, so the server applies it exactly
///   once however many times the wire delivers it.
/// - `failure`/`reshard` never retry past a failed transport (the
///   protocol has no dedup marker for them); a failed *connect* is
///   still retried since nothing reached the wire.
///
/// After `breaker_threshold` consecutive transport failures the breaker
/// opens: calls fail fast for `breaker_cooldown`, then one probe call
/// is let through (half-open) — success closes the breaker, failure
/// re-opens it.
pub struct ResilientClient {
    addr: String,
    timeout: Option<Duration>,
    max_wire_version: usize,
    max_request_bytes: usize,
    policy: RetryPolicy,
    rng: Rng,
    /// Dedup session id; one per client, derived from the policy seed.
    nonce: String,
    /// Last dedup sequence number handed out (stamping is pre-increment,
    /// so the first logical op is seq 1).
    next_seq: u64,
    conn: Option<RemoteClient>,
    ever_connected: bool,
    consecutive_failures: u32,
    /// `Some` while the breaker is open; a call at/after the instant is
    /// the half-open probe.
    open_until: Option<Instant>,
    counters: ClientCounters,
}

impl ResilientClient {
    /// No I/O happens here — the first call connects (and negotiates
    /// the highest wire version the server grants, up to this build's).
    pub fn new(addr: impl Into<String>, policy: RetryPolicy) -> ResilientClient {
        let mut rng = Rng::new(policy.seed);
        // Burn the first draw into the nonce so two clients with
        // adjacent seeds don't produce near-identical jitter schedules.
        let nonce = format!("rc-{:016x}", rng.next_u64());
        ResilientClient {
            addr: addr.into(),
            timeout: None,
            max_wire_version: WIRE_VERSION + 1,
            max_request_bytes: DEFAULT_MAX_FRAME_BYTES,
            policy,
            rng,
            nonce,
            next_seq: 0,
            conn: None,
            ever_connected: false,
            consecutive_failures: 0,
            open_until: None,
            counters: ClientCounters::default(),
        }
    }

    /// Bound connect/read/write like
    /// [`RemoteClient::connect_with_timeout`].
    pub fn set_timeout(&mut self, timeout: Option<Duration>) {
        self.timeout = timeout;
    }

    /// Cap the wire version offered when (re)negotiating; 1 pins every
    /// connection to newline JSON.
    pub fn set_max_wire_version(&mut self, v: usize) {
        self.max_wire_version = v.max(WIRE_VERSION);
    }

    /// See [`RemoteClient::set_max_request_bytes`]; applies to the
    /// current connection and every reconnect.
    pub fn set_max_request_bytes(&mut self, max: usize) {
        self.max_request_bytes = max;
        if let Some(rc) = self.conn.as_mut() {
            rc.set_max_request_bytes(max);
        }
    }

    pub fn counters(&self) -> ClientCounters {
        self.counters
    }

    /// The dedup session nonce mutating retries are stamped with.
    pub fn nonce(&self) -> &str {
        &self.nonce
    }

    /// Wire of the live connection, if one is up.
    pub fn wire(&self) -> Option<Wire> {
        self.conn.as_ref().map(RemoteClient::wire)
    }

    pub fn configure(&mut self, task: Option<&str>, policy: PredictorPolicy) -> Result<()> {
        let req =
            Request::Configure { task: task.map(str::to_string), policy, dedup: None };
        match self.exec(req)? {
            Response::Configured { .. } => Ok(()),
            other => anyhow::bail!("unexpected response to configure: {other:?}"),
        }
    }

    pub fn train(&mut self, task: &str, history: &[Execution]) -> Result<u64> {
        let req = Request::Train {
            task: task.to_string(),
            history: history.to_vec(),
            dedup: None,
        };
        match self.exec(req)? {
            Response::Trained { executions, .. } => Ok(executions),
            other => anyhow::bail!("unexpected response to train: {other:?}"),
        }
    }

    pub fn observe(&mut self, task: &str, execution: &Execution) -> Result<ObserveAck> {
        let req = Request::Observe {
            task: task.to_string(),
            execution: execution.clone(),
            dedup: None,
        };
        match self.exec(req)? {
            Response::Observed(ack) => Ok(ack),
            other => anyhow::bail!("unexpected response to observe: {other:?}"),
        }
    }

    pub fn plan(&mut self, task: &str, input_mb: f64) -> Result<PlanOutcome> {
        match self.exec(Request::Plan { task: task.to_string(), input_mb })? {
            Response::Planned(out) => Ok(out),
            other => anyhow::bail!("unexpected response to plan: {other:?}"),
        }
    }

    pub fn report_failure(
        &mut self,
        task: Option<&str>,
        plan: &StepPlan,
        fail_time: f64,
    ) -> Result<RetryOutcome> {
        let req = Request::Failure {
            task: task.map(str::to_string),
            plan: plan.clone(),
            fail_time,
        };
        match self.exec(req)? {
            Response::Retry(r) => Ok(r),
            other => anyhow::bail!("unexpected response to failure: {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<StatsSummary> {
        match self.exec(Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => anyhow::bail!("unexpected response to stats: {other:?}"),
        }
    }

    pub fn snapshot(&mut self) -> Result<Json> {
        match self.exec(Request::Snapshot)? {
            Response::Snapshot { doc } => Ok(doc),
            other => anyhow::bail!("unexpected response to snapshot: {other:?}"),
        }
    }

    pub fn reshard(&mut self, shards: usize) -> Result<Vec<usize>> {
        match self.exec(Request::Reshard { shards })? {
            Response::Resharded { shard_ids } => Ok(shard_ids),
            other => anyhow::bail!("unexpected response to reshard: {other:?}"),
        }
    }

    /// Stamp a mutating request with this session's next dedup marker
    /// (only when mutation retry is opted in). Returns whether the
    /// request now carries one. Stamping happens once per *logical* op
    /// — every retry of the op resends the identical stamp.
    fn arm_dedup(&mut self, req: &mut Request) -> bool {
        let slot = match req {
            Request::Configure { dedup, .. }
            | Request::Train { dedup, .. }
            | Request::Observe { dedup, .. } => dedup,
            _ => return false,
        };
        if !self.policy.retry_mutations {
            return false;
        }
        self.next_seq += 1;
        *slot = Some(Dedup { nonce: self.nonce.clone(), seq: self.next_seq });
        true
    }

    /// The retry loop every typed method funnels through.
    fn exec(&mut self, mut req: Request) -> Result<Response> {
        let idempotent = matches!(
            req,
            Request::Plan { .. } | Request::Stats | Request::Snapshot | Request::Hello { .. }
        );
        let deduped = self.arm_dedup(&mut req);
        let max_attempts = self.policy.max_attempts.max(1);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            if let Some(until) = self.open_until {
                if Instant::now() < until {
                    anyhow::bail!(
                        "circuit breaker open ({} consecutive transport failures to {}); \
                         failing fast until the cooldown elapses",
                        self.consecutive_failures,
                        self.addr
                    );
                }
                // Cooldown elapsed: this attempt is the half-open probe.
            }
            // A connect failure means nothing reached the wire, so even
            // a non-deduped mutation may retry it; `sent` tracks that.
            let mut sent = false;
            let outcome = self.ensure_conn().and_then(|()| {
                sent = true;
                self.conn.as_mut().expect("just connected").call_raw(&req)
            });
            match outcome {
                Ok(Ok(resp)) => {
                    self.consecutive_failures = 0;
                    self.open_until = None;
                    return Ok(resp);
                }
                Ok(Err(we)) if we.code == ErrorCode::Overloaded && attempt < max_attempts => {
                    // Shed before execution — nothing applied, the
                    // connection stays open; just back off and resend.
                    self.consecutive_failures = 0;
                    self.counters.retries += 1;
                    self.backoff(attempt);
                }
                Ok(Err(we)) => {
                    // A structured rejection proves the link works.
                    self.consecutive_failures = 0;
                    self.open_until = None;
                    return Err(report_wire_error(we));
                }
                Err(e) => {
                    self.conn = None;
                    self.consecutive_failures += 1;
                    if self.consecutive_failures >= self.policy.breaker_threshold.max(1) {
                        // Reaching an attempt means the breaker was
                        // closed or half-open — either way this is a
                        // fresh opening.
                        self.open_until =
                            Some(Instant::now() + self.policy.breaker_cooldown);
                        self.counters.circuit_opens += 1;
                    }
                    let retry_safe = idempotent || deduped || !sent;
                    if retry_safe && attempt < max_attempts {
                        self.counters.retries += 1;
                        self.backoff(attempt);
                    } else {
                        return Err(e.context(format!(
                            "{} failed after {attempt} attempt(s)",
                            req.op()
                        )));
                    }
                }
            }
        }
    }

    /// Connect + negotiate if no connection is up. Reconnects count.
    fn ensure_conn(&mut self) -> Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut rc = match self.timeout {
            Some(t) => RemoteClient::connect_with_timeout(&self.addr, t)?,
            None => RemoteClient::connect(&self.addr)?,
        };
        rc.set_max_request_bytes(self.max_request_bytes);
        // Re-negotiation on every reconnect: the server may have been
        // replaced by one speaking a different wire since last time.
        rc.negotiate(self.max_wire_version)?;
        if self.ever_connected {
            self.counters.reconnects += 1;
        }
        self.ever_connected = true;
        self.conn = Some(rc);
        Ok(())
    }

    /// Exponential backoff with seeded jitter in [0.5x, 1x) of the
    /// capped exponential step.
    fn backoff(&mut self, attempt: u32) {
        let shift = (attempt - 1).min(16);
        let step = self
            .policy
            .base_backoff
            .saturating_mul(1u32 << shift)
            .min(self.policy.max_backoff);
        let jittered = step.mul_f64(0.5 + 0.5 * self.rng.f64());
        if !jittered.is_zero() {
            std::thread::sleep(jittered);
        }
    }

    /// Test hook: kill the live socket under the client so the next
    /// call sees a transport failure and must heal.
    #[cfg(test)]
    fn sever(&mut self) {
        if let Some(rc) = self.conn.as_ref() {
            rc.writer.shutdown(std::net::Shutdown::Both).ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{Server, ServerConfig};
    use crate::coordinator::service::{Coordinator, CoordinatorConfig};
    use crate::coordinator::BackendSpec;

    fn start_server() -> (Coordinator, Server) {
        let coord =
            Coordinator::start(CoordinatorConfig::default(), BackendSpec::Native).unwrap();
        let server = Server::start_with_config(
            "127.0.0.1:0",
            coord.client(),
            ServerConfig::default(),
        )
        .unwrap();
        (coord, server)
    }

    fn fast_policy() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_millis(0),
            breaker_threshold: 10,
            seed: 77,
            ..Default::default()
        }
    }

    fn exec(task: &str) -> Execution {
        Execution::new(task, 100.0, 1.0, vec![1.0, 2.0, 3.0])
    }

    #[test]
    fn dedup_stamps_only_when_opted_in() {
        let mut off = ResilientClient::new("127.0.0.1:1", fast_policy());
        let mut req = Request::Observe {
            task: "t".into(),
            execution: exec("t"),
            dedup: None,
        };
        assert!(!off.arm_dedup(&mut req));
        assert!(matches!(&req, Request::Observe { dedup: None, .. }));

        let mut on = ResilientClient::new(
            "127.0.0.1:1",
            RetryPolicy { retry_mutations: true, ..fast_policy() },
        );
        assert!(on.arm_dedup(&mut req));
        let first = match &req {
            Request::Observe { dedup: Some(d), .. } => d.clone(),
            other => panic!("missing stamp: {other:?}"),
        };
        assert_eq!((first.nonce.as_str(), first.seq), (on.nonce(), 1));
        // The next logical op gets the next seq under the same nonce.
        assert!(on.arm_dedup(&mut req));
        match &req {
            Request::Observe { dedup: Some(d), .. } => {
                assert_eq!((d.nonce.as_str(), d.seq), (on.nonce(), 2));
            }
            other => panic!("missing stamp: {other:?}"),
        }
        // Plan never carries a stamp regardless of policy.
        let mut plan = Request::Plan { task: "t".into(), input_mb: 1.0 };
        assert!(!on.arm_dedup(&mut plan));
    }

    #[test]
    fn reconnects_and_retries_idempotent_ops_after_a_dead_socket() {
        let (_coord, mut server) = start_server();
        let mut rc = ResilientClient::new(server.addr().to_string(), fast_policy());
        rc.observe("t", &exec("t")).unwrap();
        assert_eq!(rc.counters(), ClientCounters::default());

        rc.sever();
        // plan is idempotent: the dead socket costs a retry + reconnect,
        // not an error.
        let out = rc.plan("t", 100.0).unwrap();
        assert!(!out.plan.peaks.is_empty());
        let c = rc.counters();
        assert!(c.retries >= 1, "{c:?}");
        assert_eq!(c.reconnects, 1, "{c:?}");
        assert_eq!(c.circuit_opens, 0, "{c:?}");
        server.stop();
    }

    #[test]
    fn dead_socket_fails_a_mutating_op_unless_opted_in() {
        let (_coord, mut server) = start_server();
        let mut rc = ResilientClient::new(server.addr().to_string(), fast_policy());
        rc.observe("t", &exec("t")).unwrap();
        rc.sever();
        // Default policy: the op was (partially) on the wire and carries
        // no dedup stamp, so retrying could double-apply — refuse.
        let err = rc.observe("t", &exec("t")).unwrap_err();
        assert!(err.to_string().contains("observe failed after 1 attempt"), "{err}");
        // The client still healed for the next call.
        rc.stats().unwrap();
        assert_eq!(rc.counters().reconnects, 1);
        server.stop();
    }

    #[test]
    fn opted_in_mutations_heal_with_a_dedup_stamp() {
        let (_coord, mut server) = start_server();
        let mut rc = ResilientClient::new(
            server.addr().to_string(),
            RetryPolicy { retry_mutations: true, ..fast_policy() },
        );
        rc.observe("t", &exec("t")).unwrap();
        rc.sever();
        let ack = rc.observe("t", &exec("t")).unwrap();
        assert_eq!(ack.executions, 2, "both logical observes applied");
        let stats = rc.stats().unwrap();
        assert_eq!(stats.observations, 2, "healed retry applied exactly once");
        assert!(rc.counters().reconnects >= 1);
        server.stop();
    }

    #[test]
    fn circuit_breaker_opens_then_fails_fast_and_recovers_via_probe() {
        // A port with nothing listening: every connect is refused.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut rc = ResilientClient::new(
            addr,
            RetryPolicy {
                max_attempts: 1,
                base_backoff: Duration::from_millis(0),
                breaker_threshold: 2,
                breaker_cooldown: Duration::from_millis(30),
                seed: 7,
                ..Default::default()
            },
        );
        assert!(rc.plan("t", 1.0).is_err());
        assert_eq!(rc.counters().circuit_opens, 0);
        assert!(rc.plan("t", 1.0).is_err());
        assert_eq!(rc.counters().circuit_opens, 1, "threshold reached");
        // Open breaker: fails fast without touching the socket.
        let err = rc.plan("t", 1.0).unwrap_err();
        assert!(err.to_string().contains("circuit breaker open"), "{err}");
        // After the cooldown the probe goes through — still refused, so
        // the breaker re-opens (a second distinct opening).
        std::thread::sleep(Duration::from_millis(40));
        let err = rc.plan("t", 1.0).unwrap_err();
        assert!(!err.to_string().contains("circuit breaker open"), "{err}");
        assert_eq!(rc.counters().circuit_opens, 2);
    }

    #[test]
    fn breaker_closes_after_a_successful_probe() {
        let (_coord, mut server) = start_server();
        let addr = server.addr().to_string();
        let mut rc = ResilientClient::new(
            addr,
            RetryPolicy {
                max_attempts: 1,
                base_backoff: Duration::from_millis(0),
                breaker_threshold: 1,
                breaker_cooldown: Duration::from_millis(10),
                seed: 9,
                ..Default::default()
            },
        );
        rc.plan("t", 1.0).unwrap();
        // One dead socket trips the 1-failure threshold.
        rc.sever();
        assert!(rc.plan("t", 1.0).is_err());
        assert_eq!(rc.counters().circuit_opens, 1);
        std::thread::sleep(Duration::from_millis(20));
        // Probe succeeds → breaker closes, normal service resumes.
        rc.plan("t", 1.0).unwrap();
        rc.stats().unwrap();
        assert_eq!(rc.counters().circuit_opens, 1);
        server.stop();
    }
}
