//! Failure-injection / fuzz tests for every parser and boundary surface:
//! the JSON substrate, the trace CSV readers, the wire protocol, and the
//! plan sanitizer. None of these may panic on arbitrary input — they
//! must return errors (or valid structures) deterministically.

use ksplus::segments::StepPlan;
use ksplus::trace::nextflow;
use ksplus::util::json::Json;
use ksplus::util::prop::run_prop;
use ksplus::util::rng::Rng;

/// Random bytes / mutated-valid-JSON never panic the JSON parser.
#[test]
fn json_parser_never_panics() {
    run_prop("json_fuzz_random", 500, |rng| {
        let len = rng.below(200);
        let bytes: Vec<u8> = (0..len)
            .map(|_| {
                // Bias toward JSON-relevant bytes.
                const ALPHABET: &[u8] = b"{}[]\",:0123456789.eE+-truefalsn \\u00ff";
                ALPHABET[rng.below(ALPHABET.len())]
            })
            .collect();
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(s); // must not panic
        }
    });
}

#[test]
fn json_roundtrip_random_documents() {
    // Generate random JSON values, print, reparse: must be identical.
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            const CHARS: &[char] =
                                &['a', 'b', '"', '\\', '\n', '\t', 'é', '→', ' '];
                            CHARS[rng.below(CHARS.len())]
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    run_prop("json_roundtrip", 300, |rng| {
        let doc = gen(rng, 3);
        let printed = doc.to_string();
        let back = Json::parse(&printed)
            .unwrap_or_else(|e| panic!("reparse failed: {e} for {printed}"));
        assert_eq!(back, doc, "roundtrip mismatch for {printed}");
    });
}

#[test]
fn trace_csv_reader_never_panics() {
    run_prop("trace_csv_fuzz", 300, |rng| {
        let mut content = String::from("task,input_mb,dt,samples\n");
        for _ in 0..rng.below(6) {
            let line_len = rng.below(60);
            let line: String = (0..line_len)
                .map(|_| {
                    const ALPHABET: &[u8] = b"abc,;.0123456789-e\n\t ";
                    ALPHABET[rng.below(ALPHABET.len())] as char
                })
                .collect();
            content.push_str(&line);
            content.push('\n');
        }
        let path = std::env::temp_dir().join(format!(
            "ksplus_fuzz_{}_{}.csv",
            std::process::id(),
            rng.next_u64()
        ));
        std::fs::write(&path, &content).unwrap();
        let _ = ksplus::trace::io::read_csv(&path, "fuzz"); // must not panic
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn nextflow_reader_never_panics() {
    run_prop("nextflow_fuzz", 300, |rng| {
        let mut content = String::from("process,task_id,input_bytes,timestamp_ms,rss_bytes\n");
        for _ in 0..rng.below(8) {
            let fields = rng.below(7);
            let line: Vec<String> = (0..fields)
                .map(|_| match rng.below(3) {
                    0 => format!("{}", rng.uniform(-10.0, 1e12)),
                    1 => "proc".to_string(),
                    _ => String::new(),
                })
                .collect();
            content.push_str(&line.join(","));
            content.push('\n');
        }
        let _ = nextflow::parse_long_csv(std::io::Cursor::new(content), "fuzz");
    });
}

#[test]
fn wire_protocol_never_kills_connection() {
    use ksplus::coordinator::server::Server;
    use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
    use ksplus::coordinator::BackendSpec;
    use std::io::{BufRead, BufReader, Write};

    let coord = Coordinator::start(CoordinatorConfig::default(), BackendSpec::Native).unwrap();
    let server = Server::start("127.0.0.1:0", coord.client()).unwrap();
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut rng = Rng::new(99);
    for _ in 0..100 {
        let len = rng.below(80);
        let line: String = (0..len)
            .map(|_| {
                const ALPHABET: &[u8] = b"{}[]\",:0123456789optranfilues ";
                ALPHABET[rng.below(ALPHABET.len())] as char
            })
            .collect();
        writeln!(stream, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(&resp).expect("server must answer JSON");
        assert!(j.get("ok").is_some(), "malformed response: {resp}");
    }
    // Still serves valid requests afterwards.
    writeln!(stream, r#"{{"op":"stats"}}"#).unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert_eq!(Json::parse(&resp).unwrap().get("ok"), Some(&Json::Bool(true)));
}

/// Sharded stress variant: 8 concurrent connections fire a mix of valid
/// ops (plan, observe, failure, stats) and garbage at a `shards: 4`
/// server — incremental `observe` training mutates the very models the
/// plan traffic reads, under contention. Every written line must get
/// exactly one JSON reply, no connection may die, and the final
/// aggregated `stats` must equal the sum of successful plans AND
/// observations across all clients — i.e. the shard merge loses nothing.
#[test]
fn wire_protocol_sharded_under_stress() {
    use ksplus::coordinator::server::Server;
    use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
    use ksplus::coordinator::BackendSpec;
    use std::io::{BufRead, BufReader, Write};

    let coord = Coordinator::start(
        CoordinatorConfig { shards: 4, ..Default::default() },
        BackendSpec::Native,
    )
    .unwrap();
    let server = Server::start("127.0.0.1:0", coord.client()).unwrap();
    let addr = server.addr();

    let mut handles = Vec::new();
    for t in 0..8u64 {
        handles.push(std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut rng = Rng::new(1000 + t);
            let mut ok_plans = 0u64;
            let mut ok_observes = 0u64;
            for i in 0..120u64 {
                // 0 = plan, 1 = observe, 2 = failure, 3 = stats,
                // 4 = hello, 5 = configure, 6+ = junk
                let kind = rng.below(8);
                let line = match kind {
                    // Valid plan op on one of 32 task names — enough
                    // distinct names that every one of the 4 shards
                    // receives plan traffic (untrained fallback still
                    // counts as a request).
                    0 => format!(
                        r#"{{"op":"plan","task":"t{}","input_mb":{}}}"#,
                        rng.below(32),
                        1000 + i
                    ),
                    // Valid observe op: incremental training mixed into
                    // the stress stream, hitting the same task names the
                    // plans use (so models mutate under plan load).
                    1 => {
                        let n = 1 + rng.below(4);
                        let samples: Vec<String> =
                            (0..n).map(|j| format!("{:.2}", 0.5 + j as f64)).collect();
                        format!(
                            r#"{{"op":"observe","task":"t{}","execution":{{"input_mb":{},"dt":1.0,"samples":[{}]}}}}"#,
                            rng.below(32),
                            500 + i,
                            samples.join(",")
                        )
                    }
                    // Valid failure op (stateless, any shard serves it).
                    2 => r#"{"op":"failure","plan":{"starts":[0,50],"peaks":[2,8]},"fail_time":20}"#
                        .to_string(),
                    // Valid stats op mid-stream.
                    3 => r#"{"op":"stats"}"#.to_string(),
                    // Valid hello op (version negotiation under load).
                    4 => r#"{"op":"hello","client":"stress","min_version":1}"#.to_string(),
                    // Valid configure op: policy bindings mutate routing
                    // under concurrent plan/observe traffic.
                    5 => {
                        const POLICIES: &[&str] =
                            &["ksplus", "witt-lr", "tovar-ppm", "ksegments", "default-limits"];
                        format!(
                            r#"{{"op":"configure","task":"t{}","policy":"{}"}}"#,
                            rng.below(32),
                            POLICIES[rng.below(POLICIES.len())]
                        )
                    }
                    // Garbage bytes. Never whitespace-only: the server
                    // skips blank lines without replying.
                    _ => {
                        let len = rng.below(60);
                        let mut g: String = (0..len)
                            .map(|_| {
                                const ALPHABET: &[u8] = b"{}[]\",:0123456789optranfilues ";
                                ALPHABET[rng.below(ALPHABET.len())] as char
                            })
                            .collect();
                        if g.trim().is_empty() {
                            g.push('#');
                        }
                        g
                    }
                };
                writeln!(stream, "{line}").unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                let j = Json::parse(&resp).expect("server must answer JSON");
                let ok = j.get("ok").expect("response missing 'ok'");
                match kind {
                    0 => {
                        assert_eq!(ok, &Json::Bool(true), "valid plan rejected: {resp}");
                        ok_plans += 1;
                    }
                    1 => {
                        assert_eq!(ok, &Json::Bool(true), "valid observe rejected: {resp}");
                        ok_observes += 1;
                    }
                    4 | 5 => {
                        assert_eq!(ok, &Json::Bool(true), "valid op rejected: {resp}");
                    }
                    _ => {}
                }
            }
            (ok_plans, ok_observes)
        }));
    }
    let (mut total_ok, mut total_observes) = (0u64, 0u64);
    for h in handles {
        let (p, o) = h.join().unwrap();
        total_ok += p;
        total_observes += o;
    }
    assert!(total_ok > 0);
    assert!(total_observes > 0);

    // The aggregated stats must account for every successful plan and
    // every observation, across all four shards.
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, r#"{{"op":"stats"}}"#).unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(j.get("shards").and_then(Json::as_usize), Some(4));
    assert_eq!(
        j.get("requests").and_then(Json::as_usize),
        Some(total_ok as usize),
        "merged shard stats disagree with the clients' successful plans: {resp}"
    );
    assert_eq!(
        j.get("observations").and_then(Json::as_usize),
        Some(total_observes as usize),
        "merged shard stats disagree with the clients' successful observes: {resp}"
    );
}

#[test]
fn segmentation_handles_adversarial_series() {
    use ksplus::segments::algorithm::{get_segments, get_segments_quadratic};
    run_prop("segmentation_adversarial", 200, |rng| {
        let n = 1 + rng.below(300);
        let samples: Vec<f64> = (0..n)
            .map(|_| match rng.below(5) {
                0 => 0.0,
                1 => 1e-12,
                2 => 1e6,
                3 => rng.uniform(0.0, 1.0),
                _ => rng.uniform(0.0, 128.0),
            })
            .collect();
        let k = 1 + rng.below(12);
        let seg = get_segments(&samples, k);
        assert_eq!(seg.sizes.iter().sum::<usize>(), n);
        assert!(seg.peaks.len() <= k);
        // The heap merge agrees with the quadratic oracle even on
        // adversarial value mixes (zeros, denormal-scale, 1e6 spikes).
        assert_eq!(seg, get_segments_quadratic(&samples, k));
        // Constant series, all-zeros series etc. stay well-formed.
        let flat = get_segments(&vec![samples[0]; n], k);
        assert_eq!(flat.peaks.len(), 1);
    });
}

#[test]
fn predictor_handles_pathological_histories() {
    use ksplus::predictor::{all_methods, by_name};
    use ksplus::trace::Execution;
    // Single execution, zero-memory traces, identical inputs, huge
    // outliers: every method must still produce a valid plan and a valid
    // retry.
    let pathological: Vec<Vec<Execution>> = vec![
        vec![Execution::new("t", 100.0, 1.0, vec![1.0])],
        (0..5).map(|_| Execution::new("t", 50.0, 1.0, vec![1e-9, 1e-9])).collect(),
        (0..5).map(|i| Execution::new("t", 100.0, 1.0, vec![i as f64 + 0.1])).collect(),
        vec![
            Execution::new("t", 1.0, 1.0, vec![0.1]),
            Execution::new("t", 1e9, 1.0, vec![120.0; 400]),
        ],
    ];
    for hist in &pathological {
        for m in all_methods() {
            let mut p = by_name(m, 4, 128.0).unwrap();
            p.train(hist);
            let plan = p.plan(123.0);
            assert!(plan.is_valid(), "{m} produced invalid plan for {hist:?}");
            let retry = p.on_failure(&plan, 0.5, 1);
            assert!(retry.is_valid(), "{m} produced invalid retry");
        }
    }
}

#[test]
fn step_plan_extreme_queries() {
    let p = StepPlan::new(vec![0.0, 1e-9, 1e9], vec![1e-9, 1.0, 127.9]);
    assert!(p.is_valid());
    assert_eq!(p.alloc_at(f64::MAX), 127.9);
    assert_eq!(p.alloc_at(-1e300), 1e-9);
    assert!(p.alloc_gbs(0.0) == 0.0);
    assert!(p.alloc_gbs(1e12).is_finite());
}
