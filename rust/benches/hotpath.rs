//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf):
//!
//!   L3 native  : segmentation, plan math, simulator step rate
//!   L3 service : coordinator plan throughput/latency, native vs PJRT
//!   L1/L2 PJRT : batched fit / predict / fused / wastage artifact cost
//!
//! Run: `cargo bench --bench hotpath` (artifacts required for the PJRT
//! section; it is skipped with a notice when absent).

use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
use ksplus::coordinator::BackendSpec;
use ksplus::predictor::regression::{FitEngine, NativeFit};
use ksplus::predictor::by_name;
use ksplus::segments::algorithm::get_segments;
use ksplus::sim::run_task;
use ksplus::trace::workflow::Workflow;
use ksplus::util::bench::{bench, black_box};
use ksplus::util::rng::Rng;

fn main() {
    let wf = Workflow::eager();
    let trace = wf.generate(42, 200);
    let bwa = trace.task("bwa").unwrap().clone();

    // ---- L3 native hot paths -------------------------------------------
    println!("== L3 native ==");
    let series: Vec<&Vec<f64>> = bwa.executions.iter().map(|e| &e.samples).collect();
    let total_samples: usize = series.iter().map(|s| s.len()).sum();
    let r = bench("segmentation/k4/60-traces", 3, 20, || {
        for s in &series {
            black_box(get_segments(s, 4));
        }
    });
    println!("  -> {}", r.throughput_line(total_samples as f64, "samples"));

    let mut pred = by_name("ksplus", 4, 128.0).unwrap();
    pred.train(&bwa.executions);
    let r = bench("ksplus/plan", 10, 50, || {
        for e in bwa.executions.iter().take(32) {
            black_box(pred.plan(e.input_mb));
        }
    });
    println!("  -> {}", r.throughput_line(32.0, "plans"));

    let r = bench("sim/run_task/60-traces", 3, 20, || {
        for e in &bwa.executions {
            black_box(run_task(pred.as_ref(), e, 10));
        }
    });
    println!("  -> {}", r.throughput_line(total_samples as f64, "trace-samples"));

    let r = bench("native-ols/512rows-x-128obs", 3, 20, || {
        let mut rng = Rng::new(1);
        let rows: Vec<(Vec<f64>, Vec<f64>)> = (0..512)
            .map(|_| {
                let xs: Vec<f64> = (0..128).map(|_| rng.f64()).collect();
                let ys: Vec<f64> = (0..128).map(|_| rng.f64()).collect();
                (xs, ys)
            })
            .collect();
        black_box(NativeFit.fit_batch(&rows));
    });
    println!("  -> {}", r.throughput_line(512.0, "fits"));

    // ---- coordinator service (native backend, shipped defaults) ---------
    // Comparable to the PJRT L3 section below: identical config, only the
    // backend differs.
    println!("== L3 coordinator (native backend) ==");
    coordinator_bench(
        BackendSpec::Native,
        &trace,
        1,
        CoordinatorConfig::default().batch_delay,
    );

    // ---- coordinator service: sharded vs single-worker contention -------
    // Same closed-loop client count at every width: the sharded pool
    // should sustain a multiple of the single worker's plans/sec on
    // multi-core (shards=1 is the original single-worker coordinator).
    // Linger disabled for this sweep only, so it measures pool capacity
    // rather than the single-request straggler poll.
    println!("== L3 coordinator sharded vs single (native backend) ==");
    for shards in [1, 2, 4] {
        coordinator_bench(BackendSpec::Native, &trace, shards, std::time::Duration::ZERO);
    }

    // ---- PJRT sections (feature-gated) ----------------------------------
    pjrt_sections(&trace, &bwa);
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_sections(_trace: &ksplus::trace::WorkflowTrace, _bwa: &ksplus::trace::TaskTraces) {
    println!("SKIP PJRT section: built without the 'pjrt' feature");
}

#[cfg(feature = "pjrt")]
fn pjrt_sections(trace: &ksplus::trace::WorkflowTrace, bwa: &ksplus::trace::TaskTraces) {
    use ksplus::runtime::{default_artifacts_dir, Runtime};

    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP PJRT section: artifacts not built (make artifacts)");
        return;
    }
    println!("== L1/L2 PJRT artifacts ==");
    let rt = Runtime::load(&dir).expect("runtime");
    let mut rng = Rng::new(2);
    let b = rt.manifest().fit_b;
    let rows: Vec<(Vec<f64>, Vec<f64>)> = (0..b)
        .map(|_| {
            let xs: Vec<f64> = (0..128).map(|_| rng.uniform(0.0, 1000.0)).collect();
            let ys: Vec<f64> = xs.iter().map(|x| 0.01 * x + 1.0).collect();
            (xs, ys)
        })
        .collect();
    let r = bench(&format!("pjrt/fit/{b}x128"), 3, 20, || {
        black_box(rt.fit_batch(&rows).unwrap());
    });
    println!("  -> {}", r.throughput_line(b as f64, "fits"));

    // Typical training history (<= 64 obs) hits the small bucket.
    let rows_small: Vec<(Vec<f64>, Vec<f64>)> = rows
        .iter()
        .map(|(xs, ys)| (xs[..40].to_vec(), ys[..40].to_vec()))
        .collect();
    let r = bench(&format!("pjrt/fit/{b}x40-small-bucket"), 3, 20, || {
        black_box(rt.fit_batch(&rows_small).unwrap());
    });
    println!("  -> {}", r.throughput_line(b as f64, "fits"));

    let models = rt.fit_batch(&rows).unwrap();
    let pb = rt.manifest().predict_b;
    let models_big: Vec<_> = (0..pb).map(|i| models[i % models.len()]).collect();
    let xq: Vec<f64> = (0..pb).map(|i| i as f64).collect();
    let scale = vec![1.1; pb];
    let r = bench(&format!("pjrt/predict/{pb}"), 3, 50, || {
        black_box(rt.predict_batch(&models_big, &xq, &scale).unwrap());
    });
    println!("  -> {}", r.throughput_line(pb as f64, "predictions"));

    let xq_b: Vec<f64> = (0..b).map(|i| i as f64).collect();
    let scale_b = vec![1.1; b];
    bench(&format!("pjrt/fit_predict-fused/{b}x128"), 3, 20, || {
        black_box(rt.fit_predict(&rows, &xq_b, &scale_b).unwrap());
    });
    bench(&format!("pjrt/fit+predict-two-step/{b}x128"), 3, 20, || {
        let m = rt.fit_batch(&rows).unwrap();
        black_box(rt.predict_batch(&m, &xq_b, &scale_b).unwrap());
    });

    let wrows: Vec<(Vec<f64>, Vec<f64>, f64)> = bwa
        .executions
        .iter()
        .map(|e| {
            let alloc = vec![e.peak(); e.samples.len()];
            (alloc, e.samples.clone(), e.dt)
        })
        .collect();
    let n_samples: usize = wrows.iter().map(|r| r.0.len()).sum();
    let r = bench("pjrt/wastage/60-traces", 3, 20, || {
        black_box(rt.wastage_batch(&wrows).unwrap());
    });
    println!("  -> {}", r.throughput_line(n_samples as f64, "samples"));

    // ---- coordinator service (PJRT backend) -----------------------------
    println!("== L3 coordinator (PJRT backend) ==");
    coordinator_bench(
        BackendSpec::Pjrt(Some(dir)),
        trace,
        1,
        CoordinatorConfig::default().batch_delay,
    );
}

fn coordinator_bench(
    spec: BackendSpec,
    trace: &ksplus::trace::WorkflowTrace,
    shards: usize,
    batch_delay: std::time::Duration,
) {
    let coord = Coordinator::start(
        CoordinatorConfig { shards, batch_delay, ..Default::default() },
        spec,
    )
    .expect("start coordinator");
    let client = coord.client();
    for t in &trace.tasks {
        client.train(&t.task, t.executions.clone());
    }
    // Closed-loop from 8 threads to exercise the per-shard batchers.
    let n_per_thread = 200;
    let threads = 8;
    let r = bench(&format!("coordinator/plan-closed-loop/shards{shards}"), 1, 5, || {
        let mut handles = Vec::new();
        for t in 0..threads {
            let c = coord.client();
            let tasks: Vec<(String, f64)> = trace
                .tasks
                .iter()
                .map(|tt| (tt.task.clone(), tt.executions[t % tt.executions.len()].input_mb))
                .collect();
            handles.push(std::thread::spawn(move || {
                for i in 0..n_per_thread {
                    let (task, input) = &tasks[i % tasks.len()];
                    black_box(c.plan(task, *input));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    println!(
        "  -> {}",
        r.throughput_line((n_per_thread * threads) as f64, "plans")
    );
    let stats = client.stats();
    println!(
        "  -> mean batch {:.1}, p50 latency {:.0} us, p99 {:.0} us",
        stats.mean_batch_size(),
        stats.latency_percentile_us(50.0),
        stats.latency_percentile_us(99.0)
    );
}
