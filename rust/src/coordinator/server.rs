//! Wire protocol server: newline-delimited JSON over TCP, the interface
//! a workflow engine (Nextflow plugin, Airflow operator) calls.
//!
//! Requests (one JSON object per line):
//!   {"op":"train","task":"bwa","history":[{"input_mb":..,"dt":..,"samples":[..]},..]}
//!   {"op":"observe","task":"bwa","execution":{"input_mb":..,"dt":..,"samples":[..]}}
//!   {"op":"plan","task":"bwa","input_mb":8000.0}
//!   {"op":"failure","plan":{"starts":[..],"peaks":[..]},"fail_time":624.0}
//!   {"op":"stats"}
//!
//! `observe` is the streaming form of `train`: it folds ONE finished
//! execution into the task's models in O(k) on the owning shard —
//! exactly what a workflow engine does as tasks complete. A `train` over
//! a history and the same history streamed through `observe` produce
//! bit-identical models.
//!
//! Responses:
//!   {"ok":true, ...}            on success (fields depend on op)
//!   {"ok":false,"error":"..."}  on failure
//!
//! One OS thread per connection; every connection shares the coordinator
//! worker pool (and thus its per-shard dynamic batchers), so concurrent
//! clients' plan requests for tasks on the same shard are batched into
//! single backend executions (one PJRT dispatch per flush when built
//! with the `pjrt` feature). The `stats` op reports the merge across all
//! shards.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::service::{Client, Coordinator, CoordinatorConfig};
use crate::coordinator::BackendSpec;
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::json::Json;

/// A running TCP front end over a coordinator `Client`.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use port 0 for ephemeral) and serve until `stop()`.
    pub fn start(addr: &str, client: Client) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("ksplus-server-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let c = client.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, c);
                            });
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_handle: Some(handle) })
    }

    /// Build a coordinator pool and a server over it in one call. Backend
    /// construction failures (e.g. a PJRT spec in a native-only build)
    /// surface as `Err` here, before anything is bound or detached.
    pub fn start_with_backend(
        addr: &str,
        cfg: CoordinatorConfig,
        spec: BackendSpec,
    ) -> Result<(Coordinator, Server)> {
        let coord = Coordinator::start(cfg, spec).context("start coordinator")?;
        let server = Server::start(addr, coord.client())?;
        Ok((coord, server))
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting new connections (existing ones finish naturally).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn(stream: TcpStream, client: Client) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match handle_request(&line, &client) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("ok", false.into()), ("error", format!("{e:#}").into())]),
        };
        writeln!(writer, "{resp}")?;
    }
    Ok(())
}

fn plan_to_json(p: &StepPlan) -> Json {
    Json::obj(vec![
        ("starts", Json::arr_f64(&p.starts)),
        ("peaks", Json::arr_f64(&p.peaks)),
    ])
}

fn plan_from_json(j: &Json) -> Result<StepPlan> {
    let get_vec = |key: &str| -> Result<Vec<f64>> {
        j.get(key)
            .and_then(Json::as_arr)
            .with_context(|| format!("plan missing '{key}'"))?
            .iter()
            .map(|v| v.as_f64().context("non-number in plan"))
            .collect()
    };
    let starts = get_vec("starts")?;
    let peaks = get_vec("peaks")?;
    anyhow::ensure!(!starts.is_empty() && starts.len() == peaks.len(), "malformed plan");
    Ok(StepPlan::new(starts, peaks))
}

fn execution_from_json(task: &str, j: &Json) -> Result<Execution> {
    let input_mb = j.get("input_mb").and_then(Json::as_f64).context("input_mb")?;
    let dt = j.get("dt").and_then(Json::as_f64).context("dt")?;
    anyhow::ensure!(dt > 0.0, "dt must be positive");
    let samples: Result<Vec<f64>> = j
        .get("samples")
        .and_then(Json::as_arr)
        .context("samples")?
        .iter()
        .map(|v| v.as_f64().context("non-number sample"))
        .collect();
    let samples = samples?;
    // A sample-less execution has nothing to segment; rejecting it here
    // keeps garbage off the worker threads.
    anyhow::ensure!(!samples.is_empty(), "execution needs at least one sample");
    Ok(Execution::new(task, input_mb, dt, samples))
}

fn handle_request(line: &str, client: &Client) -> Result<Json> {
    let req = Json::parse(line).context("invalid JSON")?;
    let op = req.get("op").and_then(Json::as_str).context("missing 'op'")?;
    match op {
        "train" => {
            let task = req.get("task").and_then(Json::as_str).context("missing 'task'")?;
            let history: Result<Vec<Execution>> = req
                .get("history")
                .and_then(Json::as_arr)
                .context("missing 'history'")?
                .iter()
                .map(|j| execution_from_json(task, j))
                .collect();
            let history = history?;
            anyhow::ensure!(!history.is_empty(), "empty history");
            let n = history.len();
            client.train(task, history);
            Ok(Json::obj(vec![
                ("ok", true.into()),
                ("trained", task.into()),
                ("executions", n.into()),
            ]))
        }
        "observe" => {
            let task = req.get("task").and_then(Json::as_str).context("missing 'task'")?;
            let exec =
                execution_from_json(task, req.get("execution").context("missing 'execution'")?)?;
            let count = client.observe(task, exec);
            Ok(Json::obj(vec![
                ("ok", true.into()),
                ("observed", task.into()),
                ("executions", (count as usize).into()),
            ]))
        }
        "plan" => {
            let task = req.get("task").and_then(Json::as_str).context("missing 'task'")?;
            let input = req.get("input_mb").and_then(Json::as_f64).context("missing 'input_mb'")?;
            let plan = client.plan(task, input);
            Ok(Json::obj(vec![("ok", true.into()), ("plan", plan_to_json(&plan))]))
        }
        "failure" => {
            let prev = plan_from_json(req.get("plan").context("missing 'plan'")?)?;
            let t = req.get("fail_time").and_then(Json::as_f64).context("missing 'fail_time'")?;
            let plan = client.report_failure(&prev, t);
            Ok(Json::obj(vec![("ok", true.into()), ("plan", plan_to_json(&plan))]))
        }
        "stats" => {
            let s = client.stats();
            Ok(Json::obj(vec![
                ("ok", true.into()),
                ("shards", client.shards().into()),
                ("requests", (s.requests as usize).into()),
                ("batches", (s.batches as usize).into()),
                ("failures_handled", (s.failures_handled as usize).into()),
                ("tasks_trained", (s.tasks_trained as usize).into()),
                ("observations", (s.observations as usize).into()),
                ("latency_p50_us", s.latency_percentile_us(50.0).into()),
                ("latency_p99_us", s.latency_percentile_us(99.0).into()),
            ]))
        }
        other => anyhow::bail!("unknown op '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{Coordinator, CoordinatorConfig};
    use crate::coordinator::BackendSpec;
    use crate::util::rng::Rng;

    fn start() -> (Coordinator, Server) {
        Server::start_with_backend(
            "127.0.0.1:0",
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap()
    }

    fn roundtrip(stream: &mut TcpStream, req: &str) -> Json {
        writeln!(stream, "{req}").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(&line).unwrap()
    }

    fn train_req() -> String {
        let mut rng = Rng::new(1);
        let mut hist = Vec::new();
        for _ in 0..12 {
            let input = rng.uniform(2000.0, 10000.0);
            let n = ((input * 0.005) as usize).max(3);
            let samples: Vec<String> = (0..n)
                .map(|i| {
                    let lvl = if i < n / 2 { input * 0.0004 } else { input * 0.0009 };
                    format!("{:.4}", lvl)
                })
                .collect();
            hist.push(format!(
                r#"{{"input_mb":{input:.1},"dt":1.0,"samples":[{}]}}"#,
                samples.join(",")
            ));
        }
        format!(r#"{{"op":"train","task":"bwa","history":[{}]}}"#, hist.join(","))
    }

    #[test]
    fn train_plan_failure_roundtrip() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s, &train_req());
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("executions").and_then(Json::as_usize), Some(12));

        let r = roundtrip(&mut s, r#"{"op":"plan","task":"bwa","input_mb":6000}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let plan = r.get("plan").unwrap();
        let starts = plan.get("starts").unwrap().as_arr().unwrap();
        assert!(!starts.is_empty());

        let fail = format!(
            r#"{{"op":"failure","plan":{plan},"fail_time":5.0}}"#,
            plan = plan
        );
        let r = roundtrip(&mut s, &fail);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));

        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("tasks_trained").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn observe_streams_one_execution_at_a_time() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        for i in 0..3usize {
            let r = roundtrip(
                &mut s,
                &format!(
                    r#"{{"op":"observe","task":"bwa","execution":{{"input_mb":{},"dt":1.0,"samples":[1.0,1.2,{:.1}]}}}}"#,
                    4000 + i * 1000,
                    2.0 + i as f64
                ),
            );
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
            assert_eq!(r.get("observed").and_then(Json::as_str), Some("bwa"));
            assert_eq!(r.get("executions").and_then(Json::as_usize), Some(i + 1));
        }
        let r = roundtrip(&mut s, r#"{"op":"plan","task":"bwa","input_mb":5000}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("observations").and_then(Json::as_usize), Some(3));
        assert_eq!(r.get("tasks_trained").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn observe_op_equals_train_op() {
        // The same history, once as a batch `train` and once streamed
        // through `observe`, must yield identical plans (both paths are
        // native f64 sufficient statistics).
        let (_c1, trained) = start();
        let (_c2, observed) = start();
        let mut st = TcpStream::connect(trained.addr()).unwrap();
        let mut so = TcpStream::connect(observed.addr()).unwrap();
        let r = roundtrip(&mut st, &train_req());
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        // Stream the identical executions one by one.
        let req = Json::parse(&train_req()).unwrap();
        for e in req.get("history").unwrap().as_arr().unwrap() {
            let r = roundtrip(
                &mut so,
                &format!(r#"{{"op":"observe","task":"bwa","execution":{e}}}"#),
            );
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        }
        for input in [2500, 6000, 9500] {
            let a = roundtrip(&mut st, &format!(r#"{{"op":"plan","task":"bwa","input_mb":{input}}}"#));
            let b = roundtrip(&mut so, &format!(r#"{{"op":"plan","task":"bwa","input_mb":{input}}}"#));
            assert_eq!(a.get("plan"), b.get("plan"), "input {input}");
        }
    }

    #[test]
    fn malformed_requests_get_errors_not_disconnects() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        for bad in [
            "not json",
            r#"{"op":"frobnicate"}"#,
            r#"{"op":"plan"}"#,
            r#"{"op":"train","task":"x","history":[]}"#,
            r#"{"op":"failure","plan":{"starts":[],"peaks":[]},"fail_time":1}"#,
            r#"{"op":"observe","task":"x"}"#,
            r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1.0,"samples":[]}}"#,
            r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":0,"samples":[1.0]}}"#,
        ] {
            let r = roundtrip(&mut s, bad);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "req: {bad}");
            assert!(r.get("error").is_some());
        }
        // Connection still usable afterwards.
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn concurrent_connections_share_batcher() {
        let (coord, server) = start();
        let mut s0 = TcpStream::connect(server.addr()).unwrap();
        roundtrip(&mut s0, &train_req());
        let mut handles = Vec::new();
        for i in 0..8 {
            let addr = server.addr();
            handles.push(std::thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                for j in 0..10 {
                    let r = roundtrip(
                        &mut s,
                        &format!(
                            r#"{{"op":"plan","task":"bwa","input_mb":{}}}"#,
                            3000 + i * 100 + j
                        ),
                    );
                    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = coord.client().stats();
        assert_eq!(stats.requests, 80);
        assert!(stats.batches <= 80);
    }

    #[test]
    fn stop_unblocks_accept() {
        let (_coord, mut server) = start();
        server.stop(); // must not hang
    }

    #[test]
    fn stats_reports_shard_count() {
        let (_coord, server) = Server::start_with_backend(
            "127.0.0.1:0",
            CoordinatorConfig { k: 2, shards: 3, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("shards").and_then(Json::as_usize), Some(3));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn backend_build_error_propagates_through_server_start() {
        // The startup seam end-to-end: an unbuildable backend spec fails
        // the combined constructor before any socket is bound, instead of
        // panicking a detached worker thread.
        let err = Server::start_with_backend(
            "127.0.0.1:0",
            CoordinatorConfig::default(),
            BackendSpec::Pjrt(None),
        )
        .err()
        .expect("pjrt spec must not serve in a native-only build");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
    }
}
