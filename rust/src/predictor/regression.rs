//! Regression backend for the segment predictors.
//!
//! `FitEngine` abstracts where the batched OLS runs: `NativeFit` computes
//! the closed form in-process (always available; used by the offline
//! experiment harness and native-only builds); with the `pjrt` cargo
//! feature, `runtime::PjrtFitEngine` executes the AOT Pallas kernel
//! instead (used by the online coordinator). Both implement the *same*
//! closed form — `runtime::tests` asserts parity when artifacts exist.

use crate::util::stats;

/// One fitted affine model y = slope * x + intercept.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinModel {
    pub slope: f64,
    pub intercept: f64,
}

impl LinModel {
    pub fn predict(&self, x: f64) -> f64 {
        self.slope * x + self.intercept
    }

    pub fn fit(xs: &[f64], ys: &[f64]) -> LinModel {
        let (slope, intercept) = stats::ols(xs, ys);
        LinModel { slope, intercept }
    }
}

/// A batch of independent OLS problems: each row is (xs, ys).
///
/// Deliberately NOT `Send`/`Sync`: the PJRT engine wraps thread-affine
/// FFI handles; the coordinator owns its engine on one worker thread.
pub trait FitEngine {
    fn fit_batch(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Vec<LinModel>;
}

/// In-process closed-form OLS.
#[derive(Debug, Default, Clone)]
pub struct NativeFit;

impl FitEngine for NativeFit {
    fn fit_batch(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Vec<LinModel> {
        rows.iter().map(|(xs, ys)| LinModel::fit(xs, ys)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn fit_exact_line() {
        let xs = [1.0, 2.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| -0.5 * x + 3.0).collect();
        let m = LinModel::fit(&xs, &ys);
        assert!((m.slope + 0.5).abs() < 1e-9);
        assert!((m.intercept - 3.0).abs() < 1e-9);
        assert!((m.predict(10.0) + 2.0).abs() < 1e-9);
    }

    #[test]
    fn batch_matches_individual() {
        let rows = vec![
            (vec![1.0, 2.0, 3.0], vec![2.0, 4.0, 6.0]),
            (vec![0.0, 1.0], vec![5.0, 5.0]),
            (vec![7.0], vec![3.0]),
        ];
        let batch = NativeFit.fit_batch(&rows);
        for (i, (xs, ys)) in rows.iter().enumerate() {
            assert_eq!(batch[i], LinModel::fit(xs, ys));
        }
    }

    #[test]
    fn prop_fit_residuals_sum_to_zero() {
        // OLS with intercept has zero mean residual.
        run_prop("ols_residual_zero", 150, |rng| {
            let n = 2 + rng.below(30);
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 100.0)).collect();
            let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + rng.normal_ms(0.0, 5.0)).collect();
            let m = LinModel::fit(&xs, &ys);
            let mean_resid = xs
                .iter()
                .zip(&ys)
                .map(|(x, y)| y - m.predict(*x))
                .sum::<f64>()
                / n as f64;
            assert!(mean_resid.abs() < 1e-6, "mean residual {mean_resid}");
        });
    }
}
