//! Bench for Fig 7: KS+ wastage across k = 2..10 (robustness sweep),
//! plus the greedy-vs-optimal segmentation ablation from DESIGN.md.

use ksplus::experiments::{evaluate_method, ExpConfig};
use ksplus::segments::algorithm::{get_segments, optimal_segments};
use ksplus::trace::workflow::Workflow;
use ksplus::util::bench::{bench, black_box};

fn main() {
    let cfg = ExpConfig::default();
    // Part 1: the figure itself.
    for wf in [Workflow::eager(), Workflow::sarek()] {
        let trace = wf.generate(cfg.trace_seed, cfg.target_samples);
        println!("== fig7 bench: {} ==", wf.name);
        for k in [2usize, 4, 6, 8, 10] {
            let mut wastage = 0.0;
            bench(&format!("{}/k={k}", wf.name), 0, 3, || {
                let rep =
                    evaluate_method("ksplus", k, cfg.capacity_gb, &wf, &trace, 0.5, 1)
                        .unwrap();
                wastage = black_box(rep.total_wastage_gbs());
            });
            println!("  -> k={k}: {wastage:.0} GBs");
        }
    }

    // Part 2 (ablation): greedy Algorithm 1 vs exact DP — wastage gap
    // and speed gap on real bwa series.
    let wf = Workflow::eager();
    let trace = wf.generate(cfg.trace_seed, cfg.target_samples);
    let bwa = trace.task("bwa").unwrap();
    let series: Vec<&Vec<f64>> =
        bwa.executions.iter().take(30).map(|e| &e.samples).collect();
    for k in [2usize, 4, 8] {
        let mut greedy_err = 0.0;
        let mut dp_err = 0.0;
        let rg = bench(&format!("greedy/k={k}"), 1, 10, || {
            greedy_err = series
                .iter()
                .map(|s| black_box(get_segments(s, k)).envelope_error(s))
                .sum();
        });
        let rd = bench(&format!("dp-optimal/k={k}"), 1, 10, || {
            dp_err = series
                .iter()
                .map(|s| black_box(optimal_segments(s, k)).envelope_error(s))
                .sum();
        });
        println!(
            "  -> k={k}: greedy error {greedy_err:.1} vs optimal {dp_err:.1} \
             ({}x error, {:.0}x faster)",
            if dp_err > 0.0 { format!("{:.3}", greedy_err / dp_err) } else { "inf".into() },
            rd.median_s / rg.median_s
        );
    }
}
