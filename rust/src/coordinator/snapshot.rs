//! Versioned snapshot/restore for `ModelStore` — the persistence layer
//! that makes the coordinator crash-safe.
//!
//! A trained task is tiny: 2k `OlsStats` accumulators (five `f64`s each),
//! a policy binding, a fallback peak, and an observation count — a few
//! hundred bytes. Non-KS+ policies additionally carry their bounded
//! retained history window (at most `ALT_HISTORY_CAP` executions). This
//! module serializes exactly that state, and *only* that state: the
//! closed-form models are NOT persisted, because they are a pure function
//! of the accumulators (`OlsStats::fit`) and of the retained history
//! (`Predictor::train`). Restoring refits from the raw numbers, and since
//! the crate's JSON formats `f64`s shortest-roundtrip (bit-exact through
//! a parse), a restored store serves **bit-identical plans** to the store
//! it was snapshotted from — the property the persistence tests pin.
//!
//! Three layers share the [`TaskState`] unit:
//!   * the on-disk snapshot file (`snapshot.json`, schema
//!     [`SNAPSHOT_SCHEMA`], written atomically via rename),
//!   * the `snapshot` wire op (the same JSON document, inline), and
//!   * in-process shard handoff (resharding and replica recovery move
//!     `Vec<TaskState>` through the worker channels without touching
//!     JSON at all).
//!
//! Restore is strict: the schema string, `k`, and `capacity_gb` must
//! match the receiving store — silently reinterpreting accumulators fit
//! under different hyperparameters would serve wrong plans with full
//! confidence.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::faults::FaultPlane;
use super::protocol::{execution_from_json, execution_to_json};
use super::{AltModel, ModelStore, PredictorPolicy, TaskModels, ALT_HISTORY_CAP};
use crate::predictor::regression::OlsStats;
use crate::trace::Execution;
use crate::util::json::Json;

/// Schema tag of the snapshot document; bump on breaking layout changes.
pub const SNAPSHOT_SCHEMA: &str = "ksplus-model-snapshot/v1";

/// File name of the current snapshot inside a snapshot directory.
pub const SNAPSHOT_FILE: &str = "snapshot.json";

/// Serializable per-task model state: the complete learned state of one
/// task, sufficient to reconstruct bit-identical plans. This is the unit
/// moved between shards during resharding and replica recovery, and the
/// unit stored in the snapshot file's `tasks` array.
#[derive(Debug, Clone)]
pub struct TaskState {
    pub task: String,
    /// The task's effective policy binding.
    pub policy: PredictorPolicy,
    /// KS+ sufficient-statistics state, if any.
    pub ks: Option<KsState>,
    /// Non-KS+ retained-history state, if any.
    pub alt: Option<AltState>,
}

/// The KS+ fast path's learned state: raw accumulators, not models.
#[derive(Debug, Clone)]
pub struct KsState {
    /// The 2k regressions' sufficient statistics (k starts, then k peaks).
    pub stats: Vec<OlsStats>,
    pub fallback_peak: f64,
    pub observed: u64,
}

/// A non-KS+ policy's learned state: the bounded history window its
/// predictor is refit from, plus the policy that owns it.
#[derive(Debug, Clone)]
pub struct AltState {
    pub policy: PredictorPolicy,
    pub history: Vec<Execution>,
    pub observed: u64,
}

/// Parsed snapshot document: store-wide settings plus every task.
#[derive(Debug, Clone)]
pub struct SnapshotDoc {
    pub k: usize,
    pub capacity_gb: f64,
    pub default_policy: PredictorPolicy,
    pub tasks: Vec<TaskState>,
}

fn ols_to_json(s: &OlsStats) -> Json {
    Json::obj(vec![
        ("n", s.n.into()),
        ("sx", s.sx.into()),
        ("sy", s.sy.into()),
        ("sxx", s.sxx.into()),
        ("sxy", s.sxy.into()),
    ])
}

fn f64_of(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("snapshot field '{key}' missing or not a number"))
}

fn ols_from_json(j: &Json) -> Result<OlsStats> {
    Ok(OlsStats {
        n: f64_of(j, "n")?,
        sx: f64_of(j, "sx")?,
        sy: f64_of(j, "sy")?,
        sxx: f64_of(j, "sxx")?,
        sxy: f64_of(j, "sxy")?,
    })
}

fn policy_of_json(j: &Json, key: &str) -> Result<PredictorPolicy> {
    let name = j
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("snapshot field '{key}' missing or not a string"))?;
    PredictorPolicy::parse(name).ok_or_else(|| anyhow!("unknown policy '{name}' in snapshot"))
}

impl TaskState {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("task", Json::from(self.task.as_str())),
            ("policy", self.policy.name().into()),
        ];
        if let Some(ks) = &self.ks {
            fields.push((
                "ks",
                Json::obj(vec![
                    ("stats", Json::Arr(ks.stats.iter().map(ols_to_json).collect())),
                    ("fallback_peak", ks.fallback_peak.into()),
                    ("observed", (ks.observed as usize).into()),
                ]),
            ));
        }
        if let Some(alt) = &self.alt {
            fields.push((
                "alt",
                Json::obj(vec![
                    ("policy", alt.policy.name().into()),
                    (
                        "history",
                        Json::Arr(alt.history.iter().map(execution_to_json).collect()),
                    ),
                    ("observed", (alt.observed as usize).into()),
                ]),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<TaskState> {
        let task = j
            .get("task")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("snapshot task entry without a 'task' name"))?
            .to_string();
        let policy = policy_of_json(j, "policy")?;
        let ks = match j.get("ks") {
            None => None,
            Some(kj) => {
                let stats = kj
                    .get("stats")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("task '{task}': 'ks.stats' missing"))?
                    .iter()
                    .map(ols_from_json)
                    .collect::<Result<Vec<_>>>()
                    .with_context(|| format!("task '{task}'"))?;
                Some(KsState {
                    stats,
                    fallback_peak: f64_of(kj, "fallback_peak")
                        .with_context(|| format!("task '{task}'"))?,
                    observed: f64_of(kj, "observed")
                        .with_context(|| format!("task '{task}'"))?
                        as u64,
                })
            }
        };
        let alt = match j.get("alt") {
            None => None,
            Some(aj) => {
                let history = aj
                    .get("history")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("task '{task}': 'alt.history' missing"))?
                    .iter()
                    .map(|e| execution_from_json(&task, e))
                    .collect::<Result<Vec<_>, _>>()
                    .map_err(|e| anyhow!("task '{task}': bad history execution: {e}"))?;
                Some(AltState {
                    policy: policy_of_json(aj, "policy")
                        .with_context(|| format!("task '{task}'"))?,
                    history,
                    observed: f64_of(aj, "observed")
                        .with_context(|| format!("task '{task}'"))?
                        as u64,
                })
            }
        };
        Ok(TaskState { task, policy, ks, alt })
    }
}

/// Assemble the full snapshot document from store settings + task states.
pub fn snapshot_to_json(
    k: usize,
    capacity_gb: f64,
    default_policy: PredictorPolicy,
    tasks: &[TaskState],
) -> Json {
    Json::obj(vec![
        ("schema", SNAPSHOT_SCHEMA.into()),
        ("k", k.into()),
        ("capacity_gb", capacity_gb.into()),
        ("default_policy", default_policy.name().into()),
        ("tasks", Json::Arr(tasks.iter().map(TaskState::to_json).collect())),
    ])
}

/// Parse and validate a snapshot document (schema check included).
pub fn parse_snapshot(doc: &Json) -> Result<SnapshotDoc> {
    let schema = doc.get("schema").and_then(Json::as_str).unwrap_or("<missing>");
    if schema != SNAPSHOT_SCHEMA {
        bail!("unsupported snapshot schema '{schema}' (this build reads '{SNAPSHOT_SCHEMA}')");
    }
    let k = doc
        .get("k")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("snapshot 'k' missing or not an integer"))?;
    let capacity_gb = f64_of(doc, "capacity_gb")?;
    let default_policy = policy_of_json(doc, "default_policy")?;
    let tasks = doc
        .get("tasks")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("snapshot 'tasks' missing or not an array"))?
        .iter()
        .map(TaskState::from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(SnapshotDoc { k, capacity_gb, default_policy, tasks })
}

impl ModelStore {
    /// Every task name with any recorded state *or* an explicit policy
    /// binding — the set a snapshot or a shard handoff must cover
    /// (`tasks()` alone misses configure-only bindings).
    pub fn stateful_tasks(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.extend(self.alt.keys().cloned());
        v.extend(self.policies.keys().cloned());
        v.sort();
        v.dedup();
        v
    }

    /// Extract one task's complete learned state, or `None` if the store
    /// has nothing recorded for it.
    pub fn export_task(&self, task: &str) -> Option<TaskState> {
        let bound = self.policies.get(task).copied();
        let ks = self.models.get(task).map(|tm| KsState {
            stats: tm.stats.clone(),
            fallback_peak: tm.fallback_peak,
            observed: tm.observed,
        });
        let alt = self.alt.get(task).map(|am| AltState {
            policy: am.policy,
            history: am.history.clone(),
            observed: am.observed,
        });
        if bound.is_none() && ks.is_none() && alt.is_none() {
            return None;
        }
        Some(TaskState {
            task: task.to_string(),
            policy: bound.unwrap_or(self.default_policy),
            ks,
            alt,
        })
    }

    /// Drop every trace of a task (models, history, binding).
    pub fn remove_task(&mut self, task: &str) {
        self.models.remove(task);
        self.alt.remove(task);
        self.policies.remove(task);
    }

    /// Overwrite this store's state for `st.task` with the imported
    /// state. Closed-form models are refit from the raw accumulators and
    /// the retained history — pure functions of the imported numbers —
    /// so an exported-then-imported task serves bit-identical plans.
    pub fn import_task(&mut self, st: TaskState) -> Result<()> {
        if let Some(ks) = &st.ks {
            if ks.stats.len() != 2 * self.k {
                bail!(
                    "task '{}' carries {} accumulators but this store's k={} needs {}",
                    st.task,
                    ks.stats.len(),
                    self.k,
                    2 * self.k
                );
            }
        }
        self.remove_task(&st.task);
        self.policies.insert(st.task.clone(), st.policy);
        if let Some(ks) = st.ks {
            let mut tm = TaskModels {
                stats: ks.stats,
                start_models: Vec::new(),
                peak_models: Vec::new(),
                fallback_peak: ks.fallback_peak,
                observed: ks.observed,
            };
            tm.refit(self.k);
            self.models.insert(st.task.clone(), tm);
        }
        if let Some(mut alt) = st.alt {
            if alt.history.len() > ALT_HISTORY_CAP {
                // Defensive: exports never exceed the cap, but a
                // hand-edited file must not grow the window.
                alt.history.drain(..alt.history.len() - ALT_HISTORY_CAP);
            }
            let mut pred = alt.policy.build(self.k, self.capacity_gb);
            if !alt.history.is_empty() {
                pred.train(&alt.history);
            }
            self.alt.insert(
                st.task.clone(),
                AltModel {
                    policy: alt.policy,
                    pred,
                    history: alt.history,
                    observed: alt.observed,
                },
            );
        }
        Ok(())
    }

    /// Serialize the store's complete learned state as a versioned JSON
    /// document (settings + every task's `TaskState`).
    pub fn snapshot(&self) -> Json {
        let tasks: Vec<TaskState> = self
            .stateful_tasks()
            .iter()
            .filter_map(|t| self.export_task(t))
            .collect();
        snapshot_to_json(self.k, self.capacity_gb, self.default_policy, &tasks)
    }

    /// Load a snapshot produced by [`ModelStore::snapshot`], replacing
    /// state for every task it carries (tasks absent from the snapshot
    /// are left alone). Strict about hyperparameters: the snapshot's `k`
    /// and `capacity_gb` must match this store's. Returns the number of
    /// tasks restored.
    pub fn restore(&mut self, doc: &Json) -> Result<usize> {
        let snap = parse_snapshot(doc)?;
        if snap.k != self.k {
            bail!("snapshot was taken with k={} but this store runs k={}", snap.k, self.k);
        }
        if snap.capacity_gb != self.capacity_gb {
            bail!(
                "snapshot was taken with capacity_gb={} but this store runs capacity_gb={}",
                snap.capacity_gb,
                self.capacity_gb
            );
        }
        self.default_policy = snap.default_policy;
        let n = snap.tasks.len();
        for st in snap.tasks {
            self.import_task(st)?;
        }
        Ok(n)
    }
}

/// Path of the snapshot file inside a snapshot directory.
pub fn snapshot_path(dir: &Path) -> PathBuf {
    dir.join(SNAPSHOT_FILE)
}

/// Write a snapshot document atomically and durably: `.tmp` + fsync +
/// rename (+ a directory fsync on unix, so the rename itself survives a
/// power cut), creating the directory if needed. A crash mid-write never
/// corrupts the previous snapshot. Returns the final path.
pub fn write_snapshot_file(dir: &Path, doc: &Json) -> Result<PathBuf> {
    write_snapshot_file_faulted(dir, doc, None)
}

/// [`write_snapshot_file`] with the snapshot-seam fault hook. A firing
/// torn-write fault simulates the post-crash state of a *non-atomic*
/// writer — a truncated prefix in the final path — and reports the write
/// as failed; [`load_snapshot_file`] must then classify that debris as
/// `Corrupt` rather than wedging startup.
pub fn write_snapshot_file_faulted(
    dir: &Path,
    doc: &Json,
    faults: Option<&FaultPlane>,
) -> Result<PathBuf> {
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let path = snapshot_path(dir);
    let tmp = dir.join(format!("{SNAPSHOT_FILE}.tmp"));
    let bytes = format!("{doc}\n").into_bytes();
    if let Some(f) = faults {
        if let Some(keep) = f.tear_snapshot(bytes.len()) {
            fs::write(&path, &bytes[..keep])
                .with_context(|| format!("writing {}", path.display()))?;
            bail!(
                "injected torn snapshot write: {keep} of {} bytes reached {}",
                bytes.len(),
                path.display()
            );
        }
    }
    let mut file =
        fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    file.write_all(&bytes).with_context(|| format!("writing {}", tmp.display()))?;
    // Data must be durable *before* the rename publishes the file, or a
    // crash can leave a renamed-but-empty snapshot — exactly the torn
    // state the fault above injects.
    file.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    drop(file);
    fs::rename(&tmp, &path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    #[cfg(unix)]
    if let Ok(d) = fs::File::open(dir) {
        // Best effort: persist the rename's directory entry too.
        d.sync_all().ok();
    }
    Ok(path)
}

/// What a snapshot directory held, read leniently.
#[derive(Debug)]
pub enum SnapshotLoad {
    /// No snapshot yet — a fresh start, not an error.
    Missing,
    /// A complete, parseable document ([`ModelStore::restore`] may still
    /// reject it on schema/hyperparameter grounds).
    Loaded(Json),
    /// The file exists but is not a parseable document — the signature
    /// of a torn write. Structured so callers can warn and start fresh
    /// instead of refusing to boot.
    Corrupt { path: PathBuf, reason: String },
}

/// Read the snapshot file from a directory, classifying an unparseable
/// file as [`SnapshotLoad::Corrupt`] instead of failing.
pub fn load_snapshot_file(dir: &Path) -> Result<SnapshotLoad> {
    let path = snapshot_path(dir);
    let text = match fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(SnapshotLoad::Missing)
        }
        Err(e) => return Err(anyhow!("reading {}: {e}", path.display())),
    };
    match Json::parse(&text) {
        Ok(doc) => Ok(SnapshotLoad::Loaded(doc)),
        Err(e) => Ok(SnapshotLoad::Corrupt { path, reason: format!("{e:?}") }),
    }
}

/// Read the snapshot file from a directory; `Ok(None)` when none exists
/// yet (a fresh start, not an error). Strict sibling of
/// [`load_snapshot_file`]: an unparseable file is a hard error.
pub fn read_snapshot_file(dir: &Path) -> Result<Option<Json>> {
    match load_snapshot_file(dir)? {
        SnapshotLoad::Missing => Ok(None),
        SnapshotLoad::Loaded(doc) => Ok(Some(doc)),
        SnapshotLoad::Corrupt { path, reason } => {
            Err(anyhow!("parsing {}: {reason}", path.display()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;
    use crate::util::rng::Rng;

    fn exec(task: &str, input: f64, rng: &mut Rng) -> Execution {
        let d1 = ((input * 0.01) as usize).clamp(2, 40);
        let d2 = ((input * 0.003) as usize).clamp(1, 20);
        let mut s = vec![input * 0.0005; d1];
        s.extend(vec![input * 0.001; d2]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new(task, input, 1.0, s)
    }

    fn store_with_every_policy(k: usize) -> ModelStore {
        let mut store = ModelStore::new(k, 128.0, Backend::Native);
        let mut rng = Rng::new(0xA11CE);
        for (i, p) in PredictorPolicy::ALL.iter().enumerate() {
            let task = format!("task-{}", p.name());
            store.configure(&task, *p);
            for _ in 0..12 {
                let e = exec(&task, 2000.0 + 700.0 * i as f64 + rng.uniform(0.0, 6000.0), &mut rng);
                store.observe(&task, &e);
            }
        }
        // A configure-only binding with no trained state must survive too.
        store.configure("bound-only", PredictorPolicy::TovarPpm);
        store
    }

    fn assert_same_plans(a: &ModelStore, b: &ModelStore) {
        for task in a.stateful_tasks() {
            assert_eq!(a.policy_of(&task), b.policy_of(&task), "{task}");
            for input in [500.0, 2500.0, 7000.0, 14000.0] {
                let pa = a.plan_batch_outcomes(&[(task.as_str(), input)]);
                let pb = b.plan_batch_outcomes(&[(task.as_str(), input)]);
                assert_eq!(pa, pb, "task {task} input {input}");
            }
        }
    }

    #[test]
    fn ols_stats_roundtrip_bit_exact_through_text() {
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let mut s = OlsStats::default();
            for _ in 0..10 {
                s.push(rng.uniform(0.0, 1e5), rng.uniform(0.0, 1e3));
            }
            let text = ols_to_json(&s).to_string();
            let back = ols_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(s, back, "accumulators must survive text bit-exactly");
        }
    }

    #[test]
    fn snapshot_restore_is_bit_identical_for_every_policy() {
        let store = store_with_every_policy(3);
        let doc = store.snapshot();
        // Through the full text layer, as the file and the wire would.
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        let mut restored = ModelStore::new(3, 128.0, Backend::Native);
        let n = restored.restore(&reparsed).unwrap();
        assert_eq!(n, PredictorPolicy::ALL.len() + 1);
        assert_same_plans(&store, &restored);
        // Model versions (observation counts) survive exactly.
        for p in PredictorPolicy::ALL {
            let task = format!("task-{}", p.name());
            let va = store.plan_batch_outcomes(&[(task.as_str(), 3000.0)])[0].model_version;
            let vb = restored.plan_batch_outcomes(&[(task.as_str(), 3000.0)])[0].model_version;
            assert_eq!(va, vb, "{task}");
        }
        assert_eq!(restored.policy_of("bound-only"), PredictorPolicy::TovarPpm);
    }

    #[test]
    fn alt_history_window_task_survives_restore() {
        // A task past the retention cap: the snapshot carries only the
        // window, but the observation count and served plans must match.
        let total = ALT_HISTORY_CAP + 24;
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.configure("w", PredictorPolicy::WittLr);
        for i in 0..total {
            let input = 1000.0 + i as f64;
            store.observe("w", &Execution::new("w", input, 1.0, vec![0.001 * input, 0.002 * input]));
        }
        let doc = Json::parse(&store.snapshot().to_string()).unwrap();
        let mut restored = ModelStore::new(2, 128.0, Backend::Native);
        restored.restore(&doc).unwrap();
        let a = store.plan_batch_outcomes(&[("w", 5000.0)]);
        let b = restored.plan_batch_outcomes(&[("w", 5000.0)]);
        assert_eq!(a, b);
        assert_eq!(a[0].model_version, total as u64);
    }

    #[test]
    fn restore_keeps_counting_from_where_the_snapshot_left_off() {
        // Observing after a restore continues the same trajectory the
        // original store would have taken (accumulators, not models, are
        // what the snapshot carries).
        let mut rng = Rng::new(99);
        let execs: Vec<Execution> = (0..20).map(|_| exec("bwa", rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut original = ModelStore::new(2, 128.0, Backend::Native);
        for e in &execs[..10] {
            original.observe("bwa", e);
        }
        let doc = Json::parse(&original.snapshot().to_string()).unwrap();
        let mut restored = ModelStore::new(2, 128.0, Backend::Native);
        restored.restore(&doc).unwrap();
        for e in &execs[10..] {
            original.observe("bwa", e);
            restored.observe("bwa", e);
        }
        assert_same_plans(&original, &restored);
    }

    #[test]
    fn restore_rejects_mismatched_schema_k_and_capacity() {
        let store = store_with_every_policy(2);
        let doc = store.snapshot();
        let mut wrong_k = ModelStore::new(3, 128.0, Backend::Native);
        let err = wrong_k.restore(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("k="), "{err:#}");
        let mut wrong_cap = ModelStore::new(2, 64.0, Backend::Native);
        let err = wrong_cap.restore(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("capacity"), "{err:#}");
        let bad = Json::obj(vec![("schema", "nope/v9".into())]);
        let mut fresh = ModelStore::new(2, 128.0, Backend::Native);
        let err = fresh.restore(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("schema"), "{err:#}");
    }

    #[test]
    fn snapshot_file_roundtrips_atomically() {
        let dir = std::env::temp_dir()
            .join(format!("ksplus-snapshot-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        assert!(read_snapshot_file(&dir).unwrap().is_none(), "no file yet");
        let store = store_with_every_policy(2);
        let doc = store.snapshot();
        let path = write_snapshot_file(&dir, &doc).unwrap();
        assert!(path.ends_with(SNAPSHOT_FILE));
        let back = read_snapshot_file(&dir).unwrap().expect("snapshot written");
        let mut restored = ModelStore::new(2, 128.0, Backend::Native);
        restored.restore(&back).unwrap();
        assert_same_plans(&store, &restored);
        // No .tmp litter after a successful write.
        assert!(!dir.join(format!("{SNAPSHOT_FILE}.tmp")).exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_write_fault_is_reported_and_classified_as_corrupt() {
        use crate::coordinator::faults::FaultSpec;
        let dir = std::env::temp_dir()
            .join(format!("ksplus-torn-snapshot-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let store = store_with_every_policy(2);
        let doc = store.snapshot();
        let plane =
            FaultSpec { seed: 41, torn: 1.0, ..FaultSpec::default() }.plane();
        let err = write_snapshot_file_faulted(&dir, &doc, Some(plane.as_ref())).unwrap_err();
        assert!(err.to_string().contains("torn"), "{err}");
        // The debris is a strict prefix: lenient load classifies it,
        // strict read refuses it, and neither panics.
        match load_snapshot_file(&dir).unwrap() {
            SnapshotLoad::Corrupt { path, .. } => assert!(path.ends_with(SNAPSHOT_FILE)),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        assert!(read_snapshot_file(&dir).is_err());
        // Recovery: a clean write replaces the debris and loads again.
        write_snapshot_file(&dir, &doc).unwrap();
        match load_snapshot_file(&dir).unwrap() {
            SnapshotLoad::Loaded(back) => {
                let mut restored = ModelStore::new(2, 128.0, Backend::Native);
                restored.restore(&back).unwrap();
                assert_same_plans(&store, &restored);
            }
            other => panic!("expected Loaded, got {other:?}"),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn hand_truncated_snapshot_is_corrupt_not_fatal() {
        let dir = std::env::temp_dir()
            .join(format!("ksplus-truncated-snapshot-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let store = store_with_every_policy(2);
        write_snapshot_file(&dir, &store.snapshot()).unwrap();
        let path = snapshot_path(&dir);
        let full = fs::read(&path).unwrap();
        fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(matches!(
            load_snapshot_file(&dir).unwrap(),
            SnapshotLoad::Corrupt { .. }
        ));
        let _ = fs::remove_dir_all(&dir);
    }
}
