//! Workflow definitions: task types, instance counts, DAG structure.
//!
//! The evaluation treats task instances independently (as the paper does),
//! but the DAG is retained so the online coordinator example can submit
//! tasks in dependency order like a real SWMS engine would.

use crate::trace::synth::{self, Archetype};
use crate::trace::{TaskTraces, WorkflowTrace};
use crate::util::rng::Rng;

/// A workflow = named task types with instance counts and dependencies.
#[derive(Debug, Clone)]
pub struct Workflow {
    pub name: &'static str,
    pub archetypes: Vec<Archetype>,
    pub counts: Vec<(&'static str, usize)>,
    /// DAG edges between task types: (upstream, downstream).
    pub edges: Vec<(&'static str, &'static str)>,
}

impl Workflow {
    pub fn eager() -> Workflow {
        Workflow {
            name: "eager",
            archetypes: synth::eager_archetypes(),
            counts: synth::eager_counts(),
            edges: vec![
                ("fastqc", "adapter_removal"),
                ("adapter_removal", "bwa"),
                ("bwa", "samtools"),
                ("samtools", "dedup"),
                ("dedup", "damageprofiler"),
                ("dedup", "mtnucratio"),
                ("dedup", "preseq"),
                ("dedup", "qualimap"),
            ],
        }
    }

    pub fn sarek() -> Workflow {
        Workflow {
            name: "sarek",
            archetypes: synth::sarek_archetypes(),
            counts: synth::sarek_counts(),
            edges: vec![
                ("fastqc", "bwamem2"),
                ("bwamem2", "markduplicates"),
                ("markduplicates", "baserecalibrator"),
                ("baserecalibrator", "applybqsr"),
                ("applybqsr", "strelka"),
                ("applybqsr", "mutect2"),
                ("applybqsr", "samtools_stats"),
                ("applybqsr", "mosdepth"),
                ("strelka", "snpeff"),
                ("mutect2", "vep"),
                ("snpeff", "tabix"),
                ("vep", "tabix"),
            ],
        }
    }

    pub fn by_name(name: &str) -> Option<Workflow> {
        match name {
            "eager" => Some(Workflow::eager()),
            "sarek" => Some(Workflow::sarek()),
            _ => None,
        }
    }

    pub fn archetype(&self, task: &str) -> Option<&Archetype> {
        self.archetypes.iter().find(|a| a.name == task)
    }

    /// Generate the full workflow trace; pure function of the seed.
    pub fn generate(&self, seed: u64, target_samples: usize) -> WorkflowTrace {
        let mut root = Rng::new(seed);
        let mut tasks = Vec::new();
        for (i, (name, n)) in self.counts.iter().enumerate() {
            let a = self.archetype(name).expect("count refers to unknown archetype");
            let mut rng = root.fork(i as u64 + 1);
            tasks.push(a.generate_many(&mut rng, *n, target_samples));
        }
        WorkflowTrace { name: self.name.to_string(), tasks }
    }

    /// Task types in topological order (Kahn). Panics on cycles, which
    /// would be a bug in the static definitions above.
    pub fn topo_order(&self) -> Vec<&'static str> {
        let names: Vec<&'static str> = self.counts.iter().map(|(n, _)| *n).collect();
        let mut indeg: Vec<usize> = names
            .iter()
            .map(|n| self.edges.iter().filter(|(_, d)| d == n).count())
            .collect();
        let mut order = Vec::with_capacity(names.len());
        let mut ready: Vec<usize> =
            (0..names.len()).filter(|&i| indeg[i] == 0).collect();
        while let Some(i) = ready.pop() {
            order.push(names[i]);
            for (u, d) in &self.edges {
                if *u == names[i] {
                    let j = names.iter().position(|n| n == d).expect("edge to unknown task");
                    indeg[j] -= 1;
                    if indeg[j] == 0 {
                        ready.push(j);
                    }
                }
            }
        }
        assert_eq!(order.len(), names.len(), "workflow DAG has a cycle");
        order
    }

    /// Upstream dependencies of a task type.
    pub fn deps(&self, task: &str) -> Vec<&'static str> {
        self.edges.iter().filter(|(_, d)| *d == task).map(|(u, _)| *u).collect()
    }
}

/// Fig 5 summary row: per-task instance counts and peak statistics.
#[derive(Debug, Clone)]
pub struct TaskSummary {
    pub task: String,
    pub instances: usize,
    pub mean_peak_gb: f64,
    pub median_peak_gb: f64,
    pub max_peak_gb: f64,
}

pub fn summarize(trace: &WorkflowTrace) -> Vec<TaskSummary> {
    trace
        .tasks
        .iter()
        .map(|t: &TaskTraces| {
            let peaks = t.peaks();
            TaskSummary {
                task: t.task.clone(),
                instances: t.executions.len(),
                mean_peak_gb: crate::util::stats::mean(&peaks),
                median_peak_gb: crate::util::stats::median(&peaks),
                max_peak_gb: peaks.iter().cloned().fold(0.0, f64::max),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eager_has_nine_tasks() {
        let wf = Workflow::eager();
        assert_eq!(wf.counts.len(), 9);
        assert!(wf.archetype("bwa").is_some());
    }

    #[test]
    fn sarek_has_twelve_tasks() {
        assert_eq!(Workflow::sarek().counts.len(), 12);
    }

    #[test]
    fn generate_is_seed_deterministic() {
        let wf = Workflow::eager();
        let a = wf.generate(7, 100);
        let b = wf.generate(7, 100);
        assert_eq!(a.total_instances(), b.total_instances());
        assert_eq!(a.tasks[0].executions[0], b.tasks[0].executions[0]);
        let c = wf.generate(8, 100);
        assert_ne!(a.tasks[0].executions[0], c.tasks[0].executions[0]);
    }

    #[test]
    fn counts_match_generated() {
        let wf = Workflow::sarek();
        let tr = wf.generate(1, 80);
        for (name, n) in &wf.counts {
            assert_eq!(tr.task(name).unwrap().executions.len(), *n);
        }
    }

    #[test]
    fn topo_order_respects_edges() {
        for wf in [Workflow::eager(), Workflow::sarek()] {
            let order = wf.topo_order();
            for (u, d) in &wf.edges {
                let pu = order.iter().position(|n| n == u).unwrap();
                let pd = order.iter().position(|n| n == d).unwrap();
                assert!(pu < pd, "{u} must precede {d}");
            }
        }
    }

    #[test]
    fn deps_lookup() {
        let wf = Workflow::eager();
        assert_eq!(wf.deps("bwa"), vec!["adapter_removal"]);
        assert!(wf.deps("fastqc").is_empty());
    }

    #[test]
    fn by_name_roundtrip() {
        assert!(Workflow::by_name("eager").is_some());
        assert!(Workflow::by_name("sarek").is_some());
        assert!(Workflow::by_name("nope").is_none());
    }

    #[test]
    fn summarize_covers_all_tasks() {
        let wf = Workflow::eager();
        let tr = wf.generate(3, 80);
        let s = summarize(&tr);
        assert_eq!(s.len(), 9);
        let bwa = s.iter().find(|r| r.task == "bwa").unwrap();
        assert!(bwa.mean_peak_gb > 5.0);
        assert!(bwa.max_peak_gb >= bwa.median_peak_gb);
    }
}
