//! Closed-loop service load generator: measures the sharded coordinator
//! the way a workflow engine would drive it — M client threads, each
//! blocking on its previous plan before submitting the next — and reports
//! plans/sec and latency percentiles per shard count.
//!
//! This is the scaling proof for the worker pool: at equal client count,
//! `shards: N` on an N-core machine should sustain a multiple of the
//! single-shard throughput because every shard owns an independent model
//! store, backend, and batcher. Exposed as `repro loadgen`.
//!
//! The generator can also drive the coordinator through a real TCP front
//! end instead of the in-process `Client` (`--server threaded` or
//! `--server eventloop`), on either wire (`--wire v1|v2`) and with
//! request pipelining (`--pipeline N` in-flight requests per
//! connection). That turns the same workload into an apples-to-apples
//! comparison of the serving stacks: the in-process numbers bound what
//! the pool itself can do, and the per-front-end numbers show what each
//! transport layer costs on top.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

#[cfg(unix)]
use crate::coordinator::eventloop::EventLoopServer;
use crate::coordinator::faults::FaultSpec;
use crate::coordinator::protocol::{Request, Response};
use crate::coordinator::remote::{ClientCounters, RemoteClient, ResilientClient, RetryPolicy};
use crate::coordinator::server::{Server, ServerConfig};
use crate::coordinator::service::{
    Client, ConnCounters, Coordinator, CoordinatorConfig, ServiceStats,
};
use crate::coordinator::wire::Wire;
use crate::coordinator::{BackendSpec, PredictorPolicy};
use crate::trace::workflow::Workflow;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Connect/read/write bound on every loadgen client connection: a wedged
/// server fails the run instead of hanging it.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(10);

/// How the generated load reaches the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    /// Call the coordinator `Client` directly — no sockets, no codec.
    /// The historical loadgen; measures the pool itself.
    InProcess,
    /// Thread-per-connection TCP server (`repro serve --threaded`).
    Threaded,
    /// Readiness-driven event-loop TCP server (unix only).
    EventLoop,
}

impl ServeMode {
    pub fn name(self) -> &'static str {
        match self {
            ServeMode::InProcess => "in-process",
            ServeMode::Threaded => "threaded",
            ServeMode::EventLoop => "eventloop",
        }
    }

    pub fn parse(s: &str) -> Option<ServeMode> {
        match s {
            "none" | "in-process" | "inprocess" => Some(ServeMode::InProcess),
            "threaded" => Some(ServeMode::Threaded),
            "eventloop" | "event-loop" => Some(ServeMode::EventLoop),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Coordinator worker shards.
    pub shards: usize,
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Total plan requests (split across clients, rounded up per client).
    pub requests: usize,
    /// Probability in [0, 1] that a client folds an `observe` (one
    /// finished execution, O(k) incremental model update) in front of a
    /// plan request — the online-retraining mix. 0 reproduces the pure
    /// plan workload.
    pub observe_frac: f64,
    /// Segments per task model.
    pub k: usize,
    /// Workflow whose task mix drives the request stream.
    pub workflow: String,
    /// Numeric backend for every shard.
    pub spec: BackendSpec,
    /// Predictor policy every task trains and serves under — measures a
    /// baseline-serving workload instead of the KS+ default.
    pub policy: PredictorPolicy,
    /// Chaos mode: crash-and-restore this many shards (round-robin, one
    /// at a time, spaced through the run) while the clients hammer the
    /// pool. Each kill amnesia-wipes one shard and restores it from its
    /// ring-standby replicas; the run still fails if a single
    /// observation is lost or an invalid plan is served. Requires
    /// `shards >= 2` (a lone shard has no standby).
    pub chaos_kills: usize,
    /// Serving stack the clients drive. TCP modes bind an ephemeral
    /// loopback port and run the same coordinator behind it.
    pub server: ServeMode,
    /// Wire the TCP clients negotiate (ignored in-process, where there
    /// is no wire).
    pub wire: Wire,
    /// Requests each TCP client keeps in flight per connection. 1 is
    /// strict request/response; higher depths ship a whole batch in one
    /// write and then collect the in-order responses.
    pub pipeline: usize,
    /// Seeded wire/dispatch/snapshot fault injection on the server side
    /// (`--chaos-faults`). Implies self-healing clients: every client
    /// becomes a [`ResilientClient`] with mutation retry + dedup on, and
    /// the run still asserts that no acknowledged observation is lost.
    pub chaos_faults: Option<FaultSpec>,
    /// Bound the event-loop front end's dispatch queue; excess load is
    /// shed with structured `overloaded` errors, which the resilient
    /// clients absorb with backoff. 0 = unbounded.
    pub max_queue_depth: usize,
    /// Dispatch worker threads for the event-loop front end (0 = that
    /// front end's default). A squeeze run sets 1 so the queue cap
    /// actually binds.
    pub dispatch_threads: usize,
    /// Drive the request stream from a scenario spec (`name=...,
    /// param=...`, see `scenario::ScenarioSpec`) instead of the plain
    /// workflow mix: every client replays its own seeded slice of the
    /// perturbed stream — plan, walk the OOM/retry loop through the
    /// `failure` op, observe the finished execution — so the same
    /// perturbations behind the offline wastage matrix exercise the
    /// serving hot path end to end. In-process serving only.
    pub scenario: Option<String>,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            shards: 1,
            clients: 8,
            requests: 5000,
            observe_frac: 0.0,
            k: 4,
            workflow: "eager".to_string(),
            spec: BackendSpec::Native,
            policy: PredictorPolicy::KsPlus,
            chaos_kills: 0,
            server: ServeMode::InProcess,
            wire: Wire::V1,
            pipeline: 1,
            chaos_faults: None,
            max_queue_depth: 0,
            dispatch_threads: 0,
            scenario: None,
        }
    }
}

/// One load-generation run's measurements.
#[derive(Debug, Clone)]
pub struct LoadGenReport {
    pub shards: usize,
    pub clients: usize,
    /// Policy the workload trained and served under.
    pub policy: &'static str,
    /// Serving stack the load went through.
    pub server: &'static str,
    /// Wire the TCP clients spoke ("v1" for in-process runs, where it
    /// only labels the row).
    pub wire: &'static str,
    /// Pipeline depth per connection.
    pub pipeline: usize,
    /// Plan requests actually issued (>= the configured total after
    /// per-client rounding).
    pub requests: u64,
    pub elapsed_s: f64,
    pub plans_per_s: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// `observe` ops issued alongside the plan stream.
    pub observes: u64,
    pub observes_per_s: f64,
    /// Plan requests each shard served, in shard order.
    pub per_shard_requests: Vec<u64>,
    /// Shard crash/restore cycles performed during the run.
    pub chaos_kills: u64,
    /// Requests the server shed with a structured `overloaded` error
    /// (dispatch queue at `max_queue_depth` or a connection at its
    /// in-flight cap). The resilient clients retried every one.
    pub shed: u64,
    /// High-water mark of the event-loop dispatch queue.
    pub queue_depth_max: u64,
    /// Client-side request retries (overloaded backoff plus transport
    /// replays), summed over all clients.
    pub retries: u64,
    /// Successful client reconnects after a severed connection.
    pub reconnects: u64,
    /// Circuit-breaker openings across all clients. Nonzero means some
    /// client judged the server down and started failing fast.
    pub circuit_opens: u64,
    /// Simulated OOM failures the scenario stream's retry loops hit
    /// against the served plans (0 for the plain plan/observe mix, which
    /// never replays executions against its plans).
    pub failures: u64,
}

impl LoadGenReport {
    /// The key this run files under in the bench document's "serving"
    /// section: one slot per (front end, wire) combination.
    pub fn serving_key(&self) -> String {
        format!("{}-{}", self.server, self.wire)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", self.shards.into()),
            ("clients", self.clients.into()),
            ("policy", self.policy.into()),
            ("server", self.server.into()),
            ("wire", self.wire.into()),
            ("pipeline", self.pipeline.into()),
            ("requests", (self.requests as usize).into()),
            ("elapsed_s", self.elapsed_s.into()),
            ("plans_per_s", self.plans_per_s.into()),
            ("p50_us", self.p50_us.into()),
            ("p99_us", self.p99_us.into()),
            ("batches", (self.batches as usize).into()),
            ("mean_batch_size", self.mean_batch_size.into()),
            ("observes", (self.observes as usize).into()),
            ("observes_per_s", self.observes_per_s.into()),
            (
                "per_shard_requests",
                Json::Arr(
                    self.per_shard_requests.iter().map(|&r| (r as usize).into()).collect(),
                ),
            ),
            ("chaos_kills", (self.chaos_kills as usize).into()),
            ("shed", (self.shed as usize).into()),
            ("queue_depth_max", (self.queue_depth_max as usize).into()),
            ("retries", (self.retries as usize).into()),
            ("reconnects", (self.reconnects as usize).into()),
            ("circuit_opens", (self.circuit_opens as usize).into()),
            ("failures", (self.failures as usize).into()),
        ])
    }
}

/// Write the sweep's reports into the machine-readable
/// `BENCH_hotpath.json` (schema shared with `cargo bench --bench
/// hotpath`). In-process runs land in the "plans" array (the historical
/// section); runs that went through a TCP front end land in the
/// "serving" object, one slot per "<server>-<wire>" key, so the
/// threaded-v1 and eventloop-v2 numbers sit side by side.
///
/// Merges into an existing schema-compatible file instead of clobbering
/// it: the hotpath bench owns the segmentation/observe sections, a prior
/// in-process sweep owns "plans", and each serving run only replaces its
/// own key.
pub fn write_bench_json(path: &std::path::Path, reports: &[LoadGenReport]) -> Result<()> {
    const SCHEMA: &str = "ksplus-bench-hotpath/v1";
    let mut doc = match std::fs::read_to_string(path).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(existing) if existing.get("schema").and_then(Json::as_str) == Some(SCHEMA) => {
            existing
        }
        _ => Json::obj(vec![("schema", SCHEMA.into())]),
    };
    let local: Vec<&LoadGenReport> =
        reports.iter().filter(|r| r.server == ServeMode::InProcess.name()).collect();
    let served: Vec<&LoadGenReport> =
        reports.iter().filter(|r| r.server != ServeMode::InProcess.name()).collect();
    if let Json::Obj(map) = &mut doc {
        map.insert("source".to_string(), "repro-loadgen".into());
        if !local.is_empty() {
            map.insert(
                "plans".to_string(),
                Json::Arr(local.iter().map(|r| r.to_json()).collect()),
            );
        }
        if !served.is_empty() {
            let serving = map
                .entry("serving".to_string())
                .or_insert_with(|| Json::obj(vec![]));
            if !matches!(serving, Json::Obj(_)) {
                *serving = Json::obj(vec![]);
            }
            if let Json::Obj(slots) = serving {
                for r in &served {
                    slots.insert(r.serving_key(), r.to_json());
                }
            }
        }
    }
    // A nested output path must not lose the sweep at the very end:
    // create the parent directories before writing.
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// A running TCP front end of either flavor, stopped when the run ends.
enum ServeHandle {
    Threaded(Server),
    #[cfg(unix)]
    EventLoop(EventLoopServer),
}

impl ServeHandle {
    fn addr(&self) -> std::net::SocketAddr {
        match self {
            ServeHandle::Threaded(s) => s.addr(),
            #[cfg(unix)]
            ServeHandle::EventLoop(s) => s.addr(),
        }
    }

    fn stop(&mut self) {
        match self {
            ServeHandle::Threaded(s) => s.stop(),
            #[cfg(unix)]
            ServeHandle::EventLoop(s) => s.stop(),
        }
    }

    fn counters(&self) -> Arc<ConnCounters> {
        match self {
            ServeHandle::Threaded(s) => s.counters(),
            #[cfg(unix)]
            ServeHandle::EventLoop(s) => s.counters(),
        }
    }
}

#[cfg(unix)]
fn start_eventloop(client: Client, cfg: ServerConfig) -> Result<ServeHandle> {
    Ok(ServeHandle::EventLoop(
        EventLoopServer::start_with_config("127.0.0.1:0", client, cfg)
            .context("start event-loop server")?,
    ))
}

#[cfg(not(unix))]
fn start_eventloop(_client: Client, _cfg: ServerConfig) -> Result<ServeHandle> {
    anyhow::bail!("the event-loop server needs epoll/kqueue; use --server threaded here")
}

/// Scenario-driven load (`--scenario`): train the pool from the stream's
/// own training split, then have every client replay a seeded slice of
/// the perturbed stream against the live coordinator — plan, walk the
/// OOM/retry loop through `report_failure`, and (at `observe_frac`) feed
/// the finished execution back as an `observe`. The loadgen bridge of
/// the scenario engine: retry storms and drift become live `failure` and
/// retraining traffic instead of offline matrix rows.
fn run_scenario_load(cfg: &LoadGenConfig, spec_str: &str) -> Result<LoadGenReport> {
    use crate::scenario::stream::ScenarioStream;
    use crate::scenario::ScenarioSpec;

    anyhow::ensure!(
        cfg.server == ServeMode::InProcess,
        "--scenario drives the in-process client; drop --server"
    );
    anyhow::ensure!(
        cfg.chaos_kills == 0 && cfg.chaos_faults.is_none() && cfg.max_queue_depth == 0,
        "--scenario does not compose with the chaos/overload knobs"
    );
    let mut spec = ScenarioSpec::parse(spec_str)
        .with_context(|| format!("parsing --scenario '{spec_str}'"))?;
    let per_client = cfg.requests.div_ceil(cfg.clients);
    // Each client replays its own slice; sizing the spec to the slice
    // keeps time-positional perturbations (drift's `at`) at the same
    // fraction of every client's stream.
    spec.n = per_client;
    let coord = Coordinator::start(
        CoordinatorConfig {
            k: cfg.k,
            shards: cfg.shards,
            batch_delay: Duration::ZERO,
            default_policy: cfg.policy,
            ..Default::default()
        },
        cfg.spec.clone(),
    )
    .context("start coordinator")?;
    let client = coord.client();
    // One training pass from the base spec's split; the per-client
    // streams only supply test-side traffic.
    let probe = ScenarioStream::new(&spec)?;
    for tt in probe.training() {
        client.train(&tt.task, tt.executions.clone());
    }
    let observe_frac = cfg.observe_frac;
    let t0 = Instant::now();
    let mut handles: Vec<std::thread::JoinHandle<Result<(u64, u64, u64)>>> =
        Vec::with_capacity(cfg.clients);
    for c in 0..cfg.clients {
        let cl = coord.client();
        let spec = ScenarioSpec { seed: spec.seed.wrapping_add(c as u64), ..spec.clone() };
        handles.push(std::thread::spawn(move || {
            let mut stream = ScenarioStream::new(&spec)?;
            let mut e = crate::trace::Execution::new("", 0.0, 0.0, Vec::new());
            let mut rng = Rng::new(0xBEEF ^ c as u64);
            let mut invalid = 0u64;
            let mut observes = 0u64;
            let mut failures = 0u64;
            for _ in 0..per_client {
                stream.fill_next(&mut e);
                let mut plan = cl.plan(&e.task, e.input_mb);
                if !plan.is_valid() {
                    invalid += 1;
                    continue;
                }
                // The live analogue of the simulator's retry loop: every
                // OOM goes back to the coordinator's failure op for a
                // resized plan, up to the same retry budget.
                let mut attempt = 0usize;
                while let Some((t_fail, _used)) = plan.first_oom(&e) {
                    failures += 1;
                    attempt += 1;
                    if attempt >= crate::sim::MAX_RETRIES {
                        break;
                    }
                    plan = cl.report_failure_for(Some(e.task.as_str()), &plan, t_fail).plan;
                    if !plan.is_valid() {
                        invalid += 1;
                        break;
                    }
                }
                if observe_frac > 0.0 && rng.f64() < observe_frac {
                    cl.observe(&e.task, e.clone());
                    observes += 1;
                }
            }
            Ok((invalid, observes, failures))
        }));
    }
    let mut invalid = 0u64;
    let mut observes = 0u64;
    let mut failures = 0u64;
    for h in handles {
        let (i, o, f) =
            h.join().map_err(|_| anyhow::anyhow!("scenario loadgen client panicked"))??;
        invalid += i;
        observes += o;
        failures += f;
    }
    anyhow::ensure!(invalid == 0, "coordinator returned {invalid} invalid plans");
    let served = (per_client * cfg.clients) as u64;
    let elapsed = t0.elapsed().max(Duration::from_nanos(1));
    let per_shard = client.shard_stats();
    let stats = ServiceStats::merged(&per_shard);
    anyhow::ensure!(
        stats.observations == observes,
        "coordinator lost observations: {} issued, {} recorded",
        observes,
        stats.observations
    );
    Ok(LoadGenReport {
        shards: cfg.shards,
        clients: cfg.clients,
        policy: cfg.policy.name(),
        server: cfg.server.name(),
        wire: cfg.wire.name(),
        pipeline: cfg.pipeline,
        requests: served,
        elapsed_s: elapsed.as_secs_f64(),
        plans_per_s: served as f64 / elapsed.as_secs_f64(),
        p50_us: stats.latency_percentile_us(50.0),
        p99_us: stats.latency_percentile_us(99.0),
        batches: stats.batches,
        mean_batch_size: stats.mean_batch_size(),
        observes,
        observes_per_s: observes as f64 / elapsed.as_secs_f64(),
        per_shard_requests: per_shard.iter().map(|s| s.requests).collect(),
        chaos_kills: 0,
        shed: 0,
        queue_depth_max: 0,
        retries: 0,
        reconnects: 0,
        circuit_opens: 0,
        failures,
    })
}

/// Train every task of the workflow, then hammer the coordinator from
/// `clients` closed-loop threads and collect the merged service stats.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    anyhow::ensure!(cfg.clients >= 1, "loadgen needs at least one client");
    anyhow::ensure!(cfg.requests >= 1, "loadgen needs at least one request");
    anyhow::ensure!(cfg.pipeline >= 1, "pipeline depth must be at least 1");
    anyhow::ensure!(
        (0.0..=1.0).contains(&cfg.observe_frac),
        "observe_frac must be in [0, 1]"
    );
    if let Some(spec_str) = &cfg.scenario {
        return run_scenario_load(cfg, spec_str);
    }
    anyhow::ensure!(
        cfg.chaos_kills == 0 || cfg.shards >= 2,
        "chaos kills need at least 2 shards (a lone shard has no standby to restore from)"
    );
    anyhow::ensure!(
        cfg.server != ServeMode::InProcess || (cfg.wire == Wire::V1 && cfg.pipeline == 1),
        "--wire and --pipeline need a TCP front end (--server threaded|eventloop)"
    );
    // Faults and overload squeezes imply self-healing clients: every
    // client becomes a ResilientClient with mutation retry + dedup, so
    // the run survives torn frames and `overloaded` sheds — and the
    // no-lost-acks invariant at the end still has to hold exactly.
    let resilient = cfg.chaos_faults.is_some() || cfg.max_queue_depth > 0;
    anyhow::ensure!(
        !resilient || cfg.server != ServeMode::InProcess,
        "--chaos-faults and --max-queue-depth exercise a TCP front end \
         (--server threaded|eventloop)"
    );
    anyhow::ensure!(
        !resilient || cfg.pipeline == 1,
        "self-healing clients are strict request/response; --pipeline must be 1 \
         under --chaos-faults/--max-queue-depth"
    );
    anyhow::ensure!(
        cfg.max_queue_depth == 0 || cfg.server == ServeMode::EventLoop,
        "--max-queue-depth bounds the event-loop dispatch queue; use --server eventloop"
    );
    let wf = Workflow::by_name(&cfg.workflow)
        .with_context(|| format!("unknown workflow '{}'", cfg.workflow))?;
    let trace = wf.generate(42, 150);
    let coord = Coordinator::start(
        CoordinatorConfig {
            k: cfg.k,
            shards: cfg.shards,
            // No straggler linger: closed-loop clients would otherwise
            // serialize on the poll whenever a shard has one pending
            // request, and the sweep would measure the linger knob
            // instead of pool capacity. The drain loop still batches.
            batch_delay: Duration::ZERO,
            default_policy: cfg.policy,
            ..Default::default()
        },
        cfg.spec.clone(),
    )
    .context("start coordinator")?;
    let client = coord.client();
    // With an observe mix, train on a held-out prefix: the tail of each
    // task's trace is kept back so `observe` streams genuinely unseen
    // executions (true online retraining, not a duplicate replay). At
    // observe_frac == 0 the full history is trained, keeping the pure
    // plan workload identical to earlier sweeps.
    let holdout = if cfg.observe_frac > 0.0 { 8 } else { 0 };
    let mut obs_mix: Vec<(String, crate::trace::Execution)> = Vec::new();
    for t in &trace.tasks {
        let split = t.executions.len().saturating_sub(holdout).max(1).min(t.executions.len());
        client.train(&t.task, t.executions[..split].to_vec());
        for e in &t.executions[split..] {
            obs_mix.push((t.task.clone(), e.clone()));
        }
    }
    // The request mix: every task type with a spread of real input sizes.
    let mix: Vec<(String, f64)> = trace
        .tasks
        .iter()
        .flat_map(|t| {
            t.executions.iter().take(8).map(move |e| (t.task.clone(), e.input_mb))
        })
        .collect();
    anyhow::ensure!(!mix.is_empty(), "workflow produced no tasks");
    anyhow::ensure!(
        cfg.observe_frac == 0.0 || !obs_mix.is_empty(),
        "observe mix requested but every task's trace is too short to hold out executions"
    );
    // Shared read-only across clients: the held-out executions carry
    // full sample vectors, so cloning the list per thread would be the
    // only heavyweight allocation in the setup path.
    let obs_mix = Arc::new(obs_mix);

    // TCP modes put the chosen front end (ephemeral loopback port) in
    // front of the same coordinator; training above already went through
    // the in-process client either way. The front end carries the
    // robustness knobs: the fault plane and the dispatch-queue bound
    // that turns excess load into structured `overloaded` sheds.
    let server_cfg = ServerConfig {
        dispatch_threads: cfg.dispatch_threads,
        max_queue_depth: cfg.max_queue_depth,
        faults: cfg.chaos_faults.as_ref().map(FaultSpec::plane),
        ..Default::default()
    };
    let mut front = match cfg.server {
        ServeMode::InProcess => None,
        ServeMode::Threaded => Some(ServeHandle::Threaded(
            Server::start_with_config("127.0.0.1:0", coord.client(), server_cfg)
                .context("start threaded server")?,
        )),
        ServeMode::EventLoop => Some(start_eventloop(coord.client(), server_cfg)?),
    };
    let addr = front.as_ref().map(ServeHandle::addr);

    let per_client = cfg.requests.div_ceil(cfg.clients);
    let observe_frac = cfg.observe_frac;
    let t0 = Instant::now();
    // Chaos thread: crash/restore shards round-robin while the clients
    // run. Kills are spaced so the clients interleave real traffic with
    // each amnesia-wipe-and-restore cycle. Chaos always goes through the
    // in-process client — it is an operator action, not load — so it
    // composes with any serving mode.
    let chaos_handle = (cfg.chaos_kills > 0).then(|| {
        let cl = coord.client();
        let target = cfg.chaos_kills as u64;
        std::thread::spawn(move || -> Result<u64> {
            let ids = cl.shard_ids();
            let mut kills = 0u64;
            let mut i = 0usize;
            while kills < target {
                std::thread::sleep(Duration::from_millis(10));
                let id = ids[i % ids.len()];
                i += 1;
                cl.crash_restart_shard(id)
                    .with_context(|| format!("chaos crash/restore of shard {id}"))?;
                kills += 1;
            }
            Ok(kills)
        })
    });
    let mut handles: Vec<std::thread::JoinHandle<Result<(u64, u64, ClientCounters)>>> =
        Vec::with_capacity(cfg.clients);
    for c in 0..cfg.clients {
        let mix = mix.clone();
        let obs_mix = Arc::clone(&obs_mix);
        match addr {
            // Fault/overload runs: every client is a self-healing
            // ResilientClient. Mutation retry is on (dedup stamps make
            // the replays exactly-once server-side), backoffs are kept
            // short — the run measures healing, not idling.
            Some(addr) if resilient => {
                let wire = cfg.wire;
                handles.push(std::thread::spawn(move || {
                    let mut rc = ResilientClient::new(
                        addr.to_string(),
                        RetryPolicy {
                            max_attempts: 16,
                            base_backoff: Duration::from_millis(1),
                            max_backoff: Duration::from_millis(50),
                            retry_mutations: true,
                            breaker_threshold: 32,
                            breaker_cooldown: Duration::from_millis(50),
                            // Distinct per client: the nonce derives from
                            // the seed, and sharing one would share a
                            // dedup session.
                            seed: 0x5EED ^ c as u64,
                        },
                    );
                    rc.set_timeout(Some(CLIENT_TIMEOUT));
                    rc.set_max_wire_version(wire.version());
                    let mut rng = Rng::new(0xC0FFEE ^ c as u64);
                    let mut invalid = 0u64;
                    let mut observes = 0u64;
                    for _ in 0..per_client {
                        if observe_frac > 0.0 && rng.f64() < observe_frac {
                            let (task, exec) = &obs_mix[rng.below(obs_mix.len())];
                            rc.observe(task, exec).context("resilient observe")?;
                            observes += 1;
                        }
                        let (task, input) = &mix[rng.below(mix.len())];
                        let out = rc.plan(task, *input).context("resilient plan")?;
                        if !out.plan.is_valid() {
                            invalid += 1;
                        }
                    }
                    Ok((invalid, observes, rc.counters()))
                }));
            }
            None => {
                let cl = coord.client();
                handles.push(std::thread::spawn(move || {
                    let mut rng = Rng::new(0xC0FFEE ^ c as u64);
                    let mut invalid = 0u64;
                    let mut observes = 0u64;
                    for _ in 0..per_client {
                        if observe_frac > 0.0 && rng.f64() < observe_frac {
                            let (task, exec) = &obs_mix[rng.below(obs_mix.len())];
                            cl.observe(task, exec.clone());
                            observes += 1;
                        }
                        let (task, input) = &mix[rng.below(mix.len())];
                        if !cl.plan(task, *input).is_valid() {
                            invalid += 1;
                        }
                    }
                    Ok((invalid, observes, ClientCounters::default()))
                }));
            }
            Some(addr) => {
                let wire = cfg.wire;
                let depth = cfg.pipeline;
                handles.push(std::thread::spawn(move || {
                    let mut rc = RemoteClient::connect_with_timeout(addr, CLIENT_TIMEOUT)
                        .context("loadgen client connect")?;
                    let info = rc.negotiate(wire.version()).context("negotiate wire")?;
                    anyhow::ensure!(
                        rc.wire() == wire,
                        "asked for wire {} but the server granted v{}",
                        wire.name(),
                        info.version
                    );
                    let mut rng = Rng::new(0xC0FFEE ^ c as u64);
                    let mut invalid = 0u64;
                    let mut observes = 0u64;
                    let mut remaining = per_client;
                    let mut reqs: Vec<Request> = Vec::with_capacity(depth * 2);
                    while remaining > 0 {
                        let batch = depth.min(remaining);
                        reqs.clear();
                        for _ in 0..batch {
                            if observe_frac > 0.0 && rng.f64() < observe_frac {
                                let (task, exec) = &obs_mix[rng.below(obs_mix.len())];
                                reqs.push(Request::Observe {
                                    task: task.clone(),
                                    execution: exec.clone(),
                                    dedup: None,
                                });
                            }
                            let (task, input) = &mix[rng.below(mix.len())];
                            reqs.push(Request::Plan { task: task.clone(), input_mb: *input });
                        }
                        for verdict in rc.pipeline(&reqs).context("pipelined batch")? {
                            match verdict {
                                Ok(Response::Planned(o)) => {
                                    if !o.plan.is_valid() {
                                        invalid += 1;
                                    }
                                }
                                Ok(Response::Observed(_)) => observes += 1,
                                Ok(other) => {
                                    anyhow::bail!("unexpected load response: {other:?}")
                                }
                                Err(e) => anyhow::bail!(
                                    "server rejected a load request: {} ({})",
                                    e.message,
                                    e.code.as_str()
                                ),
                            }
                        }
                        remaining -= batch;
                    }
                    Ok((invalid, observes, ClientCounters::default()))
                }));
            }
        }
    }
    let mut invalid = 0u64;
    let mut observes = 0u64;
    let mut healing = ClientCounters::default();
    for h in handles {
        let (i, o, cc) =
            h.join().map_err(|_| anyhow::anyhow!("loadgen client thread panicked"))??;
        invalid += i;
        observes += o;
        healing.retries += cc.retries;
        healing.reconnects += cc.reconnects;
        healing.circuit_opens += cc.circuit_opens;
    }
    // A trained (or fallback) plan is always well-formed; an invalid one
    // is a service bug, not a load characteristic — fail loudly rather
    // than skewing throughput.
    anyhow::ensure!(invalid == 0, "coordinator returned {invalid} invalid plans");
    let chaos_kills = match chaos_handle {
        Some(h) => h.join().map_err(|_| anyhow::anyhow!("chaos thread panicked"))??,
        None => 0,
    };
    let served = (per_client * cfg.clients) as u64;
    let elapsed = t0.elapsed().max(Duration::from_nanos(1));
    let (shed, queue_depth_max) = match front.as_ref().map(ServeHandle::counters) {
        Some(cc) => (
            cc.shed.load(std::sync::atomic::Ordering::Relaxed),
            cc.queue_depth_max.load(std::sync::atomic::Ordering::Relaxed),
        ),
        None => (0, 0),
    };
    if let Some(f) = front.as_mut() {
        f.stop();
    }

    let per_shard = client.shard_stats();
    let stats = ServiceStats::merged(&per_shard);
    // The strongest chaos assertion available to a black-box load run:
    // every acked observation is still counted after every kill, because
    // a crash wipes a shard's models, not its ledgers, and the training
    // state itself is re-folded from the standby replicas.
    anyhow::ensure!(
        stats.observations == observes,
        "coordinator lost observations: {} issued, {} recorded",
        observes,
        stats.observations
    );
    Ok(LoadGenReport {
        shards: cfg.shards,
        clients: cfg.clients,
        policy: cfg.policy.name(),
        server: cfg.server.name(),
        wire: cfg.wire.name(),
        pipeline: cfg.pipeline,
        requests: served,
        elapsed_s: elapsed.as_secs_f64(),
        plans_per_s: served as f64 / elapsed.as_secs_f64(),
        p50_us: stats.latency_percentile_us(50.0),
        p99_us: stats.latency_percentile_us(99.0),
        batches: stats.batches,
        mean_batch_size: stats.mean_batch_size(),
        observes,
        observes_per_s: observes as f64 / elapsed.as_secs_f64(),
        per_shard_requests: per_shard.iter().map(|s| s.requests).collect(),
        chaos_kills,
        shed,
        queue_depth_max,
        retries: healing.retries,
        reconnects: healing.reconnects,
        circuit_opens: healing.circuit_opens,
        failures: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loadgen_smoke_single_shard() {
        let r = run(&LoadGenConfig {
            clients: 4,
            requests: 64,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.shards, 1);
        assert_eq!(r.requests, 64);
        assert_eq!(r.per_shard_requests, vec![64]);
        assert!(r.plans_per_s > 0.0);
        assert!(r.p99_us >= r.p50_us);
        assert_eq!(r.server, "in-process");
        assert_eq!(r.wire, "v1");
    }

    #[test]
    fn loadgen_sharded_spreads_requests() {
        let r = run(&LoadGenConfig {
            shards: 4,
            clients: 4,
            requests: 200,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.per_shard_requests.len(), 4);
        // Every plan request is accounted for by exactly one shard.
        assert_eq!(r.per_shard_requests.iter().sum::<u64>(), r.requests);
        // The eager workflow's task names spread over multiple shards.
        assert!(
            r.per_shard_requests.iter().filter(|&&n| n > 0).count() > 1,
            "{:?}",
            r.per_shard_requests
        );
        let j = r.to_json();
        assert_eq!(j.get("shards").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn loadgen_mixes_observes_into_the_stream() {
        let r = run(&LoadGenConfig {
            clients: 4,
            requests: 128,
            observe_frac: 0.5,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 128);
        assert!(r.observes > 0, "no observes issued at frac 0.5");
        assert!(r.observes_per_s > 0.0);
        let j = r.to_json();
        assert_eq!(j.get("observes").and_then(Json::as_usize), Some(r.observes as usize));
    }

    #[test]
    fn loadgen_rejects_degenerate_configs() {
        assert!(run(&LoadGenConfig { clients: 0, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { requests: 0, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { workflow: "nope".into(), ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { shards: 0, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { observe_frac: 1.5, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { observe_frac: -0.1, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { pipeline: 0, ..Default::default() }).is_err());
        // Chaos on a single shard: no standby, refused up front.
        assert!(run(&LoadGenConfig { shards: 1, chaos_kills: 1, ..Default::default() }).is_err());
        // Wire/pipeline knobs without a TCP front end to apply them to.
        assert!(run(&LoadGenConfig { wire: Wire::V2, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { pipeline: 4, ..Default::default() }).is_err());
        // Robustness knobs without a front end (or queue) to apply to.
        let faults = FaultSpec::parse("seed=1,stall=0.1:1").unwrap();
        assert!(run(&LoadGenConfig {
            chaos_faults: Some(faults.clone()),
            ..Default::default()
        })
        .is_err());
        assert!(run(&LoadGenConfig {
            server: ServeMode::Threaded,
            max_queue_depth: 4,
            ..Default::default()
        })
        .is_err());
        assert!(run(&LoadGenConfig {
            server: ServeMode::EventLoop,
            chaos_faults: Some(faults),
            pipeline: 4,
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn loadgen_survives_chaos_kills_without_losing_observes() {
        // Shards die and come back from their replicas mid-run; the run's
        // own invariants (zero invalid plans, zero lost observations) do
        // the asserting.
        let r = run(&LoadGenConfig {
            shards: 3,
            clients: 4,
            requests: 300,
            observe_frac: 0.5,
            chaos_kills: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 300);
        assert_eq!(r.chaos_kills, 3);
        assert!(r.observes > 0, "no observes issued at frac 0.5");
        assert_eq!(
            r.to_json().get("chaos_kills").and_then(Json::as_usize),
            Some(3)
        );
    }

    #[test]
    fn loadgen_over_threaded_server_on_wire_v1() {
        let r = run(&LoadGenConfig {
            clients: 2,
            requests: 32,
            observe_frac: 0.25,
            server: ServeMode::Threaded,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 32);
        assert_eq!(r.server, "threaded");
        assert_eq!(r.wire, "v1");
        assert_eq!(r.serving_key(), "threaded-v1");
        assert!(r.observes > 0, "no observes issued at frac 0.25");
    }

    #[cfg(unix)]
    #[test]
    fn loadgen_over_eventloop_server_on_wire_v2_pipelined() {
        let r = run(&LoadGenConfig {
            clients: 2,
            requests: 48,
            observe_frac: 0.25,
            server: ServeMode::EventLoop,
            wire: Wire::V2,
            pipeline: 4,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 48);
        assert_eq!(r.server, "eventloop");
        assert_eq!(r.wire, "v2");
        assert_eq!(r.pipeline, 4);
        assert_eq!(r.serving_key(), "eventloop-v2");
        assert!(r.observes > 0, "no observes issued at frac 0.25");
        let j = r.to_json();
        assert_eq!(j.get("server").and_then(Json::as_str), Some("eventloop"));
        assert_eq!(j.get("wire").and_then(Json::as_str), Some("v2"));
        assert_eq!(j.get("pipeline").and_then(Json::as_usize), Some(4));
    }

    #[cfg(unix)]
    #[test]
    fn loadgen_queue_squeeze_sheds_but_loses_nothing() {
        // One dispatch worker, a depth-1 queue, and a dispatch stall make
        // admission control actually bind; the resilient clients absorb
        // every `overloaded` with backoff, so the run still serves the
        // full request count and the no-lost-acks invariant holds.
        let r = run(&LoadGenConfig {
            clients: 4,
            requests: 80,
            observe_frac: 0.25,
            server: ServeMode::EventLoop,
            max_queue_depth: 1,
            dispatch_threads: 1,
            chaos_faults: Some(FaultSpec::parse("seed=9,stall=0.9:3").unwrap()),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 80);
        assert!(r.shed > 0, "queue squeeze never shed: {r:?}");
        // Every shed came back as an `overloaded` the client retried.
        assert!(r.retries >= r.shed, "{r:?}");
        assert_eq!(r.queue_depth_max, 1, "{r:?}");
        assert!(r.observes > 0);
        let j = r.to_json();
        assert!(j.get("shed").and_then(Json::as_usize).unwrap() > 0);
        assert_eq!(
            j.get("queue_depth_max").and_then(Json::as_usize),
            Some(1)
        );
    }

    #[cfg(unix)]
    #[test]
    fn loadgen_chaos_faults_heal_without_losing_acks() {
        // Torn frames sever connections mid-response; the self-healing
        // clients reconnect and replay with dedup stamps. The run's own
        // invariant — acked observations exactly equal recorded ones —
        // is the exactly-once proof.
        let r = run(&LoadGenConfig {
            clients: 3,
            requests: 120,
            observe_frac: 0.4,
            server: ServeMode::EventLoop,
            wire: Wire::V2,
            chaos_faults: Some(
                FaultSpec::parse("seed=7,short-io=0.2,corrupt=0.08,stall=0.1:1").unwrap(),
            ),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 120);
        assert!(r.observes > 0);
        assert!(
            r.reconnects > 0,
            "corrupt frames never severed a connection: {r:?}"
        );
        assert!(r.retries >= r.reconnects, "{r:?}");
        // No queue bound: nothing shed, clients healed around faults only.
        assert_eq!(r.shed, 0, "{r:?}");
        let j = r.to_json();
        assert!(j.get("reconnects").and_then(Json::as_usize).unwrap() > 0);
        assert!(j.get("retries").and_then(Json::as_usize).unwrap() > 0);
    }

    #[test]
    fn scenario_loadgen_drives_retry_storms_through_the_live_service() {
        // A hot storm (every execution spikes 4x) must surface as real
        // `failure` traffic against the served plans; the observe mix
        // keeps the no-lost-acks invariant in play at the same time.
        let r = run(&LoadGenConfig {
            clients: 2,
            requests: 60,
            observe_frac: 0.5,
            scenario: Some(
                "name=retry-storm,prob=0.8,factor=4.0,train-per-task=8,seed=3".into(),
            ),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 60);
        assert!(r.failures > 0, "a hot retry storm never OOMed: {r:?}");
        assert!(r.observes > 0);
        assert_eq!(r.server, "in-process");
        let j = r.to_json();
        assert_eq!(
            j.get("failures").and_then(Json::as_usize),
            Some(r.failures as usize)
        );
    }

    #[test]
    fn scenario_loadgen_baseline_mostly_fits() {
        // The unperturbed stream against freshly trained models: far
        // fewer failures per request than the storm, and a full request
        // count either way.
        let r = run(&LoadGenConfig {
            clients: 2,
            requests: 60,
            scenario: Some("name=baseline,train-per-task=8,seed=3".into()),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 60);
        assert!(
            r.failures < r.requests,
            "baseline failed more often than it planned: {r:?}"
        );
        assert_eq!(r.observes, 0, "no observe mix requested");
    }

    #[test]
    fn scenario_loadgen_rejects_bad_configs() {
        // Unparseable spec.
        assert!(run(&LoadGenConfig {
            scenario: Some("name=unheard-of".into()),
            ..Default::default()
        })
        .is_err());
        // TCP front ends and chaos knobs do not compose with --scenario.
        assert!(run(&LoadGenConfig {
            scenario: Some("name=baseline".into()),
            server: ServeMode::Threaded,
            ..Default::default()
        })
        .is_err());
        assert!(run(&LoadGenConfig {
            scenario: Some("name=baseline".into()),
            shards: 2,
            chaos_kills: 1,
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn serve_mode_parses_cli_spellings() {
        assert_eq!(ServeMode::parse("none"), Some(ServeMode::InProcess));
        assert_eq!(ServeMode::parse("in-process"), Some(ServeMode::InProcess));
        assert_eq!(ServeMode::parse("threaded"), Some(ServeMode::Threaded));
        assert_eq!(ServeMode::parse("eventloop"), Some(ServeMode::EventLoop));
        assert_eq!(ServeMode::parse("event-loop"), Some(ServeMode::EventLoop));
        assert_eq!(ServeMode::parse("tokio"), None);
    }

    #[test]
    fn bench_json_writes_schema() {
        let r = run(&LoadGenConfig { clients: 2, requests: 32, ..Default::default() }).unwrap();
        let path = std::env::temp_dir().join(format!(
            "ksplus_bench_{}.json",
            std::process::id()
        ));
        write_bench_json(&path, &[r]).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(
            back.get("schema").and_then(Json::as_str),
            Some("ksplus-bench-hotpath/v1")
        );
        assert_eq!(back.get("plans").and_then(Json::as_arr).map(|a| a.len()), Some(1));
    }

    #[test]
    fn bench_json_creates_parent_directories() {
        // A nested --bench-json path used to fail the whole run at the
        // very end (after the sweep) when the directory did not exist.
        let r = run(&LoadGenConfig { clients: 2, requests: 16, ..Default::default() }).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "ksplus_bench_nested_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("a").join("b").join("bench.json");
        write_bench_json(&path, &[r]).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(
            back.get("schema").and_then(Json::as_str),
            Some("ksplus-bench-hotpath/v1")
        );
    }

    #[test]
    fn bench_json_serving_section_merges_without_clobbering_plans() {
        let local = run(&LoadGenConfig { clients: 2, requests: 16, ..Default::default() }).unwrap();
        let served = run(&LoadGenConfig {
            clients: 2,
            requests: 16,
            server: ServeMode::Threaded,
            ..Default::default()
        })
        .unwrap();
        let path = std::env::temp_dir().join(format!(
            "ksplus_bench_serving_{}.json",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        // First write the in-process sweep, then — as CI does — merge a
        // serving run into the same document.
        write_bench_json(&path, &[local]).unwrap();
        write_bench_json(&path, &[served]).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.get("plans").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        let slot = back.get("serving").and_then(|s| s.get("threaded-v1")).unwrap();
        assert_eq!(slot.get("server").and_then(Json::as_str), Some("threaded"));
        assert_eq!(slot.get("requests").and_then(Json::as_usize), Some(16));
    }

    #[test]
    fn loadgen_serves_non_default_policies() {
        for policy in [PredictorPolicy::WittLr, PredictorPolicy::DefaultLimits] {
            let r = run(&LoadGenConfig {
                clients: 2,
                requests: 32,
                observe_frac: 0.25,
                policy,
                ..Default::default()
            })
            .unwrap();
            assert_eq!(r.requests, 32, "{policy:?}");
            assert_eq!(r.policy, policy.name());
            let j = r.to_json();
            assert_eq!(j.get("policy").and_then(Json::as_str), Some(policy.name()));
        }
    }
}
