//! End-to-end record/replay conformance.
//!
//! Records live sessions through the dispatch tap, then replays them
//! against fresh coordinators — across front ends and wires, and across
//! a snapshot/restore plus reshard in the middle of a trace — asserting
//! the canonical transcripts stay bit-identical throughout.

use std::time::Duration;

use ksplus::coordinator::remote::RemoteClient;
use ksplus::coordinator::server::{Server, ServerConfig};
use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
use ksplus::coordinator::session::{self, CaseConfig, Expect, SessionTrace, Step};
use ksplus::coordinator::wire::Wire;
use ksplus::coordinator::BackendSpec;
use ksplus::util::json::Json;

const TIMEOUT: Duration = Duration::from_secs(10);

fn server_cfg(cfg: &CaseConfig) -> ServerConfig {
    ServerConfig {
        max_conns: cfg.max_conns,
        max_frame_bytes: cfg.max_frame_bytes,
        ..Default::default()
    }
}

#[test]
fn recorded_policies_trace_replays_identically_on_every_combo() {
    let trace = session::record_case("policies").expect("record policies");
    // Round-trip through the file format so the replays exercise what a
    // committed golden would actually contain.
    let trace = SessionTrace::from_json(&trace.to_json()).expect("trace roundtrip");
    let mut baseline: Option<(&str, Vec<String>)> = None;
    for (combo, threaded, wire) in session::all_combos() {
        let transcript = session::replay_trace(&trace, threaded, wire, None)
            .unwrap_or_else(|e| panic!("combo {combo}: {e:#}"));
        match &baseline {
            None => baseline = Some((combo, transcript)),
            Some((base_combo, base)) => assert_eq!(
                base, &transcript,
                "{combo} diverged from the {base_combo} baseline"
            ),
        }
    }
}

#[test]
fn replay_detects_a_tampered_expectation() {
    let mut trace = session::record_case("ops").expect("record ops");
    // Corrupt one pinned expect: claim the training step folded one
    // more execution than it did.
    let tampered = trace.steps.iter_mut().find_map(|s| match s {
        Step::Request { request, expect: Expect::Json(doc) }
            if request.get("op").and_then(Json::as_str) == Some("train") =>
        {
            if let Json::Obj(m) = doc {
                m.insert("executions".to_string(), Json::Num(999.0));
                Some(())
            } else {
                None
            }
        }
        _ => None,
    });
    assert!(tampered.is_some(), "ops trace should pin a train ack");
    let err = session::replay_trace(&trace, true, Wire::V1, None)
        .expect_err("a tampered expect must fail the replay");
    assert!(format!("{err:#}").contains("diverged"), "unexpected error: {err:#}");
}

#[test]
fn snapshot_restore_and_reshard_mid_trace_keep_the_tail_bit_identical() {
    let trace = session::record_case("mixed-session").expect("record mixed-session");
    let cfg = trace.config.clone();

    // Control: the whole trace on one uninterrupted server. One
    // transcript line per step (mixed-session has no probes), so the
    // control splits index-for-index with the steps.
    let control = session::replay_trace(&trace, true, Wire::V1, None).expect("control replay");
    assert_eq!(control.len(), trace.steps.len());

    // Split right before the 2→3 reshard: the tail then replays through
    // both a snapshot/restore boundary AND a pool resize.
    let mid = trace
        .steps
        .iter()
        .position(|s| match s {
            Step::Request { request, .. } => {
                request.get("op").and_then(Json::as_str) == Some("reshard")
            }
            _ => false,
        })
        .expect("mixed-session has a reshard step");
    assert!(mid > 0 && mid < trace.steps.len() - 1, "split must be interior");

    let coord_cfg = CoordinatorConfig {
        k: cfg.k,
        shards: cfg.shards,
        ..Default::default()
    };

    // Head on coordinator A.
    let coord_a =
        Coordinator::start(coord_cfg.clone(), BackendSpec::Native).expect("start A");
    let server_a = Server::start_with_config("127.0.0.1:0", coord_a.client(), server_cfg(&cfg))
        .expect("serve A");
    let mut rc_a =
        RemoteClient::connect_with_timeout(server_a.addr(), TIMEOUT).expect("connect A");
    rc_a.set_read_timeout(Some(TIMEOUT)).unwrap();
    rc_a.negotiate(Wire::V1.version()).expect("negotiate A");
    let head = session::replay_steps(server_a.addr(), &mut rc_a, &cfg, &trace.steps[..mid])
        .expect("head replay");
    assert_eq!(head.as_slice(), &control[..mid], "head transcript drifted");

    // Carry the trained state into a fresh coordinator B.
    let doc = coord_a.client().snapshot_json();
    drop(rc_a);
    let coord_b =
        Coordinator::start(coord_cfg, BackendSpec::Native).expect("start B");
    let restored = coord_b.client().restore_snapshot(&doc).expect("restore into B");
    assert!(restored > 0, "the snapshot should carry trained tasks");
    let server_b = Server::start_with_config("127.0.0.1:0", coord_b.client(), server_cfg(&cfg))
        .expect("serve B");
    let mut rc_b =
        RemoteClient::connect_with_timeout(server_b.addr(), TIMEOUT).expect("connect B");
    rc_b.set_read_timeout(Some(TIMEOUT)).unwrap();
    rc_b.negotiate(Wire::V1.version()).expect("negotiate B");

    // Tail on B: pinned expects (observe acks, the resharded count) and
    // the control transcript must both hold bit-for-bit.
    let tail = session::replay_steps(server_b.addr(), &mut rc_b, &cfg, &trace.steps[mid..])
        .expect("tail replay");
    assert_eq!(
        tail.as_slice(),
        &control[mid..],
        "the tail after snapshot/restore + reshard drifted from the uninterrupted run"
    );
}
