//! Predictors: KS+ and every baseline of the paper's evaluation, behind
//! one trait.
//!
//! | name                  | allocation over time | retry strategy |
//! |-----------------------|----------------------|----------------|
//! | `ksplus`              | k variable segments  | rescale segment starts; +20 % last peak |
//! | `ksegments-selective` | k equal segments     | offset only the failed segment |
//! | `ksegments-partial`   | k equal segments     | offset failed segment and all after |
//! | `tovar-ppm`           | flat peak            | allocate machine maximum |
//! | `ppm-improved`        | flat peak            | double |
//! | `witt-lr-mean`        | flat peak (LR+sigma) | double |
//! | `witt-lr-max`         | flat peak (LR+max under-prediction) | double |
//! | `default`             | flat developer limit | double |
//!
//! All predictors clamp to the node capacity (128 GB on the paper's
//! testbed) and are trained per task type on that task's history only.

pub mod ksegments;
pub mod ksplus;
pub mod ksplus_auto;
pub mod regression;
pub mod tovar;
pub mod witt;

use crate::segments::StepPlan;
use crate::trace::Execution;

/// Node memory capacity of the paper's testbed, GB.
pub const DEFAULT_CAPACITY_GB: f64 = 128.0;

/// A memory predictor for a single task type.
pub trait Predictor: Send {
    /// Stable identifier used in reports and figures.
    fn name(&self) -> &'static str;

    /// Fit internal models from historical executions of this task.
    fn train(&mut self, history: &[Execution]);

    /// Allocation plan for a new execution with the given input size.
    fn plan(&self, input_mb: f64) -> StepPlan;

    /// Revised plan after an OOM at `fail_time` seconds into an attempt
    /// running `prev`. `attempt` counts failures so far (1 = first).
    fn on_failure(&self, prev: &StepPlan, fail_time: f64, attempt: usize) -> StepPlan;

    /// Node capacity the predictor clamps to.
    fn capacity(&self) -> f64 {
        DEFAULT_CAPACITY_GB
    }
}

/// Construct a predictor by report name. `k` applies to the segment
/// methods; `capacity` to all.
pub fn by_name(name: &str, k: usize, capacity: f64) -> Option<Box<dyn Predictor>> {
    match name {
        "ksplus" => Some(Box::new(ksplus::KsPlus::new(k, capacity))),
        "ksplus-auto" => Some(Box::new(ksplus_auto::KsPlusAuto::new(capacity))),
        "ksegments-selective" => Some(Box::new(ksegments::KSegments::new(
            k,
            capacity,
            ksegments::RetryMode::Selective,
        ))),
        "ksegments-partial" => Some(Box::new(ksegments::KSegments::new(
            k,
            capacity,
            ksegments::RetryMode::Partial,
        ))),
        "tovar-ppm" => Some(Box::new(tovar::TovarPpm::new(capacity, tovar::RetryMode::MachineMax))),
        "ppm-improved" => Some(Box::new(tovar::TovarPpm::new(capacity, tovar::RetryMode::Double))),
        "witt-lr-mean" => Some(Box::new(witt::WittLr::new(capacity, witt::Offset::MeanSigma))),
        "witt-lr-max" => Some(Box::new(witt::WittLr::new(capacity, witt::Offset::MaxUnder))),
        "default" => Some(Box::new(DefaultLimits::new(capacity))),
        _ => None,
    }
}

/// The method set of Fig 6 in paper order, plus our Witt extensions.
pub fn paper_methods() -> Vec<&'static str> {
    vec![
        "ksplus",
        "ksegments-selective",
        "ksegments-partial",
        "tovar-ppm",
        "ppm-improved",
        "default",
    ]
}

pub fn all_methods() -> Vec<&'static str> {
    let mut m = paper_methods();
    m.extend(["witt-lr-mean", "witt-lr-max", "ksplus-auto"]);
    m
}

/// Sanity baseline: the workflow developers' static task limits.
///
/// The limit is taken from the task archetype (like nf-core `process`
/// labels); training only records the fallback peak in case no limit is
/// registered. Retry doubles, as Nextflow's `errorStrategy = 'retry'`
/// idiom does.
pub struct DefaultLimits {
    capacity: f64,
    limit_gb: f64,
}

impl DefaultLimits {
    pub fn new(capacity: f64) -> Self {
        DefaultLimits { capacity, limit_gb: 4.0 }
    }

    pub fn with_limit(capacity: f64, limit_gb: f64) -> Self {
        DefaultLimits { capacity, limit_gb }
    }

    /// Set the developer limit (called by the harness per task type).
    pub fn set_limit(&mut self, limit_gb: f64) {
        self.limit_gb = limit_gb;
    }
}

impl Predictor for DefaultLimits {
    fn name(&self) -> &'static str {
        "default"
    }

    fn train(&mut self, history: &[Execution]) {
        // Developers set limits a priori; nothing is learned. Keep a
        // defensive fallback when no limit was registered: generous 2x
        // max observed peak, the way a user would size it after one run.
        if self.limit_gb <= 0.0 {
            let max_peak = history.iter().map(|e| e.peak()).fold(0.0, f64::max);
            self.limit_gb = (2.0 * max_peak).max(1.0);
        }
    }

    fn plan(&self, _input_mb: f64) -> StepPlan {
        StepPlan::flat(self.limit_gb.min(self.capacity))
    }

    fn on_failure(&self, prev: &StepPlan, _fail_time: f64, _attempt: usize) -> StepPlan {
        // Degenerate (empty) plans fall back to the configured limit.
        let prev_peak = prev.last_peak_or(self.limit_gb.max(1.0));
        StepPlan::flat((prev_peak * 2.0).min(self.capacity))
    }

    fn capacity(&self) -> f64 {
        self.capacity
    }
}

/// Shared helper: clamp a plan to capacity and re-establish validity by
/// merging segments whose starts collapsed.
pub(crate) fn sanitize_plan(mut starts: Vec<f64>, mut peaks: Vec<f64>, capacity: f64) -> StepPlan {
    debug_assert_eq!(starts.len(), peaks.len());
    if starts.is_empty() {
        return StepPlan::flat(capacity);
    }
    starts[0] = 0.0;
    // Enforce monotone peaks and capacity clamp.
    for i in 0..peaks.len() {
        if i > 0 && peaks[i] < peaks[i - 1] {
            peaks[i] = peaks[i - 1];
        }
        peaks[i] = peaks[i].min(capacity).max(1e-3);
    }
    // Merge segments with non-increasing starts (keep the later peak,
    // which is >= by monotonicity).
    let mut out_s = vec![starts[0]];
    let mut out_p = vec![peaks[0]];
    for i in 1..starts.len() {
        if starts[i] <= *out_s.last().unwrap() + 1e-9 {
            *out_p.last_mut().unwrap() = peaks[i].max(*out_p.last().unwrap());
        } else if (peaks[i] - *out_p.last().unwrap()).abs() < 1e-12 {
            // Equal peak: extending the previous segment, skip the split.
            continue;
        } else {
            out_s.push(starts[i]);
            out_p.push(peaks[i]);
        }
    }
    StepPlan::new(out_s, out_p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn by_name_covers_all_methods() {
        for m in all_methods() {
            let p = by_name(m, 4, 128.0).unwrap_or_else(|| panic!("missing {m}"));
            assert_eq!(p.name(), m);
        }
        assert!(by_name("nope", 4, 128.0).is_none());
    }

    #[test]
    fn default_limits_plan_and_retry() {
        let mut p = DefaultLimits::with_limit(128.0, 16.0);
        p.train(&[]);
        let plan = p.plan(1000.0);
        assert_eq!(plan, StepPlan::flat(16.0));
        let retry = p.on_failure(&plan, 5.0, 1);
        assert_eq!(retry, StepPlan::flat(32.0));
        // Doubling saturates at capacity.
        let big = p.on_failure(&StepPlan::flat(100.0), 5.0, 2);
        assert_eq!(big, StepPlan::flat(128.0));
    }

    #[test]
    fn default_limits_fallback_from_history() {
        let mut p = DefaultLimits::with_limit(128.0, 0.0);
        let e = Execution::new("t", 1.0, 1.0, vec![1.0, 3.0]);
        p.train(&[e]);
        assert_eq!(p.plan(0.0), StepPlan::flat(6.0));
    }

    #[test]
    fn sanitize_merges_collapsed_starts() {
        let p = sanitize_plan(vec![0.0, 5.0, 5.0, 9.0], vec![1.0, 2.0, 3.0, 4.0], 128.0);
        assert!(p.is_valid());
        assert_eq!(p.starts, vec![0.0, 5.0, 9.0]);
        assert_eq!(p.peaks, vec![1.0, 3.0, 4.0]);
    }

    #[test]
    fn sanitize_enforces_monotone_peaks() {
        // Peak 2.0 is raised to 4.0, then merged with its equal-peak
        // predecessor; allocation over time is the running max.
        let p = sanitize_plan(vec![0.0, 5.0, 10.0], vec![4.0, 2.0, 8.0], 128.0);
        assert!(p.is_valid());
        assert_eq!(p.starts, vec![0.0, 10.0]);
        assert_eq!(p.peaks, vec![4.0, 8.0]);
        assert_eq!(p.alloc_at(7.0), 4.0);
    }

    #[test]
    fn sanitize_clamps_capacity() {
        let p = sanitize_plan(vec![0.0, 1.0], vec![100.0, 400.0], 128.0);
        assert_eq!(p.peaks.last(), Some(&128.0));
        assert!(p.is_valid());
    }

    #[test]
    fn prop_sanitize_always_valid() {
        run_prop("sanitize_valid", 300, |rng| {
            let k = 1 + rng.below(8);
            let mut starts = vec![0.0];
            let mut peaks = vec![rng.uniform(0.1, 200.0)];
            for _ in 1..k {
                // Deliberately messy: may repeat starts, decrease peaks.
                starts.push(starts.last().unwrap() + rng.uniform(0.0, 20.0));
                peaks.push(rng.uniform(0.1, 200.0));
            }
            let p = sanitize_plan(starts, peaks, 128.0);
            assert!(p.is_valid(), "invalid after sanitize: {p:?}");
            assert!(p.peaks.iter().all(|&x| x <= 128.0));
        });
    }
}
