//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   1. Safety offsets (Section II-B): sweep the +10 % memory and −15 %
//!      start-time offsets and measure total wastage.
//!   2. Retry strategy (Section II-C): KS+ segment rescaling vs naive
//!      peak doubling on the same plans.
//!   3. Dynamic k (future work): ksplus-auto vs fixed k.
//!
//! Run: `cargo bench --bench ablation`.

use ksplus::experiments::{evaluate_method, ExpConfig};
use ksplus::metrics::WastageReport;
use ksplus::predictor::ksplus::KsPlus;
use ksplus::predictor::Predictor;
use ksplus::segments::StepPlan;
use ksplus::sim::run_all;
use ksplus::trace::workflow::Workflow;
use ksplus::trace::split_train_test;
use ksplus::util::rng::Rng;

/// Evaluate a custom-built predictor over the whole workflow.
fn evaluate_custom<F>(_wf: &Workflow, trace: &ksplus::trace::WorkflowTrace, build: F) -> f64
where
    F: Fn() -> Box<dyn Predictor>,
{
    let mut report = WastageReport::default();
    for (idx, t) in trace.tasks.iter().enumerate() {
        let mut rng = Rng::new(1).fork(idx as u64 + 1);
        let (train, test) = split_train_test(t, 0.5, &mut rng);
        let mut pred = build();
        pred.train(&train);
        for o in run_all(pred.as_ref(), &test) {
            report.add(&o);
        }
    }
    report.total_wastage_gbs()
}

/// KS+ with the paper's retry replaced by naive doubling — isolates the
/// contribution of the segment-rescaling strategy.
struct KsPlusDoublingRetry(KsPlus);

impl Predictor for KsPlusDoublingRetry {
    fn name(&self) -> &'static str {
        "ksplus-doubling-retry"
    }
    fn train(&mut self, h: &[ksplus::trace::Execution]) {
        self.0.train(h);
    }
    fn plan(&self, input_mb: f64) -> StepPlan {
        self.0.plan(input_mb)
    }
    fn on_failure(&self, prev: &StepPlan, _t: f64, _a: usize) -> StepPlan {
        StepPlan::new(
            prev.starts.clone(),
            prev.peaks.iter().map(|p| (p * 2.0).min(self.0.capacity())).collect(),
        )
    }
    fn capacity(&self) -> f64 {
        self.0.capacity()
    }
}

fn main() {
    let cfg = ExpConfig::default();
    let wf = Workflow::eager();
    let trace = wf.generate(cfg.trace_seed, cfg.target_samples);

    println!("== ablation 1: safety offsets (eager, 50% train, k=4) ==");
    println!("{:>10} {:>10} {:>14}", "mem", "time", "wastage GBs");
    for mem in [1.0, 1.05, 1.10, 1.20] {
        for time in [1.0, 0.85, 0.70] {
            let w = evaluate_custom(&wf, &trace, || {
                Box::new(KsPlus::new(4, 128.0).with_offsets(mem, time))
            });
            let mark = if (mem, time) == (1.10, 0.85) { "  <- paper" } else { "" };
            println!("{mem:>10.2} {time:>10.2} {w:>14.0}{mark}");
        }
    }

    println!("\n== ablation 2: retry strategy (eager, 50% train, k=4) ==");
    let w_rescale = evaluate_custom(&wf, &trace, || Box::new(KsPlus::new(4, 128.0)));
    let w_double = evaluate_custom(&wf, &trace, || {
        Box::new(KsPlusDoublingRetry(KsPlus::new(4, 128.0)))
    });
    println!("  segment rescaling (paper): {w_rescale:>10.0} GBs");
    println!("  naive peak doubling      : {w_double:>10.0} GBs");
    println!(
        "  rescaling saves          : {:>9.1}%",
        (1.0 - w_rescale / w_double) * 100.0
    );

    println!("\n== ablation 3: dynamic k selection (future work) ==");
    for (label, method, k) in [
        ("fixed k=2", "ksplus", 2),
        ("fixed k=4", "ksplus", 4),
        ("fixed k=8", "ksplus", 8),
        ("auto (CV)", "ksplus-auto", 4),
    ] {
        let r = evaluate_method(method, k, 128.0, &wf, &trace, 0.5, 1).unwrap();
        println!("  {label:>10}: {:>10.0} GBs", r.total_wastage_gbs());
    }
}
