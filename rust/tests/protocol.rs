//! Wire v1 conformance over a real TCP connection: every
//! malformed-request class maps to its *specific* structured error code
//! (never a catch-all string), version negotiation works both ways, and
//! the typed `RemoteClient` round-trips every op against the live
//! server. The parse-level (service layer) table lives in
//! `coordinator::protocol`'s unit tests; this file exercises the same
//! classes end-to-end through the socket.

use ksplus::coordinator::protocol::{WIRE_VERSION, OPS};
use ksplus::coordinator::remote::RemoteClient;
use ksplus::coordinator::server::Server;
use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
use ksplus::coordinator::{BackendSpec, PredictorPolicy};
use ksplus::segments::StepPlan;
use ksplus::trace::Execution;
use ksplus::util::json::Json;
use ksplus::util::rng::Rng;

fn start(shards: usize) -> (Coordinator, Server) {
    Server::start_with_backend(
        "127.0.0.1:0",
        CoordinatorConfig { k: 2, shards, ..Default::default() },
        BackendSpec::Native,
    )
    .unwrap()
}

fn history(seed: u64, n: usize) -> Vec<Execution> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let input = rng.uniform(2000.0, 9000.0);
            let len = 4 + rng.below(6);
            let samples: Vec<f64> = (0..len)
                .map(|j| 0.0005 * input * if j < len / 2 { 1.0 } else { 2.0 })
                .collect();
            Execution::new("t", input, 1.0, samples)
        })
        .collect()
}

#[test]
fn malformed_requests_map_to_specific_error_codes_over_tcp() {
    let (_coord, server) = start(1);
    let mut rc = RemoteClient::connect(server.addr()).unwrap();
    let table: &[(&str, &str)] = &[
        ("not json at all", "invalid-json"),
        (r#"{"task":"x"}"#, "missing-field"),
        (r#"{"op":42}"#, "invalid-field"),
        (r#"{"op":"frobnicate"}"#, "unknown-op"),
        (r#"{"op":"plan"}"#, "missing-field"),
        (r#"{"op":"plan","task":"x"}"#, "missing-field"),
        (r#"{"op":"plan","task":7,"input_mb":1}"#, "invalid-field"),
        (r#"{"op":"plan","task":"x","input_mb":"big"}"#, "invalid-field"),
        (r#"{"op":"train","task":"x"}"#, "missing-field"),
        (r#"{"op":"train","task":"x","history":[]}"#, "empty-history"),
        (
            r#"{"op":"train","task":"x","history":[{"input_mb":1,"dt":1,"samples":[]}]}"#,
            "empty-samples",
        ),
        (
            r#"{"op":"train","task":"x","history":[{"input_mb":1,"dt":0,"samples":[1]}]}"#,
            "invalid-field",
        ),
        (r#"{"op":"observe","task":"x"}"#, "missing-field"),
        (
            r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1,"samples":[]}}"#,
            "empty-samples",
        ),
        (
            r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1,"samples":["a"]}}"#,
            "invalid-field",
        ),
        (r#"{"op":"configure","task":"x"}"#, "missing-field"),
        (r#"{"op":"configure","task":"x","policy":"nope"}"#, "unknown-policy"),
        (r#"{"op":"configure","task":"*","policy":"ksplus"}"#, "invalid-field"),
        (r#"{"op":"failure","fail_time":1}"#, "missing-field"),
        (r#"{"op":"failure","plan":{"starts":[0],"peaks":[1]}}"#, "missing-field"),
        (
            r#"{"op":"failure","plan":{"starts":[],"peaks":[]},"fail_time":1}"#,
            "invalid-plan",
        ),
        (
            r#"{"op":"failure","plan":{"starts":[0,1],"peaks":[1]},"fail_time":1}"#,
            "invalid-plan",
        ),
        (r#"{"op":"hello","min_version":99}"#, "unsupported-version"),
    ];
    for (line, want) in table {
        let j = rc.raw(line).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line} -> {j}");
        let err = j.get("error").expect("error object");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(*want), "req {line} -> {j}");
        let msg = err.get("message").and_then(Json::as_str).unwrap_or("");
        assert!(!msg.is_empty(), "empty error message for {line}");
    }
    // The connection survived every error class.
    let info = rc.hello().unwrap();
    assert_eq!(info.version, WIRE_VERSION);
}

#[test]
fn remote_client_roundtrips_every_op() {
    let (_coord, server) = start(2);
    let mut rc = RemoteClient::connect(server.addr()).unwrap();
    let info = rc.hello().unwrap();
    assert_eq!(info.version, WIRE_VERSION);
    assert_eq!(info.shards, 2);
    assert_eq!(info.ops, OPS.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    assert_eq!(
        info.policies,
        PredictorPolicy::names().iter().map(|s| s.to_string()).collect::<Vec<_>>()
    );

    rc.configure(Some("a"), PredictorPolicy::KsPlus).unwrap();
    rc.configure(None, PredictorPolicy::KsPlus).unwrap();
    let hist = history(5, 10);
    assert_eq!(rc.train("a", &hist).unwrap(), 10);
    let ack = rc.observe("a", &hist[0]).unwrap();
    assert_eq!(ack.task, "a");
    assert_eq!(ack.executions, 11);
    assert_eq!(ack.predictor, "ksplus");
    let out = rc.plan("a", 5000.0).unwrap();
    assert_eq!(out.predictor, "ksplus");
    assert_eq!(out.model_version, 11);
    assert!(out.plan.is_valid());
    let retry = rc
        .report_failure(Some("a"), &StepPlan::new(vec![0.0, 80.0], vec![2.0, 6.0]), 40.0)
        .unwrap();
    assert_eq!(retry.predictor, "ksplus");
    assert_eq!(retry.plan.starts, vec![0.0, 40.0]);
    let s = rc.stats().unwrap();
    assert_eq!(s.shards, 2);
    assert_eq!(s.requests, 1);
    assert_eq!(s.tasks_trained, 1);
    assert_eq!(s.observations, 1);
    assert_eq!(s.failures_handled, 1);
    assert_eq!(s.fallbacks, 0);
    assert_eq!(s.conns_refused, 0);
    assert_eq!(s.conn_timeouts, 0);

    // Admin ops: snapshot dumps a restorable doc, reshard resizes the
    // pool without touching the plans a client sees.
    let doc = rc.snapshot().unwrap();
    assert!(doc.get("schema").and_then(Json::as_str).is_some(), "{doc}");
    let ids = rc.reshard(3).unwrap();
    assert_eq!(ids.len(), 3);
    let out2 = rc.plan("a", 5000.0).unwrap();
    assert_eq!(out2, out, "resharding changed a served plan");
}

#[test]
fn wire_errors_surface_as_typed_wire_error() {
    let (_coord, server) = start(1);
    let mut rc = RemoteClient::connect(server.addr()).unwrap();
    // A typed call that the server rejects: unknown policy never leaves
    // the client in this API, so drive a version mismatch instead.
    let err = rc
        .raw(r#"{"op":"configure","task":"x","policy":"nope"}"#)
        .unwrap();
    assert_eq!(
        err.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("unknown-policy")
    );
    // The typed surface reports structured errors through anyhow.
    let e = rc.plan("", f64::NAN);
    // NaN input is serializable trouble: the request still parses (JSON
    // has no NaN literal, our writer prints it as a bare token) — accept
    // either a transport error or a served fallback, but never a panic.
    drop(e);
    // Connection still fine for well-formed traffic.
    assert!(rc.stats().is_ok());
}
