//! Threaded coordinator service: a sharded pool of workers, each with a
//! dynamic batcher + request router over its own shard-local `ModelStore`.
//!
//! `CoordinatorConfig::shards` controls the pool width (default 1, which
//! preserves the original single-worker behavior exactly). Each worker
//! thread owns its own `ModelStore` and numeric backend — the backend is
//! built *inside* the worker thread because PJRT handles are thread-affine
//! — and runs an independent dynamic batcher: plan requests coalesce per
//! shard, so a flush costs one batched predict regardless of the number of
//! clients on that shard.
//!
//! Routing: `Train`, `Observe`, and `Plan` go to `shard_for(task) =
//! fnv1a(task) % shards`, so a task's models and all its plan traffic
//! live on exactly one shard — an observed execution is visible to the
//! task's very next plan. `Failure` carries no task and is distributed
//! round-robin. `Stats` fans out to every shard and the per-shard
//! counters/latency windows are merged into one aggregate
//! `ServiceStats`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::coordinator::{
    BackendSpec, ModelStore, PlanOutcome, PlanScratch, PredictorPolicy, RetryOutcome,
};
use crate::segments::StepPlan;
use crate::trace::Execution;

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Segments per task model.
    pub k: usize,
    pub capacity_gb: f64,
    /// Flush the batcher at this many pending plan requests.
    pub batch_max: usize,
    /// ... or when the oldest pending request is this old.
    pub batch_delay: Duration,
    /// Worker shards. Each shard owns its own model store, backend, and
    /// batcher; tasks are routed by a deterministic name hash. `1`
    /// reproduces the original single-worker coordinator.
    pub shards: usize,
    /// Predictor policy for tasks with no explicit `configure` binding;
    /// pinned per task the first time it is trained or observed.
    pub default_policy: PredictorPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            k: 4,
            capacity_gb: 128.0,
            batch_max: 64,
            batch_delay: Duration::from_millis(1),
            shards: 1,
            default_policy: PredictorPolicy::KsPlus,
        }
    }
}

/// Deterministic task-to-shard routing: FNV-1a over the task name with a
/// murmur3-style avalanche finalizer. Both `train` and `plan` use this,
/// so a trained task is always found by the shard its plan requests land
/// on. The finalizer matters: raw FNV-1a has weak low bits on short,
/// similar names (all nine eager-workflow tasks share one parity), which
/// would collapse small shard counts onto a single worker.
pub fn shard_for(task: &str, shards: usize) -> usize {
    assert!(shards > 0, "shard_for with zero shards");
    let mut h = crate::util::fnv1a(task);
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51afd7ed558ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ceb9fe1a85ec53);
    h ^= h >> 33;
    (h % shards as u64) as usize
}

/// How many recent plan latencies each shard retains. A long-running
/// service must not grow a sample per request forever; percentiles are
/// computed over this sliding window of the most recent requests.
pub const LATENCY_WINDOW: usize = 4096;

/// Bounded ring buffer of the most recent latency samples. Replaces an
/// unbounded `Vec<f64>` that grew by one `f64` per request for the
/// lifetime of the service.
#[derive(Debug, Clone)]
pub struct LatencyWindow {
    buf: Vec<f64>,
    cap: usize,
    /// Next overwrite position once the buffer is full.
    next: usize,
    /// Samples ever recorded (not capped).
    total: u64,
}

impl Default for LatencyWindow {
    fn default() -> Self {
        LatencyWindow::with_capacity(LATENCY_WINDOW)
    }
}

impl LatencyWindow {
    pub fn with_capacity(cap: usize) -> LatencyWindow {
        assert!(cap > 0, "latency window needs capacity");
        LatencyWindow { buf: Vec::new(), cap, next: 0, total: 0 }
    }

    pub fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % self.cap;
        self.total += 1;
    }

    /// Samples currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Samples recorded over the service lifetime.
    pub fn total_recorded(&self) -> u64 {
        self.total
    }

    /// Percentile over the retained window.
    pub fn percentile(&self, p: f64) -> f64 {
        crate::util::stats::percentile(&self.buf, p)
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.buf
    }

    /// Retained samples in arrival order (oldest first). The ring stores
    /// samples in overwrite order once wrapped; this re-linearizes.
    pub fn chronological(&self) -> Vec<f64> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut v = Vec::with_capacity(self.buf.len());
            v.extend_from_slice(&self.buf[self.next..]);
            v.extend_from_slice(&self.buf[..self.next]);
            v
        }
    }

    /// Absorb another window. The merged window keeps *every* retained
    /// sample from both sides (capacity grows as needed), so aggregating
    /// N shards never silently drops samples any one shard retained, and
    /// percentiles over the merge are exact over the union.
    pub fn merge(&mut self, other: &LatencyWindow) {
        let mut all = self.chronological();
        all.extend(other.chronological());
        let cap = self.cap.max(all.len()).max(1);
        let next = all.len() % cap;
        let total = self.total + other.total;
        *self = LatencyWindow { buf: all, cap, next, total };
    }
}

/// Service-side counters, exposed via `Client::stats`. For a sharded
/// coordinator this is either one shard's view (`Client::shard_stats`) or
/// the merge across all shards (`Client::stats`).
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    pub failures_handled: u64,
    pub tasks_trained: u64,
    /// Single executions folded in via the incremental `Observe` path.
    pub observations: u64,
    /// Plans served by the untrained flat default (counted whenever a
    /// `PlanOutcome` carries a `fallback_reason`). Before this counter,
    /// silent fallbacks were indistinguishable from real predictions in
    /// every metric.
    pub fallbacks: u64,
    /// Recent plan-request latencies, microseconds (enqueue -> response
    /// send), bounded to the last `LATENCY_WINDOW` requests per shard.
    pub latencies_us: LatencyWindow,
}

impl ServiceStats {
    /// Fold another shard's counters and latency window into this one.
    /// After merging, `mean_batch_size` and `latency_percentile_us` are
    /// computed over the union (summed counters, concatenated windows).
    pub fn merge(&mut self, other: &ServiceStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.failures_handled += other.failures_handled;
        self.tasks_trained += other.tasks_trained;
        self.observations += other.observations;
        self.fallbacks += other.fallbacks;
        self.latencies_us.merge(&other.latencies_us);
    }

    /// Aggregate view over a set of per-shard stats.
    pub fn merged(parts: &[ServiceStats]) -> ServiceStats {
        let mut out = ServiceStats::default();
        for p in parts {
            out.merge(p);
        }
        out
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        self.latencies_us.percentile(p)
    }
}

enum Msg {
    Configure {
        /// `None` sets the shard's default policy for unbound tasks.
        task: Option<String>,
        policy: PredictorPolicy,
        done: mpsc::SyncSender<()>,
    },
    Train {
        task: String,
        history: Vec<Execution>,
        done: mpsc::SyncSender<()>,
    },
    Observe {
        task: String,
        execution: Execution,
        /// Replies with the task's total observation count and the
        /// policy the execution was folded under.
        done: mpsc::SyncSender<(u64, &'static str)>,
    },
    Plan {
        task: String,
        input_mb: f64,
        enqueued: Instant,
        resp: mpsc::SyncSender<PlanOutcome>,
    },
    Failure {
        /// Route the retry through this task's bound policy; a task-less
        /// report uses the KS+ strategy.
        task: Option<String>,
        prev: StepPlan,
        fail_time: f64,
        resp: mpsc::SyncSender<RetryOutcome>,
    },
    Stats {
        resp: mpsc::SyncSender<ServiceStats>,
    },
    Shutdown,
}

/// Handle to a running coordinator pool; cheap to clone via `client()`.
pub struct Coordinator {
    txs: Vec<mpsc::Sender<Msg>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Round-robin cursor for task-less messages (`Failure`).
    rr: Arc<AtomicUsize>,
}

/// Client endpoint (clonable, thread-safe senders to every shard).
#[derive(Clone)]
pub struct Client {
    txs: Vec<mpsc::Sender<Msg>>,
    rr: Arc<AtomicUsize>,
}

struct Pending {
    task: String,
    input_mb: f64,
    enqueued: Instant,
    resp: mpsc::SyncSender<PlanOutcome>,
}

impl Coordinator {
    /// Spawn `cfg.shards` workers. Each backend is *built inside* its
    /// worker thread because PJRT handles are thread-affine, but build
    /// failures are reported back over a readiness channel so the caller
    /// gets an `Err` here instead of clients later dying on a dead
    /// channel ("coordinator gone").
    pub fn start(cfg: CoordinatorConfig, spec: BackendSpec) -> anyhow::Result<Coordinator> {
        anyhow::ensure!(cfg.shards >= 1, "coordinator needs at least one shard");
        let mut txs = Vec::with_capacity(cfg.shards);
        let mut handles = Vec::with_capacity(cfg.shards);
        let mut readies = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let (tx, rx) = mpsc::channel::<Msg>();
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<(), String>>(1);
            let shard_cfg = cfg.clone();
            let shard_spec = spec.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ksplus-coordinator-{i}"))
                .spawn(move || {
                    let backend = match shard_spec.build() {
                        Ok(b) => {
                            let _ = ready_tx.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    worker(shard_cfg, backend, rx)
                })
                .with_context(|| format!("spawn coordinator shard {i}"))?;
            txs.push(tx);
            handles.push(handle);
            readies.push(ready_rx);
        }
        for (i, ready) in readies.into_iter().enumerate() {
            let built = ready
                .recv()
                .unwrap_or_else(|_| Err("worker died before reporting readiness".into()));
            if let Err(msg) = built {
                // Wind down whatever did start before surfacing the error.
                for tx in &txs {
                    let _ = tx.send(Msg::Shutdown);
                }
                for h in handles {
                    let _ = h.join();
                }
                return Err(anyhow::anyhow!("coordinator shard {i}: {msg}"));
            }
        }
        Ok(Coordinator { txs, handles, rr: Arc::new(AtomicUsize::new(0)) })
    }

    pub fn client(&self) -> Client {
        Client { txs: self.txs.clone(), rr: self.rr.clone() }
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Client {
    fn tx_for(&self, task: &str) -> &mpsc::Sender<Msg> {
        &self.txs[shard_for(task, self.txs.len())]
    }

    /// Any shard, for messages that carry no task (round-robin so the
    /// load spreads).
    fn any_tx(&self) -> &mpsc::Sender<Msg> {
        &self.txs[self.rr.fetch_add(1, Ordering::Relaxed) % self.txs.len()]
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Bind a task to a predictor policy — or, with `task: None`, set
    /// every shard's default policy for tasks not yet pinned to one.
    /// Blocks until the binding is visible (all shards, for a default).
    pub fn configure(&self, task: Option<&str>, policy: PredictorPolicy) {
        match task {
            Some(t) => {
                let (done_tx, done_rx) = mpsc::sync_channel(1);
                self.tx_for(t)
                    .send(Msg::Configure {
                        task: Some(t.to_string()),
                        policy,
                        done: done_tx,
                    })
                    .expect("coordinator gone");
                let _ = done_rx.recv();
            }
            None => {
                // Fan out to every shard, pipelined like `shard_stats`.
                let pending: Vec<mpsc::Receiver<()>> = self
                    .txs
                    .iter()
                    .map(|tx| {
                        let (done_tx, done_rx) = mpsc::sync_channel(1);
                        tx.send(Msg::Configure { task: None, policy, done: done_tx })
                            .expect("coordinator gone");
                        done_rx
                    })
                    .collect();
                for rx in pending {
                    let _ = rx.recv();
                }
            }
        }
    }

    /// Fit (or refit) the task's models under its bound policy; blocks
    /// until stored.
    pub fn train(&self, task: &str, history: Vec<Execution>) {
        let (done_tx, done_rx) = mpsc::sync_channel(1);
        self.tx_for(task)
            .send(Msg::Train { task: task.to_string(), history, done: done_tx })
            .expect("coordinator gone");
        let _ = done_rx.recv();
    }

    /// Fold one finished execution into the task's models — the O(k)
    /// incremental update on the shard that owns the task (same hash
    /// route as `train`/`plan`, so the updated models serve the task's
    /// very next plan request). Returns the task's total observation
    /// count; blocks until the model swap is visible.
    pub fn observe(&self, task: &str, execution: Execution) -> u64 {
        self.observe_detailed(task, execution).0
    }

    /// `observe` plus provenance: (total observation count, name of the
    /// policy the execution was folded under).
    pub fn observe_detailed(&self, task: &str, execution: Execution) -> (u64, &'static str) {
        let (done_tx, done_rx) = mpsc::sync_channel(1);
        self.tx_for(task)
            .send(Msg::Observe { task: task.to_string(), execution, done: done_tx })
            .expect("coordinator gone");
        done_rx.recv().expect("coordinator dropped request")
    }

    /// Request an allocation plan; blocks until the shard's batcher
    /// flushes.
    pub fn plan(&self, task: &str, input_mb: f64) -> StepPlan {
        self.plan_detailed(task, input_mb).plan
    }

    /// `plan` plus provenance: which policy served it, its model
    /// version, and whether it was an untrained fallback.
    pub fn plan_detailed(&self, task: &str, input_mb: f64) -> PlanOutcome {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.tx_for(task)
            .send(Msg::Plan {
                task: task.to_string(),
                input_mb,
                enqueued: Instant::now(),
                resp: resp_tx,
            })
            .expect("coordinator gone");
        resp_rx.recv().expect("coordinator dropped request")
    }

    /// Report an OOM; returns the rescaled retry plan (KS+ strategy).
    /// Task-less and stateless, so any shard serves it.
    pub fn report_failure(&self, prev: &StepPlan, fail_time: f64) -> StepPlan {
        self.report_failure_for(None, prev, fail_time).plan
    }

    /// Report an OOM for a specific task: the retry runs that task's
    /// bound policy's strategy on its owning shard. A task-less report
    /// round-robins and uses the KS+ strategy.
    pub fn report_failure_for(
        &self,
        task: Option<&str>,
        prev: &StepPlan,
        fail_time: f64,
    ) -> RetryOutcome {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        let tx = match task {
            Some(t) => self.tx_for(t),
            None => self.any_tx(),
        };
        tx.send(Msg::Failure {
            task: task.map(str::to_string),
            prev: prev.clone(),
            fail_time,
            resp: resp_tx,
        })
        .expect("coordinator gone");
        resp_rx.recv().expect("coordinator dropped request")
    }

    /// Aggregate counters across every shard.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats::merged(&self.shard_stats())
    }

    /// Per-shard counters, in shard order. The fan-out is pipelined —
    /// every shard is queried before any reply is awaited — so the
    /// aggregate costs the slowest shard's queue delay, not the sum.
    pub fn shard_stats(&self) -> Vec<ServiceStats> {
        let pending: Vec<mpsc::Receiver<ServiceStats>> = self
            .txs
            .iter()
            .map(|tx| {
                let (resp_tx, resp_rx) = mpsc::sync_channel(1);
                tx.send(Msg::Stats { resp: resp_tx }).expect("coordinator gone");
                resp_rx
            })
            .collect();
        pending
            .into_iter()
            .map(|rx| rx.recv().expect("coordinator dropped request"))
            .collect()
    }
}

/// Serve every pending plan request in one batched predict. Task names
/// are *borrowed* from the pending queue and the intermediate numeric
/// buffers live in the worker's reusable `scratch`, so a steady-state
/// flush performs no per-request `String` clones (one `Vec` of borrowed
/// request tuples is still built per flush — it cannot outlive the
/// pending queue it borrows from).
fn flush(
    pending: &mut Vec<Pending>,
    store: &ModelStore,
    stats: &mut ServiceStats,
    scratch: &mut PlanScratch,
) {
    if pending.is_empty() {
        return;
    }
    let reqs: Vec<(&str, f64)> =
        pending.iter().map(|p| (p.task.as_str(), p.input_mb)).collect();
    store.plan_batch_into(&reqs, scratch);
    drop(reqs);
    stats.batches += 1;
    for (p, outcome) in pending.drain(..).zip(scratch.plans.drain(..)) {
        stats.requests += 1;
        if outcome.fallback_reason.is_some() {
            stats.fallbacks += 1;
        }
        stats.latencies_us.push(p.enqueued.elapsed().as_secs_f64() * 1e6);
        let _ = p.resp.send(outcome);
    }
}

fn worker(cfg: CoordinatorConfig, backend: crate::coordinator::Backend, rx: mpsc::Receiver<Msg>) {
    let mut store = ModelStore::new(cfg.k, cfg.capacity_gb, backend);
    store.set_default_policy(cfg.default_policy);
    let mut stats = ServiceStats::default();
    let mut pending: Vec<Pending> = Vec::new();
    let mut scratch = PlanScratch::default();

    // Continuous ("drain-then-flush") batching: block for the first
    // message, then greedily drain whatever else is already queued —
    // requests that arrived while the previous batch was being served
    // coalesce naturally, and an idle service answers in microseconds
    // instead of waiting out a fixed delay. `batch_delay` survives only
    // as the bound on one final linger poll used when a single request
    // is pending (cheap insurance for lock-step submitters).
    'outer: loop {
        let mut next = match rx.recv() {
            Ok(m) => Some(m),
            Err(_) => break,
        };
        // Handle one message; Plan messages start a drain cycle.
        while let Some(msg) = next.take() {
            match msg {
                Msg::Plan { task, input_mb, enqueued, resp } => {
                    pending.push(Pending { task, input_mb, enqueued, resp });
                    // Drain everything already enqueued.
                    while pending.len() < cfg.batch_max {
                        match rx.try_recv() {
                            Ok(Msg::Plan { task, input_mb, enqueued, resp }) => {
                                pending.push(Pending { task, input_mb, enqueued, resp });
                            }
                            Ok(other) => {
                                next = Some(other);
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                flush(&mut pending, &store, &mut stats, &mut scratch);
                                break 'outer;
                            }
                        }
                    }
                    // Linger once for stragglers when the batch is tiny.
                    if next.is_none() && pending.len() == 1 && !cfg.batch_delay.is_zero() {
                        if let Ok(m) = rx.recv_timeout(cfg.batch_delay.min(
                            Duration::from_micros(100),
                        )) {
                            match m {
                                Msg::Plan { task, input_mb, enqueued, resp } => {
                                    pending.push(Pending { task, input_mb, enqueued, resp });
                                }
                                other => next = Some(other),
                            }
                        }
                    }
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                }
                Msg::Train { task, history, done } => {
                    // Train implies a model swap: flush first so
                    // in-flight requests see a consistent store.
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    store.train(&task, &history);
                    stats.tasks_trained += 1;
                    let _ = done.send(());
                }
                Msg::Configure { task, policy, done } => {
                    // A policy swap is a model swap: flush first so
                    // in-flight requests see a consistent routing.
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    match task {
                        Some(t) => {
                            store.configure(&t, policy);
                        }
                        None => store.set_default_policy(policy),
                    }
                    let _ = done.send(());
                }
                Msg::Observe { task, execution, done } => {
                    // Also a model swap, just an O(k) incremental one.
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    // The store decides what counts as folded (e.g.
                    // sample-less executions are no-ops); the counter
                    // follows its verdict so the two can never drift.
                    let (folded, count) = store.observe(&task, &execution);
                    if folded {
                        stats.observations += 1;
                    }
                    let _ = done.send((count, store.policy_of(&task).name()));
                }
                Msg::Failure { task, prev, fail_time, resp } => {
                    stats.failures_handled += 1;
                    let _ = resp.send(store.on_failure_for(task.as_deref(), &prev, fail_time));
                }
                Msg::Stats { resp } => {
                    let _ = resp.send(stats.clone());
                }
                Msg::Shutdown => {
                    flush(&mut pending, &store, &mut stats, &mut scratch);
                    break 'outer;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::ksplus::KsPlus;
    use crate::predictor::Predictor;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn two_phase_exec(input: f64, rng: &mut Rng) -> Execution {
        let d1 = ((input * 0.01) as usize).max(2);
        let d2 = ((input * 0.003) as usize).max(1);
        let mut s = vec![input * 0.0005; d1];
        s.extend(vec![input * 0.001; d2]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new("bwa", input, 1.0, s)
    }

    fn history(seed: u64, n: usize) -> Vec<Execution> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect()
    }

    /// Two task names guaranteed to route to different shards.
    fn two_tasks_on_distinct_shards(shards: usize) -> (String, String) {
        assert!(shards > 1, "needs at least two shards to find distinct routes");
        let a = "task-a".to_string();
        let sa = shard_for(&a, shards);
        let mut i = 0u64;
        loop {
            let b = format!("task-b{i}");
            if shard_for(&b, shards) != sa {
                return (a, b);
            }
            i += 1;
        }
    }

    #[test]
    fn end_to_end_plan_matches_offline_predictor() {
        let hist = history(1, 30);
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", hist.clone());
        let got = client.plan("bwa", 8000.0);
        let mut want = KsPlus::new(2, 128.0);
        want.train(&hist);
        let want = want.plan(8000.0);
        assert_eq!(got.k(), want.k());
        for i in 0..got.k() {
            assert!((got.starts[i] - want.starts[i]).abs() < 1e-9);
            assert!((got.peaks[i] - want.peaks[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn concurrent_clients_get_batched() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                k: 2,
                batch_max: 16,
                batch_delay: Duration::from_millis(4),
                ..Default::default()
            },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", history(2, 20));
        let mut handles = Vec::new();
        for i in 0..32 {
            let c = coord.client();
            handles.push(std::thread::spawn(move || {
                c.plan("bwa", 3000.0 + i as f64 * 100.0)
            }));
        }
        let plans: Vec<StepPlan> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(plans.len(), 32);
        assert!(plans.iter().all(|p| p.is_valid()));
        let stats = client.stats();
        assert_eq!(stats.requests, 32);
        assert!(stats.batches < 32, "no batching happened: {}", stats.batches);
        assert!(stats.mean_batch_size() > 1.0);
    }

    #[test]
    fn failure_roundtrip() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let retry = client.report_failure(&prev, 60.0);
        assert_eq!(retry.starts, vec![0.0, 60.0]);
        assert_eq!(client.stats().failures_handled, 1);
    }

    #[test]
    fn unknown_task_served_with_fallback() {
        let coord =
            Coordinator::start(CoordinatorConfig::default(), BackendSpec::Native).unwrap();
        let plan = coord.client().plan("never-trained", 123.0);
        assert!(plan.is_valid());
    }

    #[test]
    fn stats_latency_recorded() {
        let coord = Coordinator::start(
            CoordinatorConfig { batch_delay: Duration::from_micros(200), ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", history(3, 10));
        for _ in 0..5 {
            client.plan("bwa", 4000.0);
        }
        let stats = client.stats();
        assert_eq!(stats.latencies_us.len(), 5);
        assert!(stats.latency_percentile_us(50.0) > 0.0);
    }

    #[test]
    fn latency_window_is_bounded() {
        let mut w = LatencyWindow::with_capacity(8);
        for i in 0..100 {
            w.push(i as f64);
        }
        assert_eq!(w.len(), 8);
        assert_eq!(w.total_recorded(), 100);
        // Only the most recent 8 samples (92..=99) remain.
        assert!(w.as_slice().iter().all(|&v| v >= 92.0));
        let p50 = w.percentile(50.0);
        assert!((92.0..=99.0).contains(&p50), "p50 {p50}");
        assert_eq!(w.percentile(100.0), 99.0);
    }

    #[test]
    fn service_latencies_stay_bounded() {
        // The stats window must not grow past its capacity no matter how
        // many requests the service handles.
        let coord = Coordinator::start(
            CoordinatorConfig { batch_delay: Duration::ZERO, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", history(5, 10));
        let n = 64;
        for _ in 0..n {
            client.plan("bwa", 4000.0);
        }
        let stats = client.stats();
        assert_eq!(stats.requests, n);
        assert_eq!(stats.latencies_us.total_recorded(), n);
        assert!(stats.latencies_us.len() <= LATENCY_WINDOW);
        assert!(stats.latency_percentile_us(99.0) > 0.0);
    }

    #[test]
    fn latency_window_merge_exact_percentiles() {
        // Merging two windows of known samples must yield the exact
        // percentiles of the union (linear interpolation over 1..=8).
        let mut a = LatencyWindow::with_capacity(8);
        for v in [1.0, 2.0, 3.0, 4.0] {
            a.push(v);
        }
        let mut b = LatencyWindow::with_capacity(8);
        for v in [5.0, 6.0, 7.0, 8.0] {
            b.push(v);
        }
        a.merge(&b);
        assert_eq!(a.len(), 8);
        assert_eq!(a.total_recorded(), 8);
        assert_eq!(a.percentile(0.0), 1.0);
        assert_eq!(a.percentile(100.0), 8.0);
        // rank 0.5 * 7 = 3.5 -> 4 + 0.5 * (5 - 4) = 4.5
        assert_eq!(a.percentile(50.0), 4.5);
        // rank 0.25 * 7 = 1.75 -> 2 + 0.75 * (3 - 2) = 2.75
        assert_eq!(a.percentile(25.0), 2.75);
    }

    #[test]
    fn latency_window_merge_preserves_order_after_wrap() {
        let mut a = LatencyWindow::with_capacity(4);
        for i in 0..6 {
            a.push(i as f64);
        }
        assert_eq!(a.chronological(), vec![2.0, 3.0, 4.0, 5.0]);
        let mut b = LatencyWindow::with_capacity(2);
        for i in 0..5 {
            b.push(10.0 + i as f64);
        }
        assert_eq!(b.chronological(), vec![13.0, 14.0]);
        a.merge(&b);
        assert_eq!(a.len(), 6);
        assert_eq!(a.total_recorded(), 11);
        assert_eq!(a.chronological(), vec![2.0, 3.0, 4.0, 5.0, 13.0, 14.0]);
        // The merged window stays a well-formed ring: more pushes rotate
        // out the oldest sample first.
        a.push(99.0);
        assert_eq!(a.chronological(), vec![3.0, 4.0, 5.0, 13.0, 14.0, 99.0]);
    }

    #[test]
    fn service_stats_merge_counters_and_mean_batch() {
        let mut a = ServiceStats::default();
        a.requests = 10;
        a.batches = 2;
        a.failures_handled = 1;
        a.tasks_trained = 3;
        a.observations = 5;
        a.fallbacks = 2;
        a.latencies_us.push(100.0);
        let mut b = ServiceStats::default();
        b.requests = 30;
        b.batches = 8;
        b.tasks_trained = 1;
        b.observations = 7;
        b.fallbacks = 4;
        b.latencies_us.push(300.0);
        let m = ServiceStats::merged(&[a, b]);
        assert_eq!(m.requests, 40);
        assert_eq!(m.batches, 10);
        assert_eq!(m.failures_handled, 1);
        assert_eq!(m.tasks_trained, 4);
        assert_eq!(m.observations, 12);
        assert_eq!(m.fallbacks, 6);
        // Mean batch size comes from the merged counters, not an average
        // of per-shard means: (10 + 30) / (2 + 8).
        assert_eq!(m.mean_batch_size(), 4.0);
        assert_eq!(m.latencies_us.len(), 2);
        assert_eq!(m.latency_percentile_us(50.0), 200.0);
    }

    #[test]
    fn prop_shard_routing_deterministic_and_total() {
        run_prop("shard_routing", 50, |rng| {
            let shards = 1 + rng.below(8);
            // Deterministic: the same name always lands on the same shard.
            for _ in 0..32 {
                let len = 1 + rng.below(12);
                let name: String =
                    (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
                let s = shard_for(&name, shards);
                assert!(s < shards);
                assert_eq!(s, shard_for(&name, shards));
            }
            // Total: distinct names reach every shard (256 >= 64 names).
            let mut hit = vec![false; shards];
            for i in 0..256 {
                let name = format!("task-{}-{i}", rng.next_u64());
                hit[shard_for(&name, shards)] = true;
            }
            assert!(hit.iter().all(|&h| h), "unreachable shard among {shards}");
        });
    }

    #[test]
    fn trained_task_never_gets_fallback_on_any_shard() {
        // Because train and plan route by the same hash, a plan after a
        // train on the same task must always find the model — for every
        // task name, whichever shard it hashes to.
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 4, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        for i in 0..64u64 {
            let task = format!("task-{i}");
            let before = client.plan(&task, 5000.0);
            assert_eq!(before.k(), 1, "untrained task must get the flat fallback");
            client.train(&task, history(100 + i, 12));
            // Plan through a *clone* of the client: routing must agree
            // across client handles, not just within one.
            let after = client.clone().plan(&task, 5000.0);
            assert!(
                !(after.starts == before.starts && after.peaks == before.peaks),
                "{task} still served the untrained fallback after train()"
            );
        }
        let stats = client.stats();
        assert_eq!(stats.tasks_trained, 64);
        assert_eq!(stats.requests, 128);
    }

    #[test]
    fn observe_stream_matches_scratch_retrained_predictor() {
        // Satellite: interleaved observe/plan on the live coordinator
        // must match a KsPlus predictor retrained from scratch on the
        // same prefix, within 1e-9.
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let hist = history(11, 24);
        for (i, e) in hist.iter().enumerate() {
            let n = client.observe("bwa", e.clone());
            assert_eq!(n, i as u64 + 1);
            let got = client.plan("bwa", 6000.0);
            let mut scratch = KsPlus::new(2, 128.0);
            scratch.train(&hist[..=i]);
            let want = scratch.plan(6000.0);
            assert_eq!(got.k(), want.k(), "after {} observations", i + 1);
            for j in 0..got.k() {
                assert!((got.starts[j] - want.starts[j]).abs() < 1e-9, "{got:?} vs {want:?}");
                assert!((got.peaks[j] - want.peaks[j]).abs() < 1e-9, "{got:?} vs {want:?}");
            }
        }
        let stats = client.stats();
        assert_eq!(stats.observations, 24);
        assert_eq!(stats.requests, 24);
        assert_eq!(stats.tasks_trained, 0);
    }

    #[test]
    fn observe_routes_to_the_training_shard() {
        // Observe must land on the shard that owns the task's models —
        // for every task name, whichever shard it hashes to.
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 4, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        for i in 0..32u64 {
            let task = format!("task-{i}");
            let before = client.plan(&task, 5000.0);
            assert_eq!(before.k(), 1, "unobserved task must get the flat fallback");
            for e in history(300 + i, 6) {
                client.observe(&task, e);
            }
            let after = client.clone().plan(&task, 5000.0);
            assert!(
                !(after.starts == before.starts && after.peaks == before.peaks),
                "{task} still served the untrained fallback after observe()"
            );
        }
        let stats = client.stats();
        assert_eq!(stats.observations, 32 * 6);
        // Observations spread over multiple shards like training does.
        let per = client.shard_stats();
        assert!(per.iter().filter(|s| s.observations > 0).count() > 1, "{per:?}");
    }

    #[test]
    fn per_task_policies_route_plans_observes_and_failures() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 4, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.configure(Some("ks-task"), PredictorPolicy::KsPlus);
        client.configure(Some("wt-task"), PredictorPolicy::WittLr);
        client.train("ks-task", history(41, 15));
        client.train("wt-task", history(42, 15));
        let ks = client.plan_detailed("ks-task", 5000.0);
        assert_eq!(ks.predictor, "ksplus");
        assert_eq!(ks.model_version, 15);
        assert_eq!(ks.fallback_reason, None);
        assert!(ks.plan.k() >= 1);
        let wt = client.plan_detailed("wt-task", 5000.0);
        assert_eq!(wt.predictor, "witt-lr");
        assert_eq!(wt.model_version, 15);
        assert_eq!(wt.plan.k(), 1, "witt serves flat peak plans");
        // Observe provenance follows the binding.
        let mut rng = Rng::new(43);
        let (n, p) = client.observe_detailed("wt-task", two_phase_exec(4000.0, &mut rng));
        assert_eq!((n, p), (16, "witt-lr"));
        let (n, p) = client.observe_detailed("ks-task", two_phase_exec(4000.0, &mut rng));
        assert_eq!((n, p), (16, "ksplus"));
        // Failure retries run the bound policy's strategy on the owning
        // shard.
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let r = client.report_failure_for(Some("wt-task"), &prev, 60.0);
        assert_eq!(r.predictor, "witt-lr");
        assert_eq!(r.plan, StepPlan::flat(16.0));
        let r = client.report_failure_for(Some("ks-task"), &prev, 60.0);
        assert_eq!(r.predictor, "ksplus");
        assert_eq!(r.plan.starts, vec![0.0, 60.0]);
        assert_eq!(client.stats().failures_handled, 2);
    }

    #[test]
    fn service_default_policy_fans_out_to_every_shard() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 3, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.configure(None, PredictorPolicy::TovarPpm);
        // Whatever shard each task hashes to, training now lands on the
        // tovar policy.
        for i in 0..12u64 {
            let task = format!("task-{i}");
            client.train(&task, history(500 + i, 10));
            let out = client.plan_detailed(&task, 4000.0);
            assert_eq!(out.predictor, "tovar-ppm", "{task}");
            assert_eq!(out.plan.k(), 1);
        }
    }

    #[test]
    fn fallbacks_counted_and_merged_across_shards() {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards: 4, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("trained", history(51, 10));
        // 6 untrained plans spread across shards + 2 trained plans.
        for i in 0..6u64 {
            let out = client.plan_detailed(&format!("mystery-{i}"), 100.0);
            assert_eq!(out.fallback_reason, Some(crate::coordinator::FALLBACK_UNTRAINED));
            assert_eq!(out.predictor, "default-limits");
            assert_eq!(out.model_version, 0);
        }
        client.plan("trained", 4000.0);
        client.plan("trained", 8000.0);
        let stats = client.stats();
        assert_eq!(stats.requests, 8);
        assert_eq!(stats.fallbacks, 6);
        // The merge is the sum of the per-shard counters.
        let per = client.shard_stats();
        assert_eq!(per.iter().map(|s| s.fallbacks).sum::<u64>(), 6);
    }

    #[test]
    fn stats_fan_out_and_merge_across_shards() {
        let shards = 3;
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        assert_eq!(client.shards(), shards);
        let n_tasks = 12u64;
        for i in 0..n_tasks {
            let task = format!("task-{i}");
            client.train(&task, history(200 + i, 10));
            client.plan(&task, 4000.0);
            client.plan(&task, 8000.0);
        }
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        client.report_failure(&prev, 60.0);
        let per = client.shard_stats();
        assert_eq!(per.len(), shards);
        let merged = client.stats();
        assert_eq!(merged.requests, 2 * n_tasks);
        assert_eq!(merged.tasks_trained, n_tasks);
        assert_eq!(merged.failures_handled, 1);
        // The aggregate is exactly the sum of the per-shard views.
        assert_eq!(per.iter().map(|s| s.requests).sum::<u64>(), merged.requests);
        assert_eq!(per.iter().map(|s| s.tasks_trained).sum::<u64>(), merged.tasks_trained);
        assert_eq!(
            per.iter().map(|s| s.latencies_us.len()).sum::<usize>(),
            merged.latencies_us.len()
        );
        // With 12 distinct tasks over 3 shards, more than one shard must
        // have seen traffic (FNV spreads these names).
        assert!(per.iter().filter(|s| s.requests > 0).count() > 1);
    }

    #[test]
    fn per_shard_batchers_run_independently() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                k: 2,
                batch_max: 16,
                batch_delay: Duration::from_millis(4),
                shards: 2,
                ..Default::default()
            },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let (t0, t1) = two_tasks_on_distinct_shards(2);
        client.train(&t0, history(2, 20));
        client.train(&t1, history(3, 20));
        let mut handles = Vec::new();
        for i in 0..32usize {
            let c = coord.client();
            let task = if i % 2 == 0 { t0.clone() } else { t1.clone() };
            handles.push(std::thread::spawn(move || {
                c.plan(&task, 3000.0 + i as f64 * 100.0)
            }));
        }
        let plans: Vec<StepPlan> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(plans.iter().all(|p| p.is_valid()));
        let per = client.shard_stats();
        assert_eq!(per.len(), 2);
        // Both shards saw their half of the traffic and batched it
        // themselves.
        assert!(per.iter().all(|s| s.requests == 16), "{per:?}");
        assert_eq!(client.stats().requests, 32);
    }

    #[test]
    fn failure_round_robin_spreads_across_shards() {
        let shards = 4;
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, shards, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        for _ in 0..shards * 3 {
            let retry = client.report_failure(&prev, 60.0);
            assert!(retry.is_valid());
        }
        let per = client.shard_stats();
        assert!(per.iter().all(|s| s.failures_handled == 3), "{per:?}");
    }

    #[test]
    fn zero_shards_is_a_startup_error() {
        let err = Coordinator::start(
            CoordinatorConfig { shards: 0, ..Default::default() },
            BackendSpec::Native,
        )
        .err()
        .expect("zero shards must not start");
        assert!(format!("{err:#}").contains("shard"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_start_errors_instead_of_panicking_worker() {
        // The startup seam: a backend that cannot be built in this binary
        // must surface as Err from start(), not as a detached worker
        // thread panic that clients discover via "coordinator gone".
        for shards in [1, 4] {
            let err = Coordinator::start(
                CoordinatorConfig { shards, ..Default::default() },
                BackendSpec::Pjrt(None),
            )
            .err()
            .expect("pjrt spec must not start in a native-only build");
            let msg = format!("{err:#}");
            assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_end_to_end() {
        // The production path: coordinator worker owns a PJRT runtime
        // built from the AOT artifacts; plans must match the native
        // backend to f32 precision.
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let hist = history(7, 25);
        let cfg = CoordinatorConfig { k: 3, ..Default::default() };
        let pjrt = Coordinator::start(cfg.clone(), BackendSpec::Pjrt(Some(dir))).unwrap();
        let native = Coordinator::start(cfg, BackendSpec::Native).unwrap();
        pjrt.client().train("bwa", hist.clone());
        native.client().train("bwa", hist);
        for input in [2500.0, 6000.0, 11000.0] {
            let a = pjrt.client().plan("bwa", input);
            let b = native.client().plan("bwa", input);
            assert_eq!(a.k(), b.k(), "{a:?} vs {b:?}");
            for i in 0..a.k() {
                assert!((a.starts[i] - b.starts[i]).abs() < 0.5, "{a:?} vs {b:?}");
                assert!((a.peaks[i] - b.peaks[i]).abs() < 0.05, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn shutdown_flushes_cleanly() {
        let coord = Coordinator::start(
            CoordinatorConfig { shards: 3, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        client.train("bwa", history(4, 10));
        drop(coord); // must not hang or panic, across all shards
        // Client calls after shutdown fail loudly (panic) — we only
        // check drop-order safety here.
        let _ = client;
    }
}
