//! Scenario replay engine: streams a [`ScenarioSpec`] through the
//! OOM/retry simulator under each serving policy and aggregates the
//! per-(scenario × policy) wastage/failure/retry matrix behind
//! `repro scenarios --matrix`.
//!
//! Per policy the engine recreates the *identical* stream (a pure
//! function of the spec), so the matrix is a paired comparison: every
//! policy faces exactly the same million perturbed executions. Online
//! retraining is part of the replay — each task keeps a sliding window of
//! its observed executions and refits on a fixed occurrence schedule, so
//! drift scenarios show the degrade-then-recover shape instead of a
//! permanently broken model. The schedule depends only on the stream,
//! never on plan quality, which keeps the pairing exact.
//!
//! Everything here is deterministic: `Matrix::fingerprint` (FNV-1a over
//! the full-precision row text) is pinned by tests and printed by the
//! CLI, so "same spec, same table" is checkable at a glance.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::stream::ScenarioStream;
use super::ScenarioSpec;
use crate::experiments::{report, trained_predictor};
use crate::metrics::TaskOutcome;
use crate::predictor::Predictor;
use crate::sim;
use crate::sim::cluster::{ClusterConfig, PredictorSource};
use crate::sim::dag::{run_workflow_dag, DagResult};
use crate::trace::workflow::Workflow;
use crate::trace::{Execution, TaskTraces, WorkflowTrace};
use crate::util::fnv1a;
use crate::util::json::Json;

/// Serving policy → offline predictor method, in matrix column order.
/// The names are the coordinator's `PredictorPolicy` wire names; the
/// methods are `predictor::by_name` report names.
pub const POLICY_METHODS: [(&str, &str); 5] = [
    ("ksplus", "ksplus"),
    ("witt-lr", "witt-lr-mean"),
    ("tovar-ppm", "tovar-ppm"),
    ("ksegments", "ksegments-selective"),
    ("default-limits", "default"),
];

/// Executions per (scenario, policy) cell in full mode: 6 scenarios x
/// 5 policies x 40k = 1.2 M replayed task executions per matrix run.
pub const FULL_N: usize = 40_000;
/// Reduced cell size for `--quick` (CI smoke).
pub const QUICK_N: usize = 400;

pub fn method_for_policy(policy: &str) -> Option<&'static str> {
    POLICY_METHODS.iter().find(|(p, _)| *p == policy).map(|(_, m)| *m)
}

pub fn default_policies() -> Vec<&'static str> {
    POLICY_METHODS.iter().map(|(p, _)| *p).collect()
}

/// One (scenario × policy) cell of the matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixRow {
    pub scenario: String,
    pub policy: String,
    pub instances: usize,
    /// Failed attempts (OOM kills) across all instances.
    pub failures: usize,
    /// Instances that exhausted the retry budget.
    pub unfinished: usize,
    pub wastage_gbs: f64,
    pub alloc_gbs: f64,
    pub used_gbs: f64,
}

impl MatrixRow {
    fn new(scenario: String, policy: String) -> MatrixRow {
        MatrixRow {
            scenario,
            policy,
            instances: 0,
            failures: 0,
            unfinished: 0,
            wastage_gbs: 0.0,
            alloc_gbs: 0.0,
            used_gbs: 0.0,
        }
    }

    fn add(&mut self, o: &TaskOutcome) {
        self.instances += 1;
        self.failures += o.attempts - 1;
        if !o.success {
            self.unfinished += 1;
        }
        self.wastage_gbs += o.wastage_gbs;
        self.alloc_gbs += o.alloc_gbs;
        self.used_gbs += o.used_gbs;
    }

    pub fn failure_rate(&self) -> f64 {
        self.failures as f64 / self.instances.max(1) as f64
    }

    pub fn unfinished_rate(&self) -> f64 {
        self.unfinished as f64 / self.instances.max(1) as f64
    }

    pub fn wastage_per_task(&self) -> f64 {
        self.wastage_gbs / self.instances.max(1) as f64
    }

    pub fn efficiency(&self) -> f64 {
        if self.alloc_gbs <= 0.0 {
            0.0
        } else {
            self.used_gbs / self.alloc_gbs
        }
    }

    /// Full-precision row rendering ({:?} floats), the fingerprint input.
    pub fn row_text(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}|{:?}|{:?}|{:?}",
            self.scenario,
            self.policy,
            self.instances,
            self.failures,
            self.unfinished,
            self.wastage_gbs,
            self.alloc_gbs,
            self.used_gbs
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", self.scenario.as_str().into()),
            ("policy", self.policy.as_str().into()),
            ("instances", self.instances.into()),
            ("failures", self.failures.into()),
            ("unfinished", self.unfinished.into()),
            ("wastage_gbs", self.wastage_gbs.into()),
            ("alloc_gbs", self.alloc_gbs.into()),
            ("used_gbs", self.used_gbs.into()),
            ("failure_rate", self.failure_rate().into()),
            ("unfinished_rate", self.unfinished_rate().into()),
        ])
    }
}

/// Sliding window of the most recent executions of one task, backing the
/// online refits. Seeded from the training tail so the first refit never
/// trains on a near-empty window; thereafter the oldest slot is
/// overwritten in place (`Execution::copy_from`, no reallocation).
struct Ring {
    buf: Vec<Execution>,
    cap: usize,
    next: usize,
    /// Streamed executions pushed (excludes the training seed).
    seen: usize,
}

impl Ring {
    fn new(cap: usize, seed: &[Execution]) -> Ring {
        let tail = seed.len().saturating_sub(cap);
        Ring { buf: seed[tail..].to_vec(), cap, next: 0, seen: 0 }
    }

    fn push(&mut self, e: &Execution) {
        if self.buf.len() < self.cap {
            self.buf.push(e.clone());
        } else {
            self.buf[self.next].copy_from(e);
            self.next = (self.next + 1) % self.cap;
        }
        self.seen += 1;
    }

    fn contents(&self) -> &[Execution] {
        &self.buf
    }
}

/// Replay one scenario under one policy. `on_outcome` (stream index,
/// outcome) observes every simulated instance — the drift tests use it to
/// window failure rates over time.
pub fn run_scenario(
    spec: &ScenarioSpec,
    policy: &str,
    mut on_outcome: Option<&mut dyn FnMut(usize, &TaskOutcome)>,
) -> Result<MatrixRow> {
    let Some(method) = method_for_policy(policy) else {
        bail!(
            "unknown policy '{policy}' (valid: {})",
            default_policies().join(", ")
        );
    };
    let mut stream = ScenarioStream::new(spec)?;
    // The workflow only supplies per-task developer limits for the
    // `default` method; trace tasks it does not know get a data-driven
    // limit from their training history instead.
    let wf = Workflow::by_name(&spec.workflow).unwrap_or_else(Workflow::eager);
    let mut models: BTreeMap<String, (Box<dyn Predictor>, Ring)> = BTreeMap::new();
    for tt in stream.training() {
        let pred =
            trained_predictor(method, spec.k, spec.capacity_gb, &wf, &tt.task, &tt.executions)?;
        models.insert(tt.task.clone(), (pred, Ring::new(spec.window, &tt.executions)));
    }

    let mut row = MatrixRow::new(spec.name.clone(), policy.to_string());
    let mut scratch = Execution::new("", 0.0, 0.0, Vec::new());
    for i in 0..spec.n {
        stream.fill_next(&mut scratch);
        let Some((pred, ring)) = models.get_mut(&scratch.task) else {
            bail!("stream produced task '{}' with no trained model", scratch.task);
        };
        let o = sim::run_task_outcome(pred.as_ref(), &scratch, sim::MAX_RETRIES);
        row.add(&o);
        if let Some(cb) = on_outcome.as_deref_mut() {
            cb(i, &o);
        }
        if spec.retrain_every > 0 {
            // The model observes what actually ran — including the
            // perturbation — on a schedule that depends only on the
            // stream, never on plan quality (keeps policies paired).
            ring.push(&scratch);
            if ring.seen % spec.retrain_every == 0 {
                pred.train(ring.contents());
            }
        }
    }
    Ok(row)
}

/// The full wastage matrix: one row per (scenario × policy).
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: Vec<MatrixRow>,
    pub total_replayed: usize,
}

impl Matrix {
    /// FNV-1a over the full-precision row text: two runs of the same
    /// seeded specs must print the same 16-hex-digit fingerprint.
    pub fn fingerprint(&self) -> u64 {
        let text: String =
            self.rows.iter().map(|r| r.row_text() + "\n").collect();
        fnv1a(&text)
    }

    pub fn render(&self, title: &str) -> String {
        let mut t = report::Table::new(&[
            "scenario",
            "policy",
            "tasks",
            "failures",
            "fail/task",
            "unfinished",
            "wastage-gbs",
            "waste/task",
            "efficiency",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.scenario.clone(),
                r.policy.clone(),
                r.instances.to_string(),
                r.failures.to_string(),
                report::f(r.failure_rate()),
                r.unfinished.to_string(),
                report::f(r.wastage_gbs),
                report::f(r.wastage_per_task()),
                report::f(r.efficiency()),
            ]);
        }
        let mut out = t.render(title);
        out.push_str(&format!(
            "replayed {} task executions; fingerprint {:016x}\n",
            self.total_replayed,
            self.fingerprint()
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rows", Json::Arr(self.rows.iter().map(MatrixRow::to_json).collect())),
            ("total_replayed", self.total_replayed.into()),
            ("fingerprint", format!("{:016x}", self.fingerprint()).into()),
        ])
    }
}

/// Replay every (scenario, policy) pair. Row order is specs-major, so
/// the table groups a scenario's five policies together.
pub fn run_matrix(specs: &[ScenarioSpec], policies: &[&str]) -> Result<Matrix> {
    let mut rows = Vec::with_capacity(specs.len() * policies.len());
    let mut total = 0usize;
    for spec in specs {
        for policy in policies {
            let row = run_scenario(spec, policy, None)
                .with_context(|| format!("scenario '{}' policy '{policy}'", spec.name))?;
            total += row.instances;
            rows.push(row);
        }
    }
    Ok(Matrix { rows, total_replayed: total })
}

/// Write the matrix (and optional figure reproductions) into the
/// machine-readable `BENCH_scenarios.json`. Merges into an existing
/// schema-compatible document instead of clobbering: a full-mode matrix
/// and a later `--figs` run land in the same file, and each `--figs` key
/// only replaces its own slot.
pub fn write_bench_json(
    path: &Path,
    matrix: &Matrix,
    figures: Vec<(String, Json)>,
) -> Result<()> {
    const SCHEMA: &str = "ksplus-bench-scenarios/v1";
    let mut doc = match std::fs::read_to_string(path).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(existing) if existing.get("schema").and_then(Json::as_str) == Some(SCHEMA) => {
            existing
        }
        _ => Json::obj(vec![("schema", SCHEMA.into())]),
    };
    if let Json::Obj(map) = &mut doc {
        map.insert("source".to_string(), "repro-scenarios".into());
        map.insert(
            "matrix".to_string(),
            Json::Arr(matrix.rows.iter().map(MatrixRow::to_json).collect()),
        );
        map.insert("total_replayed".to_string(), matrix.total_replayed.into());
        map.insert(
            "fingerprint".to_string(),
            format!("{:016x}", matrix.fingerprint()).into(),
        );
        if !figures.is_empty() {
            let figs = map.entry("figures".to_string()).or_insert_with(|| Json::obj(vec![]));
            if !matches!(figs, Json::Obj(_)) {
                *figs = Json::obj(vec![]);
            }
            if let Json::Obj(slots) = figs {
                for (key, value) in figures {
                    slots.insert(key, value);
                }
            }
        }
    }
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Regression gates for the CI smoke matrix: per-row caps on failure and
/// unfinished rates. Override keys are `scenario/policy`, with
/// `scenario/*` as a scenario-wide wildcard; everything else uses the
/// defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct Thresholds {
    pub max_failure_rate: f64,
    pub max_unfinished_rate: f64,
    pub failure_overrides: BTreeMap<String, f64>,
    pub unfinished_overrides: BTreeMap<String, f64>,
}

impl Thresholds {
    pub fn load(path: &Path) -> Result<Thresholds> {
        const SCHEMA: &str = "ksplus-scenario-thresholds/v1";
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        if doc.get("schema").and_then(Json::as_str) != Some(SCHEMA) {
            bail!("{} is not a {SCHEMA} document", path.display());
        }
        let field = |key: &str| -> Result<f64> {
            doc.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("{}: missing number '{key}'", path.display()))
        };
        let overrides = |key: &str| -> Result<BTreeMap<String, f64>> {
            let mut out = BTreeMap::new();
            if let Some(Json::Obj(map)) = doc.get(key) {
                for (k, v) in map {
                    let Some(x) = v.as_f64() else {
                        bail!("{}: {key}.{k} is not a number", path.display());
                    };
                    out.insert(k.clone(), x);
                }
            }
            Ok(out)
        };
        Ok(Thresholds {
            max_failure_rate: field("max_failure_rate")?,
            max_unfinished_rate: field("max_unfinished_rate")?,
            failure_overrides: overrides("failure_overrides")?,
            unfinished_overrides: overrides("unfinished_overrides")?,
        })
    }

    fn cap(map: &BTreeMap<String, f64>, row: &MatrixRow, default: f64) -> f64 {
        map.get(&format!("{}/{}", row.scenario, row.policy))
            .or_else(|| map.get(&format!("{}/*", row.scenario)))
            .copied()
            .unwrap_or(default)
    }

    /// Every violated cap, as human-readable lines; empty == pass.
    pub fn check(&self, matrix: &Matrix) -> Vec<String> {
        let mut violations = Vec::new();
        for r in &matrix.rows {
            let fmax = Self::cap(&self.failure_overrides, r, self.max_failure_rate);
            if r.failure_rate() > fmax {
                violations.push(format!(
                    "{}/{}: failure rate {:.3} exceeds cap {:.3}",
                    r.scenario,
                    r.policy,
                    r.failure_rate(),
                    fmax
                ));
            }
            let umax = Self::cap(&self.unfinished_overrides, r, self.max_unfinished_rate);
            if r.unfinished_rate() > umax {
                violations.push(format!(
                    "{}/{}: unfinished rate {:.3} exceeds cap {:.3}",
                    r.scenario,
                    r.policy,
                    r.unfinished_rate(),
                    umax
                ));
            }
        }
        violations
    }
}

/// Replay a bounded slice of the scenario stream through the DAG-aware
/// cluster scheduler (`--dag`): stragglers and storms become stage
/// makespans, not just wastage. Synthetic sources only — an ingested CSV
/// carries no DAG. Bounded because the DAG path materialises its trace.
pub fn run_scenario_dag(
    spec: &ScenarioSpec,
    policy: &str,
    cluster: &ClusterConfig,
    limit: usize,
) -> Result<DagResult> {
    if spec.trace.is_some() {
        bail!("scenario DAG replay needs a synthetic workflow (a trace CSV carries no DAG)");
    }
    let Some(method) = method_for_policy(policy) else {
        bail!(
            "unknown policy '{policy}' (valid: {})",
            default_policies().join(", ")
        );
    };
    let Some(wf) = Workflow::by_name(&spec.workflow) else {
        bail!("unknown workflow '{}'", spec.workflow);
    };
    let mut stream = ScenarioStream::new(spec)?;
    struct Src(BTreeMap<String, Box<dyn Predictor>>);
    impl PredictorSource for Src {
        fn get(&self, task: &str) -> Option<&dyn Predictor> {
            self.0.get(task).map(|p| p.as_ref())
        }
    }
    let mut preds = Src(BTreeMap::new());
    let mut trace =
        WorkflowTrace { name: format!("scenario-{}", spec.name), tasks: Vec::new() };
    for tt in stream.training() {
        preds.0.insert(
            tt.task.clone(),
            trained_predictor(method, spec.k, spec.capacity_gb, &wf, &tt.task, &tt.executions)?,
        );
        trace.tasks.push(TaskTraces { task: tt.task.clone(), executions: Vec::new() });
    }
    let n = limit.min(spec.n).max(1);
    let mut scratch = Execution::new("", 0.0, 0.0, Vec::new());
    for _ in 0..n {
        stream.fill_next(&mut scratch);
        if let Some(t) = trace.tasks.iter_mut().find(|t| t.task == scratch.task) {
            t.executions.push(scratch.clone());
        }
    }
    Ok(run_workflow_dag(cluster, &wf, &trace, &preds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::presets;

    const GOLDEN_CSV: &str =
        concat!(env!("CARGO_MANIFEST_DIR"), "/../golden/traces/nfcore_rnaseq_sample.csv");

    #[test]
    fn matrix_is_bit_identical_across_runs() {
        let specs: Vec<ScenarioSpec> = presets()
            .into_iter()
            .map(|s| ScenarioSpec { n: 60, train_per_task: 12, ..s })
            .collect();
        let policies = ["ksplus", "default-limits"];
        let a = run_matrix(&specs, &policies).unwrap();
        let b = run_matrix(&specs, &policies).unwrap();
        assert_eq!(a.rows, b.rows, "matrix rows not bit-identical");
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.total_replayed, 6 * 2 * 60);
        // A different seed moves the fingerprint.
        let reseeded: Vec<ScenarioSpec> =
            specs.iter().map(|s| ScenarioSpec { seed: s.seed + 1, ..s.clone() }).collect();
        let c = run_matrix(&reseeded, &policies).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn matrix_covers_all_policies_and_renders() {
        let spec = ScenarioSpec::parse("name=baseline,n=40,train-per-task=10").unwrap();
        let policies = default_policies();
        let m = run_matrix(&[spec], &policies).unwrap();
        assert_eq!(m.rows.len(), 5);
        let text = m.render("scenario matrix (test)");
        for p in &policies {
            assert!(text.contains(p), "rendered table missing policy {p}");
        }
        assert!(text.contains("fingerprint"));
        let j = m.to_json();
        assert_eq!(j.get("rows").and_then(Json::as_arr).map(|a| a.len()), Some(5));
        assert_eq!(j.get("total_replayed").and_then(Json::as_usize), Some(200));
    }

    #[test]
    fn unknown_policy_errors() {
        let spec = ScenarioSpec::parse("name=baseline,n=10").unwrap();
        assert!(run_scenario(&spec, "nope", None).is_err());
    }

    #[test]
    fn trace_spec_replays_through_the_matrix() {
        for policy in ["ksplus", "default-limits"] {
            let spec = ScenarioSpec::parse(&format!(
                "name=heavy-tail,n=50,trace={GOLDEN_CSV}"
            ))
            .unwrap();
            let row = run_scenario(&spec, policy, None).unwrap();
            assert_eq!(row.instances, 50, "{policy}");
            assert!(row.used_gbs > 0.0, "{policy}");
            // Bit-identical on a second run, trace source included.
            let again = run_scenario(&spec, policy, None).unwrap();
            assert_eq!(row, again, "{policy}");
        }
    }

    #[test]
    fn drift_degrades_then_recovers() {
        // KS+ with online refits: failures per task must jump right after
        // the concept shift and come back down once the sliding window is
        // dominated by post-drift executions.
        let spec = ScenarioSpec::parse(
            "name=drift,n=2700,at=0.45,factor=2.0,retrain-every=16,window=96,seed=5",
        )
        .unwrap();
        let (mut pre, mut mid, mut late) = (0usize, 0usize, 0usize);
        let mut cb = |i: usize, o: &TaskOutcome| {
            let f = o.attempts - 1;
            match i {
                810..=1214 => pre += f,
                1215..=1619 => mid += f,
                2295..=2699 => late += f,
                _ => {}
            }
        };
        let row = run_scenario(&spec, "ksplus", Some(&mut cb)).unwrap();
        assert_eq!(row.instances, 2700);
        let w = 405.0;
        let (pre, mid, late) = (pre as f64 / w, mid as f64 / w, late as f64 / w);
        assert!(
            mid > pre + 0.2,
            "drift did not degrade failures: pre {pre:.3}/task, mid {mid:.3}/task"
        );
        assert!(
            late < mid * 0.75,
            "model did not recover after retraining: mid {mid:.3}/task, late {late:.3}/task"
        );
    }

    #[test]
    fn retraining_off_means_no_recovery_schedule() {
        // retrain-every=0 runs the same stream with frozen models; the
        // run must still complete and stay deterministic.
        let spec =
            ScenarioSpec::parse("name=drift,n=300,retrain-every=0,train-per-task=12").unwrap();
        let a = run_scenario(&spec, "ksplus", None).unwrap();
        let b = run_scenario(&spec, "ksplus", None).unwrap();
        assert_eq!(a, b);
        assert!(a.failures > 0, "a frozen model should be failing post-drift");
    }

    #[test]
    fn stragglers_stretch_dag_makespan() {
        let cluster = ClusterConfig { nodes: 2, node_capacity_gb: 128.0 };
        let base = ScenarioSpec::parse("name=baseline,n=400,train-per-task=12,seed=8").unwrap();
        let slow = ScenarioSpec::parse(
            "name=stragglers,n=400,prob=0.3,slow=4.0,train-per-task=12,seed=8",
        )
        .unwrap();
        let b = run_scenario_dag(&base, "ksplus", &cluster, 180).unwrap();
        let s = run_scenario_dag(&slow, "ksplus", &cluster, 180).unwrap();
        assert!(
            s.makespan_s > b.makespan_s * 1.2,
            "stragglers {:.1}s vs baseline {:.1}s",
            s.makespan_s,
            b.makespan_s
        );
        assert!(!s.stages.is_empty());
    }

    #[test]
    fn dag_replay_rejects_trace_sources() {
        let spec =
            ScenarioSpec::parse(&format!("name=baseline,trace={GOLDEN_CSV}")).unwrap();
        let cluster = ClusterConfig { nodes: 2, node_capacity_gb: 128.0 };
        assert!(run_scenario_dag(&spec, "ksplus", &cluster, 50).is_err());
    }

    fn row(scenario: &str, policy: &str, failures: usize, unfinished: usize) -> MatrixRow {
        MatrixRow {
            scenario: scenario.into(),
            policy: policy.into(),
            instances: 100,
            failures,
            unfinished,
            wastage_gbs: 10.0,
            alloc_gbs: 100.0,
            used_gbs: 50.0,
        }
    }

    #[test]
    fn thresholds_cap_lookup_and_check() {
        let mut t = Thresholds {
            max_failure_rate: 0.5,
            max_unfinished_rate: 0.02,
            failure_overrides: BTreeMap::new(),
            unfinished_overrides: BTreeMap::new(),
        };
        t.failure_overrides.insert("drift/*".into(), 3.0);
        t.failure_overrides.insert("drift/ksplus".into(), 1.0);
        let m = Matrix {
            rows: vec![
                row("baseline", "ksplus", 10, 0),    // 0.1 <= 0.5: ok
                row("baseline", "tovar-ppm", 80, 0), // 0.8 > 0.5: violation
                row("drift", "ksplus", 150, 0),      // 1.5 > 1.0 (exact key)
                row("drift", "witt-lr", 150, 0),     // 1.5 <= 3.0 (wildcard)
                row("heavy-tail", "ksplus", 0, 5),   // 0.05 > 0.02 unfinished
            ],
            total_replayed: 500,
        };
        let v = t.check(&m);
        assert_eq!(v.len(), 3, "{v:?}");
        assert!(v[0].contains("baseline/tovar-ppm"), "{v:?}");
        assert!(v[1].contains("drift/ksplus"), "{v:?}");
        assert!(v[2].contains("heavy-tail/ksplus"), "{v:?}");
    }

    #[test]
    fn thresholds_load_parses_and_rejects_bad_schema() {
        let dir = std::env::temp_dir();
        let good = dir.join(format!("ksplus_thresh_{}.json", std::process::id()));
        std::fs::write(
            &good,
            r#"{"schema":"ksplus-scenario-thresholds/v1","max_failure_rate":2.0,
                "max_unfinished_rate":0.02,
                "failure_overrides":{"drift/*":6.0},
                "unfinished_overrides":{"heavy-tail/*":0.05}}"#,
        )
        .unwrap();
        let t = Thresholds::load(&good).unwrap();
        std::fs::remove_file(&good).ok();
        assert!((t.max_failure_rate - 2.0).abs() < 1e-12);
        assert_eq!(t.failure_overrides.get("drift/*"), Some(&6.0));
        assert_eq!(t.unfinished_overrides.get("heavy-tail/*"), Some(&0.05));

        let bad = dir.join(format!("ksplus_thresh_bad_{}.json", std::process::id()));
        std::fs::write(&bad, r#"{"schema":"something-else/v1","max_failure_rate":2.0}"#)
            .unwrap();
        assert!(Thresholds::load(&bad).is_err());
        std::fs::remove_file(&bad).ok();
        assert!(Thresholds::load(Path::new("/nonexistent/t.json")).is_err());
    }

    #[test]
    fn committed_thresholds_file_loads() {
        let t = Thresholds::load(Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../golden/scenarios/thresholds.json"
        )))
        .unwrap();
        assert!(t.max_failure_rate > 0.0);
        assert!(t.max_unfinished_rate > 0.0);
    }

    #[test]
    fn bench_json_merges_matrix_and_figures() {
        let spec = ScenarioSpec::parse("name=baseline,n=30,train-per-task=10").unwrap();
        let m = run_matrix(&[spec], &["ksplus"]).unwrap();
        let path = std::env::temp_dir()
            .join(format!("ksplus_bench_scenarios_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        write_bench_json(&path, &m, vec![]).unwrap();
        // Second write adds a figure slot without clobbering the matrix.
        write_bench_json(
            &path,
            &m,
            vec![("fig6".to_string(), Json::obj(vec![("ok", 1.0.into())]))],
        )
        .unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(
            back.get("schema").and_then(Json::as_str),
            Some("ksplus-bench-scenarios/v1")
        );
        assert_eq!(back.get("matrix").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        assert_eq!(back.get("total_replayed").and_then(Json::as_usize), Some(30));
        assert!(back.get("figures").and_then(|f| f.get("fig6")).is_some());
        assert_eq!(
            back.get("fingerprint").and_then(Json::as_str),
            Some(format!("{:016x}", m.fingerprint()).as_str())
        );
    }
}
