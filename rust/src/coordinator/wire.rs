//! The wire-codec seam: every server front end and the typed client
//! speak through [`Wire`], which selects between the two framings of
//! the coordinator protocol:
//!
//! * **v1 — JSON lines** (`Wire::V1`): one JSON object per
//!   `\n`-terminated line, exactly the bytes `protocol::Request::to_json`
//!   / `Response::to_json` have always produced. This module adds no
//!   bytes and removes none — v1 traffic is byte-for-byte what the
//!   pre-codec server emitted.
//! * **v2 — length-prefixed binary** (`Wire::V2`): each frame is a
//!   little-endian `u32` payload length followed by the payload; the
//!   payload's first byte is an op tag, the rest fixed-width
//!   little-endian fields. Floats travel as raw `f64::to_bits`, so the
//!   bit-exactness v1 gets from shortest-roundtrip formatting is
//!   structural here. The framing is specified normatively in
//!   `docs/PROTOCOL.md` ("Wire v2").
//!
//! A connection starts on v1; a `hello` with `max_version >= 2`
//! switches it to v2 for every frame after the hello response
//! (STARTTLS-style — the hello response itself still rides the wire the
//! hello arrived on). `protocol::negotiate_version` is the single
//! negotiation rule shared by every front end.
//!
//! Semantic validation is shared with the JSON parser
//! (`execution_from_parts`, `plan_from_parts`, …), so a malformed
//! request earns the identical `ErrorCode` and message on either wire.
//! Frames that cannot be decoded *structurally* (unknown tag, truncated
//! field) get v2's own `invalid-frame` — the analogue of v1's
//! `invalid-json`.

use std::io::{self, BufRead, ErrorKind, Read};

use crate::coordinator::protocol::{
    execution_from_parts, plan_from_parts, policy_from_name, validate_configure_task,
    validate_history_len, validate_reshard_shards, Dedup, ErrorCode, ObserveAck, Request,
    Response, ServerInfo, StatsSummary, WireError, OPS, PROVENANCE_UNKNOWN, WIRE_V2,
    WIRE_VERSION,
};
use crate::coordinator::{PlanOutcome, PredictorPolicy, RetryOutcome, FALLBACK_UNTRAINED};
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::json::Json;

/// The unified request-size cap both framings enforce (`repro serve
/// --max-frame-bytes`): v1 bounds the line length, v2 bounds the
/// declared frame length, and both answer `request-too-large`.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 20;

/// Hard structural ceiling on a v2 frame payload: the length header is
/// a `u32`, so anything larger cannot be framed at all. The
/// `try_encode_*` functions check against it (and the caller's cap)
/// *before* the cast inside `frame` — nothing oversized is ever
/// truncated into an undecodable stream.
pub const MAX_V2_PAYLOAD_BYTES: usize = u32::MAX as usize;

/// Error frames carry this tag instead of `0x80 | request_tag`, so a
/// pipelining client can decode an error without knowing which request
/// it answers (responses stay in request order regardless).
const TAG_ERROR: u8 = 0xFF;

/// Success responses echo the request's op tag with the high bit set.
const RESPONSE_BIT: u8 = 0x80;

/// Request op tags are `1 + index` into `protocol::OPS` — `hello` is
/// 0x01 through `reshard` 0x09. Tag 0x00 is reserved (never valid), so
/// an all-zero frame cannot masquerade as a request.
fn op_tag(op: &str) -> Option<u8> {
    OPS.iter().position(|&o| o == op).map(|i| (i + 1) as u8)
}

fn tag_op(tag: u8) -> Option<&'static str> {
    OPS.get((tag as usize).checked_sub(1)?).copied()
}

fn response_op(resp: &Response) -> &'static str {
    match resp {
        Response::Hello(_) => "hello",
        Response::Configured { .. } => "configure",
        Response::Trained { .. } => "train",
        Response::Observed(_) => "observe",
        Response::Planned(_) => "plan",
        Response::Retry(_) => "failure",
        Response::Stats(_) => "stats",
        Response::Snapshot { .. } => "snapshot",
        Response::Resharded { .. } => "reshard",
    }
}

/// One framing of the coordinator protocol. Copyable connection state:
/// the event loop, the threaded server, and `RemoteClient` each hold
/// the current `Wire` per connection and flip it after a successful
/// v2 negotiation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wire {
    /// Newline-delimited JSON (wire version 1).
    V1,
    /// Length-prefixed binary (wire version 2).
    V2,
}

impl Wire {
    pub fn version(self) -> usize {
        match self {
            Wire::V1 => WIRE_VERSION,
            Wire::V2 => WIRE_V2,
        }
    }

    pub fn from_version(v: usize) -> Option<Wire> {
        match v {
            WIRE_VERSION => Some(Wire::V1),
            WIRE_V2 => Some(Wire::V2),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Wire::V1 => "v1",
            Wire::V2 => "v2",
        }
    }

    /// CLI spelling (`--wire v1|v2`; bare version numbers accepted).
    pub fn parse(s: &str) -> Option<Wire> {
        match s {
            "v1" | "1" => Some(Wire::V1),
            "v2" | "2" => Some(Wire::V2),
            _ => None,
        }
    }

    /// Nonblocking frame splitter for the event loop: does `buf` (the
    /// front of a connection's read buffer) hold one complete frame?
    /// `Frame { consumed, from, to }` says "the payload is
    /// `buf[from..to]`; drop the first `consumed` bytes afterwards" —
    /// for v1 the payload is the line without its `\n`, for v2 the
    /// tagged payload without its length header.
    pub fn split(self, buf: &[u8], max_frame_bytes: usize) -> FrameSplit {
        match self {
            Wire::V1 => match buf.iter().position(|&b| b == b'\n') {
                // Same boundary as the bounded line reader: the line
                // *content* must fit the cap.
                Some(pos) if pos > max_frame_bytes => FrameSplit::TooLarge,
                Some(pos) => FrameSplit::Frame { consumed: pos + 1, from: 0, to: pos },
                None if buf.len() > max_frame_bytes => FrameSplit::TooLarge,
                None => FrameSplit::Incomplete,
            },
            Wire::V2 => {
                if buf.len() < 4 {
                    return FrameSplit::Incomplete;
                }
                let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
                if len > max_frame_bytes {
                    // Decided from the header alone — the oversized
                    // payload is never buffered.
                    return FrameSplit::TooLarge;
                }
                if buf.len() < 4 + len {
                    return FrameSplit::Incomplete;
                }
                FrameSplit::Frame { consumed: 4 + len, from: 4, to: 4 + len }
            }
        }
    }
}

/// Result of [`Wire::split`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameSplit {
    /// Not enough buffered bytes for one frame yet — keep reading.
    Incomplete,
    /// The frame declares/implies a length over the cap. The connection
    /// must be poisoned (`request-too-large`, then close) — neither
    /// framing can resynchronize past a dropped oversized frame.
    TooLarge,
    /// One complete frame: payload at `buf[from..to]`, and the first
    /// `consumed` bytes of `buf` are done with.
    Frame { consumed: usize, from: usize, to: usize },
}

// ---- binary primitives ---------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_opt_str(out: &mut Vec<u8>, s: Option<&str>) {
    match s {
        None => out.push(0),
        Some(s) => {
            out.push(1);
            put_str(out, s);
        }
    }
}

fn put_opt_u32(out: &mut Vec<u8>, v: Option<u32>) {
    match v {
        None => out.push(0),
        Some(v) => {
            out.push(1);
            put_u32(out, v);
        }
    }
}

fn put_f64s(out: &mut Vec<u8>, v: &[f64]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        put_f64(out, x);
    }
}

fn put_execution(out: &mut Vec<u8>, e: &Execution) {
    put_f64(out, e.input_mb);
    put_f64(out, e.dt);
    put_f64s(out, &e.samples);
}

fn put_plan(out: &mut Vec<u8>, p: &StepPlan) {
    put_f64s(out, &p.starts);
    put_f64s(out, &p.peaks);
}

/// Trailing-optional dedup pair on mutating requests: appended after
/// every base field, so pre-dedup decoders (which ignore trailing
/// bytes) keep working, and absent entirely when the client sends none.
fn put_dedup(out: &mut Vec<u8>, dedup: &Option<Dedup>) {
    if let Some(d) = dedup {
        put_str(out, &d.nonce);
        put_u64(out, d.seq);
    }
}

/// Wrap a tagged payload in the 4-byte length header. Callers must
/// have length-checked `1 + body.len()` against
/// [`MAX_V2_PAYLOAD_BYTES`] first (the `try_encode_*` functions do) —
/// the `as u32` cast here would otherwise truncate silently and emit an
/// undecodable stream.
fn frame(tag: u8, body: &[u8]) -> Vec<u8> {
    debug_assert!(1 + body.len() <= MAX_V2_PAYLOAD_BYTES);
    let mut out = Vec::with_capacity(5 + body.len());
    put_u32(&mut out, (1 + body.len()) as u32);
    out.push(tag);
    out.extend_from_slice(body);
    out
}

fn bad(msg: impl Into<String>) -> WireError {
    WireError::new(ErrorCode::InvalidFrame, msg)
}

/// Bounds-checked cursor over one binary payload. Every structural
/// decode error is `invalid-frame`; trailing unread bytes are ignored
/// by design (the forward-compatibility seam — a newer peer may append
/// fields).
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, i: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(bad("truncated frame"));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| bad("string field is not valid UTF-8"))
    }

    fn opt_str(&mut self) -> Result<Option<String>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.str()?)),
            _ => Err(bad("optional-field flag must be 0 or 1")),
        }
    }

    fn opt_u32(&mut self) -> Result<Option<u32>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u32()?)),
            _ => Err(bad("optional-field flag must be 0 or 1")),
        }
    }

    fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.u32()? as usize;
        // Check against the bytes actually present before allocating —
        // a hostile length cannot force a huge allocation.
        if n > self.remaining() / 8 {
            return Err(bad("array length exceeds frame"));
        }
        (0..n).map(|_| self.f64()).collect()
    }

    /// Raw execution fields; semantic validation happens in
    /// `execution_from_parts`, identically to the JSON path.
    fn execution(&mut self, task: &str) -> Result<Execution, WireError> {
        let input_mb = self.f64()?;
        let dt = self.f64()?;
        let samples = self.f64s()?;
        execution_from_parts(task, input_mb, dt, samples)
    }

    fn plan(&mut self) -> Result<StepPlan, WireError> {
        let starts = self.f64s()?;
        let peaks = self.f64s()?;
        plan_from_parts(starts, peaks)
    }

    /// Decoder counterpart of [`put_dedup`]: a dedup pair is present iff
    /// any payload bytes remain after the base fields.
    fn trailing_dedup(&mut self) -> Result<Option<Dedup>, WireError> {
        if self.remaining() == 0 {
            return Ok(None);
        }
        let nonce = self.str()?;
        let seq = self.u64()?;
        Ok(Some(Dedup { nonce, seq }))
    }
}

// ---- requests ------------------------------------------------------------

fn oversized(code: ErrorCode, what: &str, got: usize, cap: usize) -> WireError {
    WireError::new(code, format!("encoded {what} is {got} bytes, over the {cap}-byte frame cap"))
}

/// Encode one request for the given wire, refusing — rather than
/// corrupting — anything that cannot be framed within `max` bytes.
/// v1 output is the JSON line (trailing `\n` included), byte-identical
/// to what `RemoteClient` has always written; the cap bounds the line
/// content, the same boundary the receiving server enforces with
/// `--max-frame-bytes`. v2 additionally enforces the structural `u32`
/// ceiling of the length header ([`MAX_V2_PAYLOAD_BYTES`]) — the old
/// infallible encoder cast lengths with `as u32` and silently truncated
/// oversized bodies. Failure is `request-too-large`, the same code the
/// server would answer, except the request never left this process and
/// the connection stays usable.
pub fn try_encode_request(wire: Wire, req: &Request, max: usize) -> Result<Vec<u8>, WireError> {
    let what = || format!("{} request", req.op());
    match wire {
        Wire::V1 => {
            let mut v = req.to_json().to_string().into_bytes();
            if v.len() > max {
                return Err(oversized(ErrorCode::RequestTooLarge, &what(), v.len(), max));
            }
            v.push(b'\n');
            Ok(v)
        }
        Wire::V2 => {
            let body = v2_request_body(req);
            let cap = max.min(MAX_V2_PAYLOAD_BYTES);
            if 1 + body.len() > cap {
                return Err(oversized(
                    ErrorCode::RequestTooLarge,
                    &what(),
                    1 + body.len(),
                    cap,
                ));
            }
            Ok(frame(op_tag(req.op()).expect("every Request op is in OPS"), &body))
        }
    }
}

fn v2_request_body(req: &Request) -> Vec<u8> {
    let mut body = Vec::new();
    match req {
        Request::Hello { client, min_version, max_version } => {
            put_opt_str(&mut body, client.as_deref());
            put_opt_u32(&mut body, min_version.map(|v| v as u32));
            put_opt_u32(&mut body, max_version.map(|v| v as u32));
        }
        Request::Configure { task, policy, dedup } => {
            put_opt_str(&mut body, task.as_deref());
            put_str(&mut body, policy.name());
            put_dedup(&mut body, dedup);
        }
        Request::Train { task, history, dedup } => {
            put_str(&mut body, task);
            put_u32(&mut body, history.len() as u32);
            for e in history {
                put_execution(&mut body, e);
            }
            put_dedup(&mut body, dedup);
        }
        Request::Observe { task, execution, dedup } => {
            put_str(&mut body, task);
            put_execution(&mut body, execution);
            put_dedup(&mut body, dedup);
        }
        Request::Plan { task, input_mb } => {
            put_str(&mut body, task);
            put_f64(&mut body, *input_mb);
        }
        Request::Failure { task, plan, fail_time } => {
            put_opt_str(&mut body, task.as_deref());
            put_plan(&mut body, plan);
            put_f64(&mut body, *fail_time);
        }
        Request::Stats | Request::Snapshot => {}
        Request::Reshard { shards } => {
            put_u32(&mut body, *shards as u32);
        }
    }
    body
}

/// Decode one request payload (as delimited by [`Wire::split`] or
/// [`read_frame`]). `Ok(None)` is v1's blank line — skipped without a
/// reply, exactly the old server behavior.
pub fn decode_request(wire: Wire, payload: &[u8]) -> Result<Option<Request>, WireError> {
    match wire {
        Wire::V1 => {
            // Lossy conversion, as the bounded line reader always did:
            // invalid UTF-8 fails JSON parsing with `invalid-json`.
            let line = String::from_utf8_lossy(payload);
            if line.trim().is_empty() {
                return Ok(None);
            }
            Request::parse(&line).map(Some)
        }
        Wire::V2 => {
            let mut c = Cur::new(payload);
            let tag = c.u8().map_err(|_| bad("empty frame"))?;
            let op = tag_op(tag).ok_or_else(|| bad(format!("unknown op tag 0x{tag:02x}")))?;
            let req = match op {
                "hello" => Request::Hello {
                    client: c.opt_str()?,
                    min_version: c.opt_u32()?.map(|v| v as usize),
                    max_version: c.opt_u32()?.map(|v| v as usize),
                },
                "configure" => {
                    let task = validate_configure_task(c.opt_str()?)?;
                    let policy = policy_from_name(&c.str()?)?;
                    Request::Configure { task, policy, dedup: c.trailing_dedup()? }
                }
                "train" => {
                    let task = c.str()?;
                    let n = c.u32()? as usize;
                    validate_history_len(n)?;
                    let history = (0..n)
                        .map(|_| c.execution(&task))
                        .collect::<Result<Vec<_>, _>>()?;
                    Request::Train { task, history, dedup: c.trailing_dedup()? }
                }
                "observe" => {
                    let task = c.str()?;
                    let execution = c.execution(&task)?;
                    Request::Observe { task, execution, dedup: c.trailing_dedup()? }
                }
                "plan" => Request::Plan { task: c.str()?, input_mb: c.f64()? },
                "failure" => Request::Failure {
                    task: c.opt_str()?,
                    plan: c.plan()?,
                    fail_time: c.f64()?,
                },
                "stats" => Request::Stats,
                "snapshot" => Request::Snapshot,
                "reshard" => {
                    Request::Reshard { shards: validate_reshard_shards(c.u32()? as usize)? }
                }
                _ => unreachable!("tag_op returns only OPS entries"),
            };
            Ok(Some(req))
        }
    }
}

// ---- responses -----------------------------------------------------------

/// Encode one success response, refusing anything that cannot be
/// framed. v1 output is the JSON line with its trailing `\n`,
/// byte-identical to the threaded server's `writeln!`, and has no
/// structural size limit — `max` is a caller-chosen bound (servers pass
/// [`MAX_V2_PAYLOAD_BYTES`]: responses are not subject to the *request*
/// cap, a snapshot legitimately exceeds it). On v2 the effective cap is
/// `min(max, MAX_V2_PAYLOAD_BYTES)` — past the `u32` length header
/// nothing can be framed. Failure is `internal` (the server built a
/// response it cannot ship); front ends substitute
/// `encode_error(wire, &err)` so the client sees a structured error
/// instead of a truncated, undecodable stream.
pub fn try_encode_response(wire: Wire, resp: &Response, max: usize) -> Result<Vec<u8>, WireError> {
    let what = || format!("{} response", response_op(resp));
    match wire {
        Wire::V1 => {
            let mut v = resp.to_json().to_string().into_bytes();
            if v.len() > max {
                return Err(oversized(ErrorCode::Internal, &what(), v.len(), max));
            }
            v.push(b'\n');
            Ok(v)
        }
        Wire::V2 => {
            let body = v2_response_body(resp);
            let cap = max.min(MAX_V2_PAYLOAD_BYTES);
            if 1 + body.len() > cap {
                return Err(oversized(ErrorCode::Internal, &what(), 1 + body.len(), cap));
            }
            let tag = RESPONSE_BIT
                | op_tag(response_op(resp)).expect("every Response op is in OPS");
            Ok(frame(tag, &body))
        }
    }
}

fn v2_response_body(resp: &Response) -> Vec<u8> {
    let mut body = Vec::new();
    match resp {
        Response::Hello(i) => {
            put_u32(&mut body, i.version as u32);
            put_u32(&mut body, i.shards as u32);
            put_u32(&mut body, i.ops.len() as u32);
            for op in &i.ops {
                put_str(&mut body, op);
            }
            put_u32(&mut body, i.policies.len() as u32);
            for p in &i.policies {
                put_str(&mut body, p);
            }
        }
        Response::Configured { task, policy } => {
            put_opt_str(&mut body, task.as_deref());
            put_str(&mut body, policy.name());
        }
        Response::Trained { task, executions } => {
            put_str(&mut body, task);
            put_u64(&mut body, *executions);
        }
        Response::Observed(a) => {
            put_str(&mut body, &a.task);
            put_u64(&mut body, a.executions);
            put_str(&mut body, a.predictor);
        }
        Response::Planned(o) => {
            put_plan(&mut body, &o.plan);
            put_str(&mut body, o.predictor);
            put_u64(&mut body, o.model_version);
            put_opt_str(&mut body, o.fallback_reason);
        }
        Response::Retry(r) => {
            put_plan(&mut body, &r.plan);
            put_str(&mut body, r.predictor);
        }
        Response::Stats(s) => {
            put_u32(&mut body, s.shards as u32);
            put_u64(&mut body, s.requests);
            put_u64(&mut body, s.batches);
            put_u64(&mut body, s.failures_handled);
            put_u64(&mut body, s.tasks_trained);
            put_u64(&mut body, s.observations);
            put_u64(&mut body, s.fallbacks);
            put_u64(&mut body, s.conns_refused);
            put_u64(&mut body, s.conn_timeouts);
            put_f64(&mut body, s.latency_p50_us);
            put_f64(&mut body, s.latency_p99_us);
            // Appended after every pre-overflow-counter field so old
            // decoders (which ignore trailing bytes) keep working.
            put_u64(&mut body, s.conns_overflowed);
            // Overload-control counters, appended in turn after the
            // overflow counter for the same forward compatibility.
            put_u64(&mut body, s.shed);
            put_u64(&mut body, s.queue_depth_max);
            put_u64(&mut body, s.drains);
        }
        Response::Snapshot { doc } => {
            // The snapshot document is structurally JSON (it is
            // the on-disk schema); v2 carries its text as one
            // string field rather than inventing a second
            // serialization of the whole model state.
            put_str(&mut body, &doc.to_string());
        }
        Response::Resharded { shard_ids } => {
            put_u32(&mut body, shard_ids.len() as u32);
            for &id in shard_ids {
                put_u32(&mut body, id as u32);
            }
        }
    }
    body
}

/// Encode an error reply (`ok:false` line on v1, a `0xFF` frame on v2).
pub fn encode_error(wire: Wire, err: &WireError) -> Vec<u8> {
    match wire {
        Wire::V1 => {
            let mut v = err.to_json().to_string().into_bytes();
            v.push(b'\n');
            v
        }
        Wire::V2 => {
            let mut body = Vec::new();
            put_str(&mut body, err.code.as_str());
            put_str(&mut body, &err.message);
            frame(TAG_ERROR, &body)
        }
    }
}

/// Client side: decode one response payload for the request op it
/// answers. Server-sent errors come back as `Err` (as
/// `Response::from_json` always has); structurally undecodable frames
/// are `Err` with `invalid-frame`/`invalid-json`.
pub fn decode_response(wire: Wire, payload: &[u8], op: &str) -> Result<Response, WireError> {
    match wire {
        Wire::V1 => {
            let line = String::from_utf8_lossy(payload);
            let j = Json::parse(&line)
                .map_err(|e| WireError::new(ErrorCode::InvalidJson, e.to_string()))?;
            Response::from_json(&j, op)
        }
        Wire::V2 => {
            let mut c = Cur::new(payload);
            let tag = c.u8().map_err(|_| bad("empty frame"))?;
            if tag == TAG_ERROR {
                let code = c.str()?;
                let message = c.str()?;
                // Unknown codes from a newer server degrade to
                // Internal, as WireError::from_json does.
                return Err(WireError {
                    code: ErrorCode::parse(&code).unwrap_or(ErrorCode::Internal),
                    message,
                });
            }
            let want = RESPONSE_BIT | op_tag(op).ok_or_else(|| bad("unknown request op"))?;
            if tag != want {
                return Err(bad(format!(
                    "response tag 0x{tag:02x} does not answer op '{op}'"
                )));
            }
            // Provenance degradation, same stance as the JSON decoder.
            let predictor_of = |name: String| -> &'static str {
                PredictorPolicy::parse(&name)
                    .map(PredictorPolicy::name)
                    .unwrap_or(PROVENANCE_UNKNOWN)
            };
            match op {
                "hello" => {
                    let version = c.u32()? as usize;
                    let shards = c.u32()? as usize;
                    let n_ops = c.u32()? as usize;
                    if n_ops > c.remaining() / 4 {
                        return Err(bad("array length exceeds frame"));
                    }
                    let ops = (0..n_ops).map(|_| c.str()).collect::<Result<Vec<_>, _>>()?;
                    let n_pol = c.u32()? as usize;
                    if n_pol > c.remaining() / 4 {
                        return Err(bad("array length exceeds frame"));
                    }
                    let policies =
                        (0..n_pol).map(|_| c.str()).collect::<Result<Vec<_>, _>>()?;
                    Ok(Response::Hello(ServerInfo { version, ops, policies, shards }))
                }
                "configure" => {
                    let task = c.opt_str()?;
                    let policy = policy_from_name(&c.str()?)?;
                    Ok(Response::Configured { task, policy })
                }
                "train" => Ok(Response::Trained { task: c.str()?, executions: c.u64()? }),
                "observe" => Ok(Response::Observed(ObserveAck {
                    task: c.str()?,
                    executions: c.u64()?,
                    predictor: predictor_of(c.str()?),
                })),
                "plan" => {
                    let plan = c.plan()?;
                    let predictor = predictor_of(c.str()?);
                    let model_version = c.u64()?;
                    let fallback_reason = match c.opt_str()?.as_deref() {
                        None => None,
                        Some(FALLBACK_UNTRAINED) => Some(FALLBACK_UNTRAINED),
                        // A newer server's reason: still a fallback.
                        Some(_) => Some(PROVENANCE_UNKNOWN),
                    };
                    Ok(Response::Planned(PlanOutcome {
                        plan,
                        predictor,
                        model_version,
                        fallback_reason,
                    }))
                }
                "failure" => Ok(Response::Retry(RetryOutcome {
                    plan: c.plan()?,
                    predictor: predictor_of(c.str()?),
                })),
                "stats" => {
                    let mut s = StatsSummary {
                        shards: c.u32()? as usize,
                        requests: c.u64()?,
                        batches: c.u64()?,
                        failures_handled: c.u64()?,
                        tasks_trained: c.u64()?,
                        observations: c.u64()?,
                        fallbacks: c.u64()?,
                        conns_refused: c.u64()?,
                        conn_timeouts: c.u64()?,
                        latency_p50_us: c.f64()?,
                        latency_p99_us: c.f64()?,
                        conns_overflowed: 0,
                        shed: 0,
                        queue_depth_max: 0,
                        drains: 0,
                    };
                    // Frames from servers predating each appended
                    // counter end earlier; default 0, the JSON
                    // decoder's stance for absent counters.
                    if c.remaining() >= 8 {
                        s.conns_overflowed = c.u64()?;
                    }
                    if c.remaining() >= 8 {
                        s.shed = c.u64()?;
                    }
                    if c.remaining() >= 8 {
                        s.queue_depth_max = c.u64()?;
                    }
                    if c.remaining() >= 8 {
                        s.drains = c.u64()?;
                    }
                    Ok(Response::Stats(s))
                }
                "snapshot" => {
                    let text = c.str()?;
                    let doc = Json::parse(&text)
                        .map_err(|e| bad(format!("snapshot payload is not JSON: {e}")))?;
                    Ok(Response::Snapshot { doc })
                }
                "reshard" => {
                    let n = c.u32()? as usize;
                    if n > c.remaining() / 4 {
                        return Err(bad("array length exceeds frame"));
                    }
                    let shard_ids = (0..n)
                        .map(|_| c.u32().map(|v| v as usize))
                        .collect::<Result<Vec<_>, _>>()?;
                    Ok(Response::Resharded { shard_ids })
                }
                other => Err(WireError::new(
                    ErrorCode::UnknownOp,
                    format!("no response decoder for op '{other}'"),
                )),
            }
        }
    }
}

// ---- blocking frame reader -----------------------------------------------

/// Outcome of one blocking framed read (threaded server and
/// `RemoteClient`). The v1 arm preserves the bounded line reader's
/// semantics exactly, including serving an unterminated final line
/// before reporting EOF.
#[derive(Debug)]
pub enum FrameRead {
    /// One frame's payload (v1: the line bytes without `\n`).
    Frame(Vec<u8>),
    /// Peer closed the connection cleanly.
    Eof,
    /// The frame exceeds `max_frame_bytes`; the connection must be
    /// closed — neither framing can resynchronize past it.
    TooLong,
    /// The socket's read timeout elapsed.
    TimedOut,
}

fn is_timeout(e: &io::Error) -> bool {
    e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut
}

enum Exact {
    Ok,
    Eof,
    TimedOut,
}

fn read_exact_soft<R: Read>(r: &mut R, buf: &mut [u8]) -> io::Result<Exact> {
    let mut n = 0;
    while n < buf.len() {
        match r.read(&mut buf[n..]) {
            Ok(0) => return Ok(Exact::Eof),
            Ok(m) => n += m,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => return Ok(Exact::TimedOut),
            Err(e) => return Err(e),
        }
    }
    Ok(Exact::Ok)
}

/// Read one frame of at most `max` payload bytes from a blocking
/// reader. Neither arm can be driven into unbounded allocation: v1
/// never buffers more than `max + one chunk` bytes of an endless line,
/// v2 rejects the frame from its 4-byte header before allocating.
pub fn read_frame<R: BufRead>(reader: &mut R, wire: Wire, max: usize) -> io::Result<FrameRead> {
    match wire {
        Wire::V1 => {
            let mut buf: Vec<u8> = Vec::new();
            loop {
                let (used, done) = {
                    let chunk = match reader.fill_buf() {
                        Ok(c) => c,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(e) if is_timeout(&e) => return Ok(FrameRead::TimedOut),
                        Err(e) => return Err(e),
                    };
                    if chunk.is_empty() {
                        return Ok(if buf.is_empty() {
                            FrameRead::Eof
                        } else {
                            FrameRead::Frame(buf)
                        });
                    }
                    match chunk.iter().position(|&b| b == b'\n') {
                        Some(pos) if buf.len() + pos > max => (pos + 1, Some(FrameRead::TooLong)),
                        Some(pos) => {
                            buf.extend_from_slice(&chunk[..pos]);
                            (pos + 1, Some(FrameRead::Frame(std::mem::take(&mut buf))))
                        }
                        None if buf.len() + chunk.len() > max => {
                            (chunk.len(), Some(FrameRead::TooLong))
                        }
                        None => {
                            let n = chunk.len();
                            buf.extend_from_slice(chunk);
                            (n, None)
                        }
                    }
                };
                reader.consume(used);
                if let Some(outcome) = done {
                    return Ok(outcome);
                }
            }
        }
        Wire::V2 => {
            let mut hdr = [0u8; 4];
            match read_exact_soft(reader, &mut hdr)? {
                Exact::Eof => return Ok(FrameRead::Eof),
                Exact::TimedOut => return Ok(FrameRead::TimedOut),
                Exact::Ok => {}
            }
            let len = u32::from_le_bytes(hdr) as usize;
            if len > max {
                return Ok(FrameRead::TooLong);
            }
            let mut payload = vec![0u8; len];
            match read_exact_soft(reader, &mut payload)? {
                // EOF or timeout mid-frame: the stream cannot be
                // resynchronized either way — report the terminal state.
                Exact::Eof => Ok(FrameRead::Eof),
                Exact::TimedOut => Ok(FrameRead::TimedOut),
                Exact::Ok => Ok(FrameRead::Frame(payload)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn exec(seed: u64) -> Execution {
        let mut rng = Rng::new(seed);
        let n = 3 + rng.below(6);
        Execution::new(
            "t",
            rng.uniform(100.0, 9000.0),
            1.0,
            (0..n).map(|_| rng.uniform(0.01, 12.0)).collect(),
        )
    }

    fn every_request() -> Vec<Request> {
        vec![
            Request::Hello {
                client: Some("codec-test".into()),
                min_version: Some(1),
                max_version: Some(2),
            },
            Request::Hello { client: None, min_version: None, max_version: None },
            Request::Configure {
                task: Some("bwa".into()),
                policy: PredictorPolicy::WittLr,
                dedup: None,
            },
            Request::Configure { task: None, policy: PredictorPolicy::KsPlus, dedup: None },
            Request::Configure {
                task: Some("bwa".into()),
                policy: PredictorPolicy::KsPlus,
                dedup: Some(Dedup { nonce: "codec-nonce".into(), seq: 1 }),
            },
            Request::Train { task: "t".into(), history: vec![exec(1), exec(2)], dedup: None },
            Request::Train {
                task: "t".into(),
                history: vec![exec(6)],
                dedup: Some(Dedup { nonce: "codec-nonce".into(), seq: 2 }),
            },
            Request::Observe { task: "t".into(), execution: exec(3), dedup: None },
            Request::Observe {
                task: "t".into(),
                execution: exec(7),
                dedup: Some(Dedup { nonce: "codec-nonce".into(), seq: 3 }),
            },
            Request::Plan { task: "bwa".into(), input_mb: 1234.5 },
            Request::Failure {
                task: Some("bwa".into()),
                plan: StepPlan::new(vec![0.0, 10.5], vec![2.25, 8.0]),
                fail_time: 3.5,
            },
            Request::Stats,
            Request::Snapshot,
            Request::Reshard { shards: 4 },
        ]
    }

    fn every_response() -> Vec<Response> {
        vec![
            Response::Hello(ServerInfo {
                version: 2,
                ops: OPS.iter().map(|s| s.to_string()).collect(),
                policies: PredictorPolicy::names().iter().map(|s| s.to_string()).collect(),
                shards: 4,
            }),
            Response::Configured { task: Some("bwa".into()), policy: PredictorPolicy::TovarPpm },
            Response::Configured { task: None, policy: PredictorPolicy::KsPlus },
            Response::Trained { task: "bwa".into(), executions: 12 },
            Response::Observed(ObserveAck {
                task: "bwa".into(),
                executions: 13,
                predictor: "ksplus",
            }),
            Response::Planned(PlanOutcome {
                plan: StepPlan::new(
                    vec![0.0, 68.279_999_999_999_99],
                    vec![4.125, 8.800000000000001],
                ),
                predictor: "ksplus",
                model_version: 13,
                fallback_reason: None,
            }),
            Response::Planned(PlanOutcome {
                plan: StepPlan::flat(32.0),
                predictor: "default-limits",
                model_version: 0,
                fallback_reason: Some(FALLBACK_UNTRAINED),
            }),
            Response::Retry(RetryOutcome {
                plan: StepPlan::new(vec![0.0, 60.0], vec![2.0, 8.0]),
                predictor: "witt-lr",
            }),
            Response::Stats(StatsSummary {
                shards: 2,
                requests: 100,
                batches: 20,
                failures_handled: 3,
                tasks_trained: 5,
                observations: 7,
                fallbacks: 2,
                conns_refused: 4,
                conn_timeouts: 1,
                latency_p50_us: 12.5,
                latency_p99_us: 90.25,
                conns_overflowed: 6,
                shed: 9,
                queue_depth_max: 17,
                drains: 1,
            }),
            Response::Snapshot {
                doc: Json::obj(vec![
                    ("schema", "ksplus-model-snapshot/v1".into()),
                    ("tasks", Json::Arr(vec![])),
                ]),
            },
            Response::Resharded { shard_ids: vec![0, 2, 5] },
        ]
    }

    #[test]
    fn v1_is_byte_identical_to_the_json_lines() {
        // The codec seam must not perturb v1 traffic by a single byte.
        for req in every_request() {
            let mut want = req.to_json().to_string().into_bytes();
            want.push(b'\n');
            assert_eq!(try_encode_request(Wire::V1, &req, DEFAULT_MAX_FRAME_BYTES).unwrap(), want);
        }
        for resp in every_response() {
            let mut want = resp.to_json().to_string().into_bytes();
            want.push(b'\n');
            assert_eq!(try_encode_response(Wire::V1, &resp, MAX_V2_PAYLOAD_BYTES).unwrap(), want);
        }
        let err = WireError::new(ErrorCode::UnknownOp, "nope");
        let mut want = err.to_json().to_string().into_bytes();
        want.push(b'\n');
        assert_eq!(encode_error(Wire::V1, &err), want);
    }

    #[test]
    fn v2_requests_roundtrip_every_op() {
        for req in every_request() {
            let framed = try_encode_request(Wire::V2, &req, DEFAULT_MAX_FRAME_BYTES).unwrap();
            let split = Wire::V2.split(&framed, DEFAULT_MAX_FRAME_BYTES);
            let FrameSplit::Frame { consumed, from, to } = split else {
                panic!("{req:?}: not one frame: {split:?}");
            };
            assert_eq!(consumed, framed.len());
            let back = decode_request(Wire::V2, &framed[from..to])
                .unwrap_or_else(|e| panic!("{req:?}: {e}"))
                .expect("v2 has no blank frames");
            assert_eq!(back, req);
        }
    }

    #[test]
    fn v2_responses_roundtrip_with_bit_exact_floats() {
        for resp in every_response() {
            let op = response_op(&resp);
            let framed = try_encode_response(Wire::V2, &resp, MAX_V2_PAYLOAD_BYTES).unwrap();
            let FrameSplit::Frame { from, to, .. } =
                Wire::V2.split(&framed, DEFAULT_MAX_FRAME_BYTES)
            else {
                panic!("{op}: bad frame");
            };
            let back = decode_response(Wire::V2, &framed[from..to], op)
                .unwrap_or_else(|e| panic!("{op}: {e}"));
            assert_eq!(back, resp, "roundtrip for {op}");
        }
        // PartialEq on f64 conflates 0.0/-0.0; pin bits explicitly.
        let plan = StepPlan::new(vec![-0.0, 68.279_999_999_999_99], vec![4.4, f64::MIN_POSITIVE]);
        let resp = Response::Retry(RetryOutcome { plan: plan.clone(), predictor: "ksplus" });
        let framed = try_encode_response(Wire::V2, &resp, MAX_V2_PAYLOAD_BYTES).unwrap();
        let FrameSplit::Frame { from, to, .. } = Wire::V2.split(&framed, 1 << 20) else {
            panic!()
        };
        match decode_response(Wire::V2, &framed[from..to], "failure").unwrap() {
            Response::Retry(r) => {
                for (a, b) in r.plan.starts.iter().zip(&plan.starts) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                for (a, b) in r.plan.peaks.iter().zip(&plan.peaks) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn v2_error_frames_roundtrip_and_unknown_codes_degrade() {
        for code in ErrorCode::ALL {
            let err = WireError::new(code, format!("ctx {}", code.as_str()));
            let framed = encode_error(Wire::V2, &err);
            let FrameSplit::Frame { from, to, .. } = Wire::V2.split(&framed, 1 << 20) else {
                panic!()
            };
            let got = decode_response(Wire::V2, &framed[from..to], "plan").unwrap_err();
            assert_eq!(got, err);
        }
        // A code from the future degrades to Internal, message kept.
        let mut body = Vec::new();
        put_str(&mut body, "circuit-breaker-open");
        put_str(&mut body, "try later");
        let framed = frame(TAG_ERROR, &body);
        let FrameSplit::Frame { from, to, .. } = Wire::V2.split(&framed, 1 << 20) else {
            panic!()
        };
        let got = decode_response(Wire::V2, &framed[from..to], "plan").unwrap_err();
        assert_eq!(got.code, ErrorCode::Internal);
        assert_eq!(got.message, "try later");
    }

    #[test]
    fn oversized_encodes_are_refused_not_truncated() {
        // A request over the cap is refused before a single byte is
        // written, with the same structured code the server would
        // answer — the old encoder cast lengths `as u32` and emitted a
        // stream no peer could resynchronize past.
        let req = Request::Train {
            task: "t".into(),
            history: (0..16u64).map(exec).collect(),
            dedup: None,
        };
        for wire in [Wire::V1, Wire::V2] {
            let err = try_encode_request(wire, &req, 64).unwrap_err();
            assert_eq!(err.code, ErrorCode::RequestTooLarge, "{}", wire.name());
            assert!(err.message.contains("64-byte"), "{}", err.message);
            // The same request clears the real default cap.
            assert!(try_encode_request(wire, &req, DEFAULT_MAX_FRAME_BYTES).is_ok());
        }
        // Response overflow is the server's own fault, hence `internal`.
        let resp = Response::Snapshot {
            doc: Json::obj(vec![("blob", "x".repeat(256).into())]),
        };
        for wire in [Wire::V1, Wire::V2] {
            let err = try_encode_response(wire, &resp, 64).unwrap_err();
            assert_eq!(err.code, ErrorCode::Internal, "{}", wire.name());
        }
        // The structural u32 ceiling clamps any larger caller cap (a
        // >4 GiB body can't be built in a unit test; the clamp is the
        // code path under test).
        assert!(try_encode_request(Wire::V2, &Request::Stats, usize::MAX).is_ok());
        assert!(try_encode_response(
            Wire::V2,
            &Response::Trained { task: "t".into(), executions: 1 },
            usize::MAX
        )
        .is_ok());
    }

    #[test]
    fn v1_boundary_is_line_content_not_newline() {
        // The cap bounds the line *content*, the same boundary
        // `Wire::split` and the server's bounded reader enforce.
        let line_len = Request::Stats.to_json().to_string().len();
        assert!(try_encode_request(Wire::V1, &Request::Stats, line_len).is_ok());
        assert_eq!(
            try_encode_request(Wire::V1, &Request::Stats, line_len - 1).unwrap_err().code,
            ErrorCode::RequestTooLarge
        );
    }

    #[test]
    fn stats_trailing_counters_are_optional_in_v2_frames() {
        // The appended counters (conns_overflowed, then shed /
        // queue_depth_max / drains) peel off the tail in reverse order:
        // a frame from any older server simply ends earlier, and the
        // decoder defaults whatever is absent to 0 while keeping every
        // other field.
        let resp = every_response()
            .into_iter()
            .find(|r| matches!(r, Response::Stats(_)))
            .unwrap();
        let framed = try_encode_response(Wire::V2, &resp, MAX_V2_PAYLOAD_BYTES).unwrap();
        // Pre-overload-control server: the last three u64s are absent.
        let pre_overload = &framed[4..framed.len() - 24];
        match decode_response(Wire::V2, pre_overload, "stats").unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.conns_overflowed, 6);
                assert_eq!((s.shed, s.queue_depth_max, s.drains), (0, 0, 0));
                assert_eq!(s.conn_timeouts, 1);
                assert_eq!(s.latency_p99_us, 90.25);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Pre-overflow-counter server: all four trailing u64s absent.
        let pre_overflow = &framed[4..framed.len() - 32];
        match decode_response(Wire::V2, pre_overflow, "stats").unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.conns_overflowed, 0);
                assert_eq!((s.shed, s.queue_depth_max, s.drains), (0, 0, 0));
                assert_eq!(s.conn_timeouts, 1);
                assert_eq!(s.latency_p99_us, 90.25);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The full current frame carries all four.
        match decode_response(Wire::V2, &framed[4..], "stats").unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.conns_overflowed, 6);
                assert_eq!((s.shed, s.queue_depth_max, s.drains), (9, 17, 1));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn v2_structural_garbage_is_invalid_frame_without_big_allocations() {
        // Empty payload, unknown tag, truncated fields.
        assert_eq!(decode_request(Wire::V2, &[]).unwrap_err().code, ErrorCode::InvalidFrame);
        assert_eq!(
            decode_request(Wire::V2, &[0x6f]).unwrap_err().code,
            ErrorCode::InvalidFrame
        );
        assert_eq!(
            decode_request(Wire::V2, &[0x00]).unwrap_err().code,
            ErrorCode::InvalidFrame
        );
        // plan op with a truncated task string.
        let payload = [0x05, 0xff, 0xff, 0xff, 0x7f];
        assert_eq!(
            decode_request(Wire::V2, &payload).unwrap_err().code,
            ErrorCode::InvalidFrame
        );
        // observe with a samples count far past the frame: must error
        // before allocating, not OOM.
        let mut body = Vec::new();
        put_str(&mut body, "t");
        put_f64(&mut body, 1.0);
        put_f64(&mut body, 1.0);
        put_u32(&mut body, u32::MAX);
        let mut payload = vec![0x04];
        payload.extend_from_slice(&body);
        assert_eq!(
            decode_request(Wire::V2, &payload).unwrap_err().code,
            ErrorCode::InvalidFrame
        );
    }

    #[test]
    fn v2_semantic_errors_match_v1_codes_and_messages() {
        // The same malformed request earns the identical error on both
        // wires — codes *and* messages, because the validators are the
        // same functions.
        let cases: Vec<(Request, &str)> = vec![
            (
                Request::Observe {
                    task: "t".into(),
                    execution: Execution::new("t", 1.0, 0.0, vec![1.0]),
                    dedup: None,
                },
                r#"{"op":"observe","task":"t","execution":{"input_mb":1,"dt":0,"samples":[1]}}"#,
            ),
            (
                Request::Observe {
                    task: "t".into(),
                    execution: Execution::new("t", 1.0, 1.0, vec![]),
                    dedup: None,
                },
                r#"{"op":"observe","task":"t","execution":{"input_mb":1,"dt":1,"samples":[]}}"#,
            ),
            (
                Request::Failure {
                    task: None,
                    plan: StepPlan::new(vec![0.0, 1.0], vec![1.0]),
                    fail_time: 1.0,
                },
                r#"{"op":"failure","plan":{"starts":[0,1],"peaks":[1]},"fail_time":1}"#,
            ),
            (
                Request::Reshard { shards: 0 },
                r#"{"op":"reshard","shards":0}"#,
            ),
            (
                Request::Configure {
                    task: Some("*".into()),
                    policy: PredictorPolicy::KsPlus,
                    dedup: None,
                },
                r#"{"op":"configure","task":"*","policy":"ksplus"}"#,
            ),
            (
                Request::Train { task: "t".into(), history: vec![], dedup: None },
                r#"{"op":"train","task":"t","history":[]}"#,
            ),
        ];
        for (req, v1_line) in cases {
            let v1_err = Request::parse(v1_line).unwrap_err();
            let framed = try_encode_request(Wire::V2, &req, DEFAULT_MAX_FRAME_BYTES).unwrap();
            let FrameSplit::Frame { from, to, .. } = Wire::V2.split(&framed, 1 << 20) else {
                panic!()
            };
            let v2_err = decode_request(Wire::V2, &framed[from..to]).unwrap_err();
            assert_eq!(v2_err, v1_err, "wires disagree for {v1_line}");
        }
    }

    #[test]
    fn split_handles_partial_frames_and_caps() {
        // v2: header alone, partial payload, exact frame, frame + tail.
        let framed = try_encode_request(Wire::V2, &Request::Stats, 1024).unwrap();
        assert_eq!(Wire::V2.split(&framed[..3], 1024), FrameSplit::Incomplete);
        assert_eq!(Wire::V2.split(&framed[..4], 1024), FrameSplit::Incomplete);
        let FrameSplit::Frame { consumed, from, to } = Wire::V2.split(&framed, 1024) else {
            panic!()
        };
        assert_eq!((consumed, from, to), (framed.len(), 4, framed.len()));
        let mut two = framed.clone();
        two.extend_from_slice(&framed);
        let FrameSplit::Frame { consumed, .. } = Wire::V2.split(&two, 1024) else { panic!() };
        assert_eq!(consumed, framed.len());
        // Oversized: rejected from the header alone, payload absent.
        let mut huge = Vec::new();
        put_u32(&mut huge, 2048);
        assert_eq!(Wire::V2.split(&huge, 1024), FrameSplit::TooLarge);

        // v1: no newline yet, newline, content-over-cap boundaries.
        assert_eq!(Wire::V1.split(b"{\"op\":\"st", 1024), FrameSplit::Incomplete);
        assert_eq!(
            Wire::V1.split(b"{\"op\":\"stats\"}\nrest", 1024),
            FrameSplit::Frame { consumed: 15, from: 0, to: 14 }
        );
        // A 5-byte line is within a 5-byte cap; 6 bytes is not.
        assert_eq!(
            Wire::V1.split(b"aaaaa\n", 5),
            FrameSplit::Frame { consumed: 6, from: 0, to: 5 }
        );
        assert_eq!(Wire::V1.split(b"aaaaaa\n", 5), FrameSplit::TooLarge);
        assert_eq!(Wire::V1.split(b"aaaaaa", 5), FrameSplit::TooLarge);
    }

    #[test]
    fn blocking_read_frame_matches_split_semantics() {
        use std::io::BufReader;
        // v1 line, v1 unterminated final line, then EOF.
        let mut r = BufReader::new(&b"{\"op\":\"stats\"}\n{\"op\":\"snap"[..]);
        let FrameRead::Frame(p) = read_frame(&mut r, Wire::V1, 1024).unwrap() else { panic!() };
        assert_eq!(p, b"{\"op\":\"stats\"}");
        let FrameRead::Frame(p) = read_frame(&mut r, Wire::V1, 1024).unwrap() else { panic!() };
        assert_eq!(p, b"{\"op\":\"snap");
        assert!(matches!(read_frame(&mut r, Wire::V1, 1024).unwrap(), FrameRead::Eof));

        // v1 over-cap line.
        let long = vec![b'x'; 64];
        let mut r = BufReader::new(&long[..]);
        assert!(matches!(read_frame(&mut r, Wire::V1, 16).unwrap(), FrameRead::TooLong));

        // v2: two frames back to back, then EOF.
        let mut bytes = try_encode_request(Wire::V2, &Request::Stats, 1024).unwrap();
        bytes.extend_from_slice(
            &try_encode_request(
                Wire::V2,
                &Request::Plan { task: "bwa".into(), input_mb: 7.5 },
                1024,
            )
            .unwrap(),
        );
        let mut r = BufReader::new(&bytes[..]);
        let FrameRead::Frame(p) = read_frame(&mut r, Wire::V2, 1024).unwrap() else { panic!() };
        assert_eq!(decode_request(Wire::V2, &p).unwrap(), Some(Request::Stats));
        let FrameRead::Frame(p) = read_frame(&mut r, Wire::V2, 1024).unwrap() else { panic!() };
        assert_eq!(
            decode_request(Wire::V2, &p).unwrap(),
            Some(Request::Plan { task: "bwa".into(), input_mb: 7.5 })
        );
        assert!(matches!(read_frame(&mut r, Wire::V2, 1024).unwrap(), FrameRead::Eof));

        // v2 over-cap frame: refused from the header.
        let mut huge = Vec::new();
        put_u32(&mut huge, (1 << 30) as u32);
        let mut r = BufReader::new(&huge[..]);
        assert!(matches!(read_frame(&mut r, Wire::V2, 1024).unwrap(), FrameRead::TooLong));
    }

    #[test]
    fn blank_v1_lines_are_skipped_without_reply() {
        assert_eq!(decode_request(Wire::V1, b"").unwrap(), None);
        assert_eq!(decode_request(Wire::V1, b"   \r").unwrap(), None);
        assert!(decode_request(Wire::V1, b"{\"op\":\"stats\"}").unwrap().is_some());
    }

    #[test]
    fn wire_names_and_versions() {
        assert_eq!(Wire::parse("v1"), Some(Wire::V1));
        assert_eq!(Wire::parse("2"), Some(Wire::V2));
        assert_eq!(Wire::parse("v3"), None);
        assert_eq!(Wire::from_version(Wire::V2.version()), Some(Wire::V2));
        assert_eq!(Wire::V1.name(), "v1");
        assert_eq!(Wire::V2.name(), "v2");
    }
}
