//! Deterministic PRNG substrate (the build environment has no `rand`).
//!
//! xoshiro256++ seeded via SplitMix64, plus the distribution samplers the
//! trace generators need: uniform, normal (Box-Muller), log-normal, and
//! bounded Pareto. Every experiment seeds its own `Rng` explicitly so all
//! results are reproducible run-to-run (the paper uses 10 seeds per
//! experiment; we do the same).

/// xoshiro256++ generator. Not cryptographic; statistical quality is more
/// than sufficient for workload synthesis and property tests.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed all 256 bits of state from a single u64 via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream for a sub-component (task, replicate).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal parameterised by the *underlying* normal's mu/sigma.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto with scale `xm` and shape `alpha`, truncated at `cap`.
    pub fn pareto(&mut self, xm: f64, alpha: f64, cap: f64) -> f64 {
        let u = 1.0 - self.f64();
        (xm / u.powf(1.0 / alpha)).min(cap)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n), in shuffled order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let idx = r.sample_indices(20, 10);
        assert_eq!(idx.len(), 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn log_normal_positive() {
        let mut r = Rng::new(23);
        for _ in 0..1000 {
            assert!(r.log_normal(1.0, 0.5) > 0.0);
        }
    }

    #[test]
    fn pareto_bounds() {
        let mut r = Rng::new(29);
        for _ in 0..1000 {
            let x = r.pareto(1.0, 1.5, 100.0);
            assert!((1.0..=100.0).contains(&x));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
