//! Cluster-throughput experiment (ours): the paper's introduction argues
//! that over-allocation "limits the throughput on both a workflow and a
//! cluster level". This experiment quantifies that claim: run the full
//! eager workflow in DAG order on a small cluster under every method and
//! report makespan, throughput, and memory efficiency next to wastage.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::experiments::{report, trained_predictor, ExpConfig, ExpOutput};
use crate::predictor::{paper_methods, Predictor};
use crate::sim::cluster::{ClusterConfig, PredictorSource};
use crate::sim::dag::run_workflow_dag;
use crate::trace::workflow::Workflow;
use crate::trace::{split_train_test, TaskTraces, WorkflowTrace};
use crate::util::json::Json;
use crate::util::rng::Rng;

struct Trained(BTreeMap<String, Box<dyn Predictor>>);

impl PredictorSource for Trained {
    fn get(&self, task: &str) -> Option<&dyn Predictor> {
        self.0.get(task).map(|p| p.as_ref())
    }
}

#[derive(Debug, Clone)]
pub struct ThroughputRow {
    pub method: &'static str,
    pub makespan_s: f64,
    pub throughput_per_h: f64,
    pub wastage_gbs: f64,
    pub efficiency: f64,
}

pub fn collect(cfg: &ExpConfig, nodes: usize) -> Result<Vec<ThroughputRow>> {
    let wf = Workflow::eager();
    let full = wf.generate(cfg.trace_seed, cfg.target_samples);
    let cluster = ClusterConfig { nodes, node_capacity_gb: cfg.capacity_gb };
    let mut rows = Vec::new();
    for method in paper_methods() {
        // Identical split across methods (seed 1).
        let mut preds = Trained(BTreeMap::new());
        let mut test = WorkflowTrace { name: full.name.clone(), tasks: Vec::new() };
        for (idx, t) in full.tasks.iter().enumerate() {
            let mut rng = Rng::new(1).fork(idx as u64 + 1);
            let (train, test_set) = split_train_test(t, 0.5, &mut rng);
            preds.0.insert(
                t.task.clone(),
                trained_predictor(method, cfg.k, cfg.capacity_gb, &wf, &t.task, &train)?,
            );
            test.tasks.push(TaskTraces { task: t.task.clone(), executions: test_set });
        }
        let r = run_workflow_dag(&cluster, &wf, &test, &preds);
        let instances = r.report.total_instances() as f64;
        rows.push(ThroughputRow {
            method,
            makespan_s: r.makespan_s,
            throughput_per_h: if r.makespan_s > 0.0 {
                instances / (r.makespan_s / 3600.0)
            } else {
                0.0
            },
            wastage_gbs: r.report.total_wastage_gbs(),
            efficiency: r.report.efficiency(),
        });
    }
    Ok(rows)
}

pub fn run(cfg: &ExpConfig) -> Result<ExpOutput> {
    let nodes = 4;
    let rows = collect(cfg, nodes)?;
    let mut table = report::Table::new(&[
        "method",
        "makespan s",
        "tasks/h",
        "wastage GBs",
        "mem efficiency",
    ]);
    let mut json_rows = Vec::new();
    for r in &rows {
        table.row(vec![
            r.method.to_string(),
            report::f(r.makespan_s),
            report::f(r.throughput_per_h),
            report::f(r.wastage_gbs),
            format!("{:.1}%", r.efficiency * 100.0),
        ]);
        json_rows.push(Json::obj(vec![
            ("method", r.method.into()),
            ("makespan_s", r.makespan_s.into()),
            ("throughput_per_h", r.throughput_per_h.into()),
            ("wastage_gbs", r.wastage_gbs.into()),
            ("efficiency", r.efficiency.into()),
        ]));
    }
    let text = table.render(&format!(
        "Throughput (ours): eager DAG on {nodes} x 128 GB nodes, 50% train"
    ));
    Ok(ExpOutput { text, json: Json::obj(vec![("throughput", Json::Arr(json_rows))]) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ksplus_best_or_near_best_throughput() {
        let cfg = ExpConfig { seeds: vec![1], ..Default::default() };
        let rows = collect(&cfg, 2).unwrap();
        let ks = rows.iter().find(|r| r.method == "ksplus").unwrap();
        let best = rows.iter().map(|r| r.throughput_per_h).fold(0.0, f64::max);
        assert!(
            ks.throughput_per_h >= best * 0.9,
            "KS+ {:.1} vs best {best:.1} tasks/h",
            ks.throughput_per_h
        );
        // And strictly the best memory efficiency.
        let ks_eff = ks.efficiency;
        for r in &rows {
            if r.method != "ksplus" {
                assert!(ks_eff >= r.efficiency, "{} beats KS+ efficiency", r.method);
            }
        }
    }

    #[test]
    fn report_renders() {
        let cfg = ExpConfig { seeds: vec![1], ..Default::default() };
        let out = run(&cfg).unwrap();
        assert!(out.text.contains("Throughput"));
        assert!(out.json.get("throughput").is_some());
    }
}
