//! Seeded property-test harness (no `proptest` offline).
//!
//! `run_prop("name", cases, |rng| { ... })` executes the closure `cases`
//! times with independent deterministic RNG streams and reports the first
//! failing seed so a counterexample can be replayed exactly with
//! `PROP_SEED=<seed> cargo test <name>`.

use crate::util::fnv1a;
use crate::util::rng::Rng;

/// Number of cases used by most invariant suites.
pub const DEFAULT_CASES: u64 = 200;

/// Run `f` for `cases` deterministic seeds; panic with the failing seed.
pub fn run_prop<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng),
{
    // Replay hook: PROP_SEED pins a single case.
    if let Ok(seed) = std::env::var("PROP_SEED") {
        if let Ok(seed) = seed.parse::<u64>() {
            let mut rng = Rng::new(seed);
            f(&mut rng);
            return;
        }
    }
    for case in 0..cases {
        // Stable per-(name, case) seed so adding cases elsewhere does not
        // shift this property's stream.
        let seed = fnv1a(name) ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (replay: PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0u64;
        run_prop("count", 50, |_| n += 1);
        assert_eq!(n, 50);
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            run_prop("fail", 10, |rng| {
                let x = rng.f64();
                assert!(x < 2.0); // never fails
                assert!(x >= 0.0);
                if rng.below(3) == 1 {
                    panic!("boom");
                }
            })
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn deterministic_streams() {
        let mut first = Vec::new();
        run_prop("det", 5, |rng| first.push(rng.next_u64()));
        let mut second = Vec::new();
        run_prop("det", 5, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}
