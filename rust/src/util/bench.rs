//! Minimal wall-clock bench harness (no `criterion` offline).
//!
//! Used by the `cargo bench` targets (all `harness = false`): warmup,
//! fixed repetition count, median/p95/mean reporting, and a trivial
//! throughput helper. Results print in a stable grep-friendly format:
//!
//! ```text
//! bench <name>: median 1.234 ms  p95 1.456 ms  mean 1.300 ms  (20 iters)
//! ```

use std::time::Instant;

use crate::util::stats;

/// Outcome of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub p95_s: f64,
    pub mean_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "bench {}: median {}  p95 {}  mean {}  ({} iters)",
            self.name,
            fmt_t(self.median_s),
            fmt_t(self.p95_s),
            fmt_t(self.mean_s),
            self.iters
        )
    }

    pub fn throughput_line(&self, items: f64, unit: &str) -> String {
        format!(
            "bench {}: {:.0} {unit}/s (median over {} iters)",
            self.name,
            items / self.median_s,
            self.iters
        )
    }

    /// Median nanoseconds per item — the unit `BENCH_hotpath.json`
    /// records for segmentation and observe.
    pub fn ns_per_op(&self, items: f64) -> f64 {
        self.median_s * 1e9 / items
    }

    /// Median items per second.
    pub fn per_s(&self, items: f64) -> f64 {
        items / self.median_s
    }
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Run `f` `iters` times after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median_s: stats::median(&samples),
        p95_s: stats::percentile(&samples, 95.0),
        mean_s: stats::mean(&samples),
    };
    println!("{}", r.line());
    r
}

/// Guard against the optimizer discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12); // warmup + iters
        assert_eq!(r.iters, 10);
        assert!(r.median_s >= 0.0 && r.p95_s >= r.median_s);
    }

    #[test]
    fn formats_scale() {
        assert!(fmt_t(2.0).ends_with(" s"));
        assert!(fmt_t(0.002).ends_with(" ms"));
        assert!(fmt_t(0.0000002).ends_with(" us"));
    }

    #[test]
    fn throughput_line_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            median_s: 0.5,
            p95_s: 0.6,
            mean_s: 0.5,
        };
        assert!(r.throughput_line(100.0, "tasks").contains("200 tasks/s"));
        assert_eq!(r.ns_per_op(100.0), 5_000_000.0);
        assert_eq!(r.per_s(100.0), 200.0);
    }
}
