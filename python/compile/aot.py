"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

Emits HLO *text* (NOT HloModuleProto.serialize()): jax >= 0.5 writes protos
with 64-bit instruction ids that xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs, per bucket, into --outdir:
  fit_b{B}_n{N}.hlo.txt          (x[B,N], y[B,N], m[B,N]) -> (coef[B,2],)
  predict_b{B}.hlo.txt           (coef[B,2], xq[B], scale[B]) -> (yhat[B],)
  fit_predict_b{B}_n{N}.hlo.txt  (x,y,m,xq,scale) -> (yhat[B], coef[B,2])
  wastage_b{B}_n{N}.hlo.txt      (alloc,used,m[B,N], dt[B]) -> (gbs[B],)
  manifest.json                  shapes + entry metadata for the rust side

Run once at build time (`make artifacts`); never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import ols


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def lower_all(outdir: str, b: int, n: int, pb: int) -> dict:
    entries = []

    def emit(name: str, fn, specs, inputs, outputs):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": inputs,
                "outputs": outputs,
            }
        )
        print(f"  {fname}: {len(text)} chars")

    # Fit and fused fit+predict come in two observation buckets: the
    # small one covers typical training histories (n <= 64) at ~1/8 the
    # cost; the rust runtime selects per call.
    for nn in sorted({ols.FIT_N_SMALL, n}):
        emit(
            f"fit_b{b}_n{nn}",
            model.fit_model,
            [_spec(b, nn)] * 3,
            [{"shape": [b, nn]}] * 3,
            [{"shape": [b, 2]}],
        )
        emit(
            f"fit_predict_b{b}_n{nn}",
            model.fit_predict_model,
            [_spec(b, nn)] * 3 + [_spec(b), _spec(b)],
            [{"shape": [b, nn]}] * 3 + [{"shape": [b]}, {"shape": [b]}],
            [{"shape": [b]}, {"shape": [b, 2]}],
        )
    emit(
        f"predict_b{pb}",
        model.predict_model,
        [_spec(pb, 2), _spec(pb), _spec(pb)],
        [{"shape": [pb, 2]}, {"shape": [pb]}, {"shape": [pb]}],
        [{"shape": [pb]}],
    )
    emit(
        f"wastage_b{b}_n{n}",
        model.wastage_model,
        [_spec(b, n)] * 3 + [_spec(b)],
        [{"shape": [b, n]}] * 3 + [{"shape": [b]}],
        [{"shape": [b]}],
    )
    k = ols.PLAN_K
    emit(
        f"plan_wastage_b{b}_n{n}_k{k}",
        model.plan_wastage_model,
        [_spec(b, k), _spec(b, k), _spec(b, n), _spec(b, n), _spec(b)],
        [{"shape": [b, k]}] * 2 + [{"shape": [b, n]}] * 2 + [{"shape": [b]}],
        [{"shape": [b]}],
    )
    return {
        "buckets": {
            "fit_b": b,
            "fit_n": n,
            "fit_n_small": min(ols.FIT_N_SMALL, n),
            "predict_b": pb,
            "plan_k": k,
        },
        "block_b": ols.BLOCK_B,
        "entries": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--fit-b", type=int, default=ols.FIT_B)
    ap.add_argument("--fit-n", type=int, default=ols.FIT_N)
    ap.add_argument("--predict-b", type=int, default=ols.PREDICT_B)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    manifest = lower_all(args.outdir, args.fit_b, args.fit_n, args.predict_b)
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
