//! Execution simulator: replays recorded/synthetic memory traces against
//! allocation plans with Linux-OOM-killer semantics and the predictor's
//! retry loop — the substrate behind every Fig 6/7/8 number.
//!
//! `cluster` adds the discrete-event multi-node scheduler used by the
//! `simulate` subcommand and the online example to translate memory
//! efficiency into cluster throughput.

pub mod cluster;
pub mod dag;

use crate::metrics::TaskOutcome;
use crate::predictor::Predictor;
use crate::segments::StepPlan;
use crate::trace::Execution;

/// Maximum retries before the simulator falls back to a full-capacity
/// allocation (a real SWMS would page an operator at this point).
pub const MAX_RETRIES: usize = 10;

/// One attempt's outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct Attempt {
    pub plan: StepPlan,
    /// OOM time, seconds; `None` == success.
    pub fail_time: Option<f64>,
    /// Wastage contributed by this attempt, GB*s.
    pub wastage_gbs: f64,
}

/// Simulate one task instance: run the predictor's plan against the
/// trace, applying the OOM killer (usage > allocation at any sample) and
/// the predictor's retry strategy until success or `max_retries`.
///
/// The trace is replayed identically on retry — the paper's evaluation
/// (and any deterministic task) behaves the same way.
pub fn run_task(pred: &dyn Predictor, e: &Execution, max_retries: usize) -> (TaskOutcome, Vec<Attempt>) {
    let mut attempts = Vec::new();
    let mut plan = pred.plan(e.input_mb).clamped(pred.capacity());
    let mut wastage = 0.0;
    let mut success = false;
    let mut alloc_gbs = 0.0;

    for attempt_no in 0..=max_retries {
        match plan.first_oom(e) {
            None => {
                let w = plan.wastage_gbs(e);
                wastage += w;
                alloc_gbs = plan.alloc_gbs(e.duration());
                attempts.push(Attempt { plan: plan.clone(), fail_time: None, wastage_gbs: w });
                success = true;
                break;
            }
            Some((t_fail, _used)) => {
                // A failed attempt wastes everything it allocated until
                // the OOM kill (the partial work is discarded).
                let w = plan.alloc_gbs(t_fail.max(e.dt));
                wastage += w;
                attempts.push(Attempt {
                    plan: plan.clone(),
                    fail_time: Some(t_fail),
                    wastage_gbs: w,
                });
                if attempt_no == max_retries {
                    break;
                }
                plan = if attempt_no + 1 == max_retries {
                    // Last resort: machine maximum.
                    StepPlan::flat(pred.capacity())
                } else {
                    pred.on_failure(&plan, t_fail, attempt_no + 1).clamped(pred.capacity())
                };
            }
        }
    }

    let outcome = TaskOutcome {
        task: e.task.clone(),
        input_mb: e.input_mb,
        attempts: attempts.len(),
        success,
        wastage_gbs: wastage,
        alloc_gbs,
        used_gbs: e.used_gbs(),
    };
    (outcome, attempts)
}

/// Allocation-lean variant of [`run_task`] for high-volume replay (the
/// scenario engine streams millions of executions through this): the
/// identical OOM/retry loop and accounting, but no per-attempt log and no
/// plan clones beyond what the retry strategy itself returns.
pub fn run_task_outcome(pred: &dyn Predictor, e: &Execution, max_retries: usize) -> TaskOutcome {
    let mut plan = pred.plan(e.input_mb).clamped(pred.capacity());
    let mut wastage = 0.0;
    let mut success = false;
    let mut alloc_gbs = 0.0;
    let mut attempts = 0usize;

    for attempt_no in 0..=max_retries {
        attempts += 1;
        match plan.first_oom(e) {
            None => {
                wastage += plan.wastage_gbs(e);
                alloc_gbs = plan.alloc_gbs(e.duration());
                success = true;
                break;
            }
            Some((t_fail, _used)) => {
                wastage += plan.alloc_gbs(t_fail.max(e.dt));
                if attempt_no == max_retries {
                    break;
                }
                plan = if attempt_no + 1 == max_retries {
                    StepPlan::flat(pred.capacity())
                } else {
                    pred.on_failure(&plan, t_fail, attempt_no + 1).clamped(pred.capacity())
                };
            }
        }
    }

    TaskOutcome {
        task: e.task.clone(),
        input_mb: e.input_mb,
        attempts,
        success,
        wastage_gbs: wastage,
        alloc_gbs,
        used_gbs: e.used_gbs(),
    }
}

/// Run a whole test set through a trained predictor.
pub fn run_all(pred: &dyn Predictor, test: &[Execution]) -> Vec<TaskOutcome> {
    test.iter().map(|e| run_task(pred, e, MAX_RETRIES).0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::DefaultLimits;
    use crate::util::prop::run_prop;

    /// Minimal scripted predictor for testing the loop mechanics.
    struct Scripted {
        first: StepPlan,
        retries: Vec<StepPlan>,
    }

    impl Predictor for Scripted {
        fn name(&self) -> &'static str {
            "scripted"
        }
        fn train(&mut self, _h: &[Execution]) {}
        fn plan(&self, _i: f64) -> StepPlan {
            self.first.clone()
        }
        fn on_failure(&self, _p: &StepPlan, _t: f64, attempt: usize) -> StepPlan {
            self.retries[(attempt - 1).min(self.retries.len() - 1)].clone()
        }
    }

    fn exec(samples: Vec<f64>, dt: f64) -> Execution {
        Execution::new("t", 100.0, dt, samples)
    }

    #[test]
    fn success_first_try_wastage() {
        let e = exec(vec![1.0, 1.0, 3.0], 1.0);
        let p = Scripted { first: StepPlan::flat(4.0), retries: vec![] };
        let (o, attempts) = run_task(&p, &e, 5);
        assert!(o.success);
        assert_eq!(o.attempts, 1);
        // waste = (3 + 3 + 1) * 1 = 7
        assert!((o.wastage_gbs - 7.0).abs() < 1e-12);
        assert_eq!(attempts[0].fail_time, None);
        assert!((o.alloc_gbs - 12.0).abs() < 1e-12);
        assert!((o.used_gbs - 5.0).abs() < 1e-12);
    }

    #[test]
    fn failure_costs_full_allocation() {
        let e = exec(vec![1.0, 5.0, 5.0], 1.0);
        let p = Scripted {
            first: StepPlan::flat(2.0),
            retries: vec![StepPlan::flat(6.0)],
        };
        let (o, attempts) = run_task(&p, &e, 5);
        assert!(o.success);
        assert_eq!(o.attempts, 2);
        assert_eq!(attempts[0].fail_time, Some(1.0));
        // Attempt 1: OOM at t=1, alloc 2 GB for 1 s = 2 GBs wasted.
        assert!((attempts[0].wastage_gbs - 2.0).abs() < 1e-12);
        // Attempt 2: alloc 6, used 1+5+5 -> waste (5+1+1)*1 = 7.
        assert!((attempts[1].wastage_gbs - 7.0).abs() < 1e-12);
        assert!((o.wastage_gbs - 9.0).abs() < 1e-12);
    }

    #[test]
    fn oom_at_t0_charges_at_least_one_sample() {
        let e = exec(vec![5.0, 5.0], 1.0);
        let p = Scripted { first: StepPlan::flat(1.0), retries: vec![StepPlan::flat(8.0)] };
        let (o, attempts) = run_task(&p, &e, 5);
        assert!(o.success);
        assert!(attempts[0].wastage_gbs > 0.0, "zero-cost failed attempt");
    }

    #[test]
    fn gives_up_after_max_retries() {
        // Usage exceeds even capacity: never succeeds.
        let e = exec(vec![500.0], 1.0);
        let p = DefaultLimits::with_limit(128.0, 4.0);
        let (o, attempts) = run_task(&p, &e, 3);
        assert!(!o.success);
        assert_eq!(o.attempts, 4); // initial + 3 retries
        assert!(attempts.iter().all(|a| a.fail_time.is_some()));
    }

    #[test]
    fn penultimate_retry_falls_back_to_capacity() {
        // A predictor whose retries never help must still succeed via the
        // capacity fallback as long as the task fits the machine.
        let e = exec(vec![100.0, 100.0], 1.0);
        let p = Scripted {
            first: StepPlan::flat(1.0),
            retries: vec![StepPlan::flat(1.1); 20],
        };
        let (o, _) = run_task(&p, &e, 5);
        assert!(o.success, "capacity fallback must cover a 100 GB task");
    }

    #[test]
    fn monotone_retry_makes_progress() {
        // Doubling retry on a tall narrow spike converges quickly.
        let e = exec(vec![1.0, 1.0, 30.0, 1.0], 1.0);
        let p = DefaultLimits::with_limit(128.0, 4.0);
        let (o, _) = run_task(&p, &e, 10);
        assert!(o.success);
        assert_eq!(o.attempts, 4); // 4 -> 8 -> 16 -> 32
    }

    #[test]
    fn prop_wastage_nonnegative_and_consistent() {
        run_prop("sim_wastage_consistency", 150, |rng| {
            let n = 1 + rng.below(100);
            let samples: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 20.0)).collect();
            let e = exec(samples, rng.uniform(0.5, 3.0));
            let limit = rng.uniform(0.5, 24.0);
            let p = DefaultLimits::with_limit(128.0, limit);
            let (o, attempts) = run_task(&p, &e, MAX_RETRIES);
            assert!(o.wastage_gbs >= -1e-9);
            assert!(o.success, "must succeed under 128 GB capacity");
            // Total equals sum of attempts.
            let sum: f64 = attempts.iter().map(|a| a.wastage_gbs).sum();
            assert!((sum - o.wastage_gbs).abs() < 1e-9);
            // The successful attempt covers the trace.
            assert!(attempts.last().unwrap().plan.covers(&e));
            // Success wastage >= alloc - used exactly.
            let last = attempts.last().unwrap();
            let expect = last.plan.wastage_gbs(&e);
            assert!((last.wastage_gbs - expect).abs() < 1e-9);
        });
    }

    #[test]
    fn prop_run_task_outcome_matches_run_task() {
        // The lean variant must be observationally identical, including
        // never-succeeding executions that exhaust the retry budget.
        run_prop("run_task_outcome_parity", 80, |rng| {
            let n = 1 + rng.below(60);
            let samples: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 200.0)).collect();
            let e = exec(samples, rng.uniform(0.5, 2.0));
            let limit = rng.uniform(0.5, 8.0);
            let p = DefaultLimits::with_limit(128.0, limit);
            assert_eq!(run_task_outcome(&p, &e, MAX_RETRIES), run_task(&p, &e, MAX_RETRIES).0);
            assert_eq!(run_task_outcome(&p, &e, 2), run_task(&p, &e, 2).0);
        });
    }

    #[test]
    fn run_all_matches_individual() {
        let e1 = exec(vec![1.0, 2.0], 1.0);
        let e2 = exec(vec![3.0, 8.0], 1.0);
        let p = DefaultLimits::with_limit(128.0, 4.0);
        let all = run_all(&p, &[e1.clone(), e2.clone()]);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0], run_task(&p, &e1, MAX_RETRIES).0);
        assert_eq!(all[1], run_task(&p, &e2, MAX_RETRIES).0);
    }
}
