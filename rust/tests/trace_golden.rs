//! Golden ingestion conformance for the committed nf-core-shaped
//! long-form monitoring CSV: parse -> summarize must reproduce the
//! committed per-task peak/duration table **bit-exactly** (floats are
//! rendered with `{:?}`, Rust's shortest-roundtrip form), so importer
//! refactors can't silently shift the figures derived from real traces.
//!
//! The CSV is constructed so every derived float is a dyadic rational
//! (rss multiples of 0.25 GB, inputs multiples of 1 MB, 1000 ms grid):
//! every division, sum, mean, and interpolated median is exact in IEEE
//! double, which is what makes a bit-exact pin meaningful.

use std::fmt::Write as _;
use std::path::Path;

use ksplus::trace::workflow::summarize;
use ksplus::trace::{load_csv_auto, nextflow};

const CSV: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/../golden/traces/nfcore_rnaseq_sample.csv");
const EXPECTED: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/../golden/traces/expected_summary.txt");

#[test]
fn golden_trace_ingestion_is_bit_exact() {
    let trace = load_csv_auto(Path::new(CSV), "nfcore_rnaseq_sample").unwrap();
    let mut got = String::new();
    got.push_str("task,instance,input_mb,dt,samples,peak_gb,duration_s,used_gbs\n");
    for t in &trace.tasks {
        for (i, e) in t.executions.iter().enumerate() {
            writeln!(
                got,
                "{},{},{:?},{:?},{},{:?},{:?},{:?}",
                t.task,
                i,
                e.input_mb,
                e.dt,
                e.samples.len(),
                e.peak(),
                e.duration(),
                e.used_gbs()
            )
            .unwrap();
        }
    }
    got.push_str("task,instances,mean_peak_gb,median_peak_gb,max_peak_gb\n");
    for s in summarize(&trace) {
        writeln!(
            got,
            "{},{},{:?},{:?},{:?}",
            s.task, s.instances, s.mean_peak_gb, s.median_peak_gb, s.max_peak_gb
        )
        .unwrap();
    }
    let want = std::fs::read_to_string(EXPECTED).unwrap();
    assert_eq!(
        got, want,
        "golden trace summary drifted; if the importer change is intentional, \
         update golden/traces/expected_summary.txt"
    );
}

#[test]
fn auto_loader_matches_long_form_reader() {
    let via_auto = load_csv_auto(Path::new(CSV), "x").unwrap();
    let direct = nextflow::read_long_csv(Path::new(CSV), "x").unwrap();
    assert_eq!(via_auto.tasks.len(), direct.tasks.len());
    for (a, b) in via_auto.tasks.iter().zip(&direct.tasks) {
        assert_eq!(a.task, b.task);
        assert_eq!(a.executions, b.executions);
    }
}
