//! `RemoteClient`: typed TCP client for the coordinator's wire v1 —
//! the counterpart of the in-process `service::Client`, sharing the
//! exact `Request`/`Response` types of `coordinator::protocol` with the
//! server, so client and server cannot drift.
//!
//! One request/response pair per call, newline-delimited JSON over a
//! persistent connection. Server-side errors surface as the structured
//! `WireError` (`code: message` via its `Display`) wrapped in
//! `anyhow::Error`.
//!
//! ```no_run
//! # use ksplus::coordinator::remote::RemoteClient;
//! # use ksplus::coordinator::PredictorPolicy;
//! # fn main() -> anyhow::Result<()> {
//! let mut rc = RemoteClient::connect("127.0.0.1:7070")?;
//! let info = rc.hello()?;
//! rc.configure(Some("bwa"), PredictorPolicy::WittLr)?;
//! let out = rc.plan("bwa", 8000.0)?;
//! println!("served by {} (v{})", out.predictor, out.model_version);
//! # Ok(())
//! # }
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::protocol::{
    ObserveAck, Request, Response, ServerInfo, StatsSummary, WireError, WIRE_VERSION,
};
use crate::coordinator::{PlanOutcome, PredictorPolicy, RetryOutcome};
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::json::Json;

pub struct RemoteClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl RemoteClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<RemoteClient> {
        let stream = TcpStream::connect(addr).context("connect to coordinator")?;
        RemoteClient::from_stream(stream)
    }

    /// Like [`connect`](RemoteClient::connect), but bounds both the TCP
    /// connect and every subsequent response read by `timeout` — a hung
    /// or unreachable coordinator fails the call instead of blocking the
    /// workflow engine forever.
    pub fn connect_with_timeout<A: ToSocketAddrs>(
        addr: A,
        timeout: Duration,
    ) -> Result<RemoteClient> {
        let resolved = addr
            .to_socket_addrs()
            .context("resolve coordinator address")?
            .next()
            .ok_or_else(|| anyhow::anyhow!("coordinator address resolved to nothing"))?;
        let stream = TcpStream::connect_timeout(&resolved, timeout)
            .with_context(|| format!("connect to coordinator at {resolved}"))?;
        let mut rc = RemoteClient::from_stream(stream)?;
        rc.set_read_timeout(Some(timeout))?;
        Ok(rc)
    }

    fn from_stream(stream: TcpStream) -> Result<RemoteClient> {
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().context("clone coordinator stream")?;
        Ok(RemoteClient { reader: BufReader::new(stream), writer })
    }

    /// Bound every response read. A read that times out leaves the
    /// connection mid-frame — treat the client as dead and reconnect.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout).context("set read timeout")
    }

    /// Send one raw line and parse the reply as JSON. Escape hatch for
    /// conformance tests that need to ship intentionally malformed
    /// requests; typed callers use the op methods below.
    pub fn raw(&mut self, line: &str) -> Result<Json> {
        writeln!(self.writer, "{line}").context("write request")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp).context("read response")?;
        anyhow::ensure!(!resp.is_empty(), "server closed the connection");
        Json::parse(&resp).map_err(|e| anyhow::anyhow!("unparseable response: {e}"))
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        let j = self.raw(&req.to_json().to_string())?;
        Response::from_json(&j, req.op()).map_err(report_wire_error)
    }

    /// Version/capability negotiation. Call once after connecting; fails
    /// if the server cannot speak wire v1.
    pub fn hello(&mut self) -> Result<ServerInfo> {
        match self.call(&Request::Hello {
            client: Some("ksplus-remote-client".into()),
            min_version: Some(WIRE_VERSION),
            max_version: Some(WIRE_VERSION),
        })? {
            Response::Hello(info) => Ok(info),
            other => anyhow::bail!("unexpected response to hello: {other:?}"),
        }
    }

    /// Bind a task (or, with `None`, the service-wide default) to a
    /// predictor policy.
    pub fn configure(&mut self, task: Option<&str>, policy: PredictorPolicy) -> Result<()> {
        match self.call(&Request::Configure { task: task.map(str::to_string), policy })? {
            Response::Configured { .. } => Ok(()),
            other => anyhow::bail!("unexpected response to configure: {other:?}"),
        }
    }

    /// Batch-train the task; returns the number of executions shipped.
    pub fn train(&mut self, task: &str, history: &[Execution]) -> Result<u64> {
        match self.call(&Request::Train { task: task.to_string(), history: history.to_vec() })? {
            Response::Trained { executions, .. } => Ok(executions),
            other => anyhow::bail!("unexpected response to train: {other:?}"),
        }
    }

    /// Fold one finished execution into the task's models.
    pub fn observe(&mut self, task: &str, execution: &Execution) -> Result<ObserveAck> {
        match self.call(&Request::Observe {
            task: task.to_string(),
            execution: execution.clone(),
        })? {
            Response::Observed(ack) => Ok(ack),
            other => anyhow::bail!("unexpected response to observe: {other:?}"),
        }
    }

    /// Request an allocation plan; the outcome carries provenance.
    pub fn plan(&mut self, task: &str, input_mb: f64) -> Result<PlanOutcome> {
        match self.call(&Request::Plan { task: task.to_string(), input_mb })? {
            Response::Planned(out) => Ok(out),
            other => anyhow::bail!("unexpected response to plan: {other:?}"),
        }
    }

    /// Report an OOM. With `task`, the retry uses that task's bound
    /// policy; without, the KS+ segment-rescaling strategy.
    pub fn report_failure(
        &mut self,
        task: Option<&str>,
        plan: &StepPlan,
        fail_time: f64,
    ) -> Result<RetryOutcome> {
        match self.call(&Request::Failure {
            task: task.map(str::to_string),
            plan: plan.clone(),
            fail_time,
        })? {
            Response::Retry(r) => Ok(r),
            other => anyhow::bail!("unexpected response to failure: {other:?}"),
        }
    }

    /// Merged service counters across every shard.
    pub fn stats(&mut self) -> Result<StatsSummary> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => anyhow::bail!("unexpected response to stats: {other:?}"),
        }
    }

    /// Dump the server's full model state as a restorable snapshot
    /// document (admin op; check `hello().ops` for `"snapshot"`).
    pub fn snapshot(&mut self) -> Result<Json> {
        match self.call(&Request::Snapshot)? {
            Response::Snapshot { doc } => Ok(doc),
            other => anyhow::bail!("unexpected response to snapshot: {other:?}"),
        }
    }

    /// Resize the server's worker pool to `shards` workers; returns the
    /// live shard ids after the resize (admin op; check `hello().ops`
    /// for `"reshard"`).
    pub fn reshard(&mut self, shards: usize) -> Result<Vec<usize>> {
        match self.call(&Request::Reshard { shards })? {
            Response::Resharded { shard_ids } => Ok(shard_ids),
            other => anyhow::bail!("unexpected response to reshard: {other:?}"),
        }
    }
}

fn report_wire_error(e: WireError) -> anyhow::Error {
    // The blanket std-error conversion keeps "{code}: {message}".
    anyhow::Error::from(e)
}
