//! Online memory-prediction service: the deployment surface a workflow
//! engine (Nextflow/Airflow/Snakemake) would call before submitting each
//! task to the resource manager.
//!
//! Architecture (std threads + channels; see DESIGN.md Section 5b). The
//! coordinator is a pool of `shards` identical workers; every worker
//! owns its own model store, numeric backend, and dynamic batcher:
//!
//! ```text
//!                ┌─hash(task)──▶ worker 0 (store + backend + batcher)
//!   clients ──┬──┤              worker 1 (store + backend + batcher)
//!             │  └─hash(task)──▶ ...
//!             │                 worker N-1 (store + backend + batcher)
//!             │   each worker:
//!             │     ├─ Train    : batched OLS fit (2k rows/task)
//!             │     ├─ Plan     : dynamic batcher — collects up to
//!             │     │             `batch_max` requests or `batch_delay`,
//!             │     │             then ONE batched predict over the
//!             │     │             queued task×segment models
//!             │     └─ Failure  : KS+ segment-rescaling retry
//!             │                   (stateless; round-robin over shards)
//!             └──fan-out───────▶ Stats : merged across every shard
//! ```
//!
//! `Train` and `Plan` route by a deterministic FNV-1a hash of the task
//! name (`service::shard_for`), so one shard owns each task's models and
//! its plan traffic; `shards: 1` (the default) reproduces the original
//! single-worker coordinator. Each per-shard batcher is the L3 hot path:
//! with the `pjrt` cargo feature every flush is a single PJRT execution
//! of `predict_b{B}.hlo.txt` covering every queued request's 2k
//! regression evaluations; in default (native-only) builds the same
//! flush runs the closed-form OLS in-process. The Python stack is never
//! invoked either way.

pub mod server;
pub mod service;

use crate::predictor::ksplus::{KsPlus, MEM_OVERPREDICT, TIME_UNDERPREDICT};
use crate::predictor::regression::{FitEngine, LinModel, NativeFit};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::segments::StepPlan;
use crate::trace::Execution;

/// Numeric backend for the coordinator. PJRT handles are thread-affine
/// (`Rc`): the service constructs its backend *inside* the worker thread
/// from a `BackendSpec`. The PJRT variant only exists when the crate is
/// compiled with the `pjrt` feature; `Backend::Native` is always there.
#[derive(Clone)]
pub enum Backend {
    /// In-process closed form (tests, environments without artifacts).
    Native,
    /// AOT Pallas kernels through PJRT (production path, `pjrt` feature).
    #[cfg(feature = "pjrt")]
    Pjrt(std::rc::Rc<Runtime>),
}

/// Send-able description of a backend, resolved on the worker thread.
///
/// `BackendSpec::Pjrt` is always available to *describe* — callers such
/// as the CLI and the wire protocol compile unchanged either way — but
/// `build()` returns a runtime error in a native-only build.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    Native,
    /// Load artifacts from this directory (or the default location).
    Pjrt(Option<std::path::PathBuf>),
}

impl BackendSpec {
    /// Whether this spec can be built in this binary (the native backend
    /// always can; PJRT needs the `pjrt` cargo feature).
    pub fn available(&self) -> bool {
        match self {
            BackendSpec::Native => true,
            BackendSpec::Pjrt(_) => cfg!(feature = "pjrt"),
        }
    }

    pub fn build(&self) -> anyhow::Result<Backend> {
        match self {
            BackendSpec::Native => Ok(Backend::Native),
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt(dir) => {
                let dir = dir
                    .clone()
                    .unwrap_or_else(crate::runtime::default_artifacts_dir);
                Ok(Backend::Pjrt(std::rc::Rc::new(Runtime::load(&dir)?)))
            }
            #[cfg(not(feature = "pjrt"))]
            BackendSpec::Pjrt(_) => anyhow::bail!(
                "the PJRT backend was not compiled into this binary; rebuild \
                 with `cargo build --features pjrt`, or use BackendSpec::Native"
            ),
        }
    }
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    fn fit(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Vec<LinModel> {
        match self {
            Backend::Native => NativeFit.fit_batch(rows),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.fit_batch(rows).expect("PJRT fit"),
        }
    }

    fn predict(&self, models: &[LinModel], xq: &[f64], scale: &[f64]) -> Vec<f64> {
        match self {
            Backend::Native => models
                .iter()
                .zip(xq.iter().zip(scale))
                .map(|(m, (x, s))| (m.predict(*x) * s).max(0.0))
                .collect(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.predict_batch(models, xq, scale).expect("PJRT predict"),
        }
    }
}

/// Per-task fitted segment models.
#[derive(Debug, Clone)]
pub struct TaskModels {
    pub start_models: Vec<LinModel>,
    pub peak_models: Vec<LinModel>,
    /// Highest peak seen in training (fallback allocation).
    pub fallback_peak: f64,
}

/// Model store + pure prediction logic, shared by the threaded service
/// and the batch experiment path.
pub struct ModelStore {
    pub k: usize,
    pub capacity_gb: f64,
    backend: Backend,
    models: std::collections::BTreeMap<String, TaskModels>,
}

impl ModelStore {
    pub fn new(k: usize, capacity_gb: f64, backend: Backend) -> Self {
        ModelStore { k, capacity_gb, backend, models: Default::default() }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn has_task(&self, task: &str) -> bool {
        self.models.contains_key(task)
    }

    pub fn tasks(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Train (or retrain) one task from its history: one batched fit of
    /// 2k regression rows.
    pub fn train(&mut self, task: &str, history: &[Execution]) {
        if history.is_empty() {
            return;
        }
        let rows = KsPlus::regression_rows(self.k, history);
        let fitted = self.backend.fit(&rows);
        let fallback_peak = history.iter().map(|e| e.peak()).fold(0.0, f64::max).max(0.1);
        self.models.insert(
            task.to_string(),
            TaskModels {
                start_models: fitted[..self.k].to_vec(),
                peak_models: fitted[self.k..].to_vec(),
                fallback_peak,
            },
        );
    }

    /// Plan a batch of requests with ONE backend predict call.
    /// Unknown tasks get a capacity-safe flat fallback.
    pub fn plan_batch(&self, requests: &[(String, f64)]) -> Vec<StepPlan> {
        // Gather rows for known tasks.
        let mut models = Vec::with_capacity(requests.len() * 2 * self.k);
        let mut xq = Vec::with_capacity(models.capacity());
        let mut scale = Vec::with_capacity(models.capacity());
        let mut known = Vec::with_capacity(requests.len());
        for (task, input) in requests {
            match self.models.get(task) {
                None => known.push(false),
                Some(tm) => {
                    known.push(true);
                    for m in &tm.start_models {
                        models.push(*m);
                        xq.push(*input);
                        scale.push(TIME_UNDERPREDICT);
                    }
                    for m in &tm.peak_models {
                        models.push(*m);
                        xq.push(*input);
                        scale.push(MEM_OVERPREDICT);
                    }
                }
            }
        }
        let flat = self.backend.predict(&models, &xq, &scale);
        let mut out = Vec::with_capacity(requests.len());
        let mut off = 0usize;
        for (i, (task, _)) in requests.iter().enumerate() {
            if !known[i] {
                let peak = self
                    .models
                    .get(task)
                    .map(|m| m.fallback_peak)
                    .unwrap_or(self.capacity_gb / 4.0);
                out.push(StepPlan::flat(peak.min(self.capacity_gb)));
                continue;
            }
            let starts = &flat[off..off + self.k];
            let peaks = &flat[off + self.k..off + 2 * self.k];
            off += 2 * self.k;
            // Offsets already applied via `scale`; pass identity here.
            out.push(KsPlus::assemble_plan(starts, peaks, 1.0, 1.0, self.capacity_gb));
        }
        out
    }

    /// KS+ retry strategy (Section II-C) for a reported OOM.
    pub fn on_failure(&self, prev: &StepPlan, fail_time: f64) -> StepPlan {
        // Stateless plan math: delegate to a throwaway KsPlus with our
        // capacity. (The strategy uses no trained state.)
        use crate::predictor::Predictor;
        KsPlus::new(self.k, self.capacity_gb).on_failure(prev, fail_time, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::Predictor;
    use crate::util::rng::Rng;

    fn two_phase_exec(input: f64, rng: &mut Rng) -> Execution {
        let d1 = ((input * 0.01) as usize).max(2);
        let d2 = ((input * 0.003) as usize).max(1);
        let mut s = vec![input * 0.0005; d1];
        s.extend(vec![input * 0.001; d2]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new("bwa", input, 1.0, s)
    }

    #[test]
    fn backend_spec_availability_tracks_feature() {
        assert!(BackendSpec::Native.available());
        assert_eq!(BackendSpec::Pjrt(None).available(), cfg!(feature = "pjrt"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_is_runtime_error_without_feature() {
        let err = BackendSpec::Pjrt(None).build().err().expect("must not build");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
    }

    #[test]
    fn store_matches_ksplus_predictor() {
        let mut rng = Rng::new(1);
        let hist: Vec<Execution> =
            (0..30).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let mut pred = KsPlus::new(2, 128.0);
        pred.train(&hist);
        let plans = store.plan_batch(&[("bwa".into(), 8000.0)]);
        let want = pred.plan(8000.0);
        assert_eq!(plans[0].k(), want.k());
        for i in 0..want.k() {
            assert!((plans[0].starts[i] - want.starts[i]).abs() < 1e-9, "{plans:?} vs {want:?}");
            assert!((plans[0].peaks[i] - want.peaks[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn unknown_task_gets_fallback() {
        let store = ModelStore::new(2, 128.0, Backend::Native);
        let plans = store.plan_batch(&[("mystery".into(), 100.0)]);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].k(), 1);
        assert!(plans[0].peaks[0] <= 128.0);
    }

    #[test]
    fn batch_of_mixed_tasks() {
        let mut rng = Rng::new(2);
        let hist: Vec<Execution> =
            (0..20).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let reqs: Vec<(String, f64)> = vec![
            ("bwa".into(), 4000.0),
            ("mystery".into(), 1.0),
            ("bwa".into(), 8000.0),
        ];
        let plans = store.plan_batch(&reqs);
        assert_eq!(plans.len(), 3);
        assert!(plans[0].peaks.last() < plans[2].peaks.last());
        assert!(plans.iter().all(|p| p.is_valid()));
    }

    #[test]
    fn failure_rescaling_delegates_to_ksplus() {
        let store = ModelStore::new(2, 128.0, Backend::Native);
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let next = store.on_failure(&prev, 60.0);
        assert_eq!(next.starts, vec![0.0, 60.0]);
    }

    #[test]
    fn retrain_replaces_models() {
        let mut rng = Rng::new(3);
        let h1: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(3000.0, &mut rng)).collect();
        let h2: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(9000.0, &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &h1);
        let p1 = store.plan_batch(&[("bwa".into(), 5000.0)]);
        store.train("bwa", &h2);
        let p2 = store.plan_batch(&[("bwa".into(), 5000.0)]);
        // Different training data -> different (still valid) plans.
        assert!(p1[0].is_valid() && p2[0].is_valid());
    }
}
