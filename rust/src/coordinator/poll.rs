//! Hand-rolled readiness abstraction for the event-loop server: one
//! small `Poller` over **epoll** (Linux/Android) or **kqueue**
//! (macOS/iOS), with a stub that reports `Unsupported` elsewhere (the
//! CLI falls back to the threaded server there). Dependencies are
//! vendored in this workspace, so there is no tokio/mio — the two
//! syscall surfaces are tiny and declared directly.
//!
//! Semantics are deliberately the intersection of the two APIs:
//!
//! * **Level-triggered**: readiness is re-reported while it holds, so
//!   the loop may leave bytes unread in the kernel buffer without
//!   losing the connection (kqueue is naturally level-triggered;
//!   epoll is used without `EPOLLET`).
//! * One `usize` token per fd, echoed back in each [`Event`].
//! * Error/hangup conditions surface as `readable` so the owner's next
//!   read observes the actual `io::Error`/EOF — the loop has one error
//!   path, not two.
//!
//! [`Waker`] lets dispatch worker threads interrupt a blocked
//! [`Poller::wait`]: it is the read end of a socketpair registered like
//! any connection (no pipe/eventfd FFI needed — `UnixStream::pair` is
//! std).

use std::io::{self, Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::time::Duration;

/// One readiness report: the registered token plus which directions
/// are ready.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

/// Readiness selector. All methods take `&self`; registration state
/// lives in the kernel.
pub struct Poller {
    sys: sys::Selector,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { sys: sys::Selector::new()? })
    }

    /// Register `fd` with interest in `readable`/`writable` readiness.
    pub fn register(
        &self,
        fd: RawFd,
        token: usize,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.sys.register(fd, token, readable, writable)
    }

    /// Change an existing registration's interests (cheaper than
    /// deregister + register; used to toggle write interest as the
    /// write buffer fills and drains).
    pub fn reregister(
        &self,
        fd: RawFd,
        token: usize,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.sys.reregister(fd, token, readable, writable)
    }

    /// Remove `fd` entirely. Call before closing the fd — close-time
    /// auto-cleanup is not portable across the two backends.
    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.sys.deregister(fd)
    }

    /// Block until readiness or `timeout` (`None` = forever), appending
    /// to `events` (cleared first). Spurious empty returns are allowed.
    pub fn wait(&self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        self.sys.wait(events, timeout)
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        self.sys.close();
    }
}

/// Wake handle for a blocked [`Poller::wait`]: any thread calls
/// [`Waker::wake`]; the loop sees the paired receive end readable and
/// drains it. Writes are nonblocking and best-effort — once the pair's
/// buffer holds a byte the loop is already due to wake, so a
/// `WouldBlock` here is success.
pub struct Waker {
    tx: UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// Build a waker and its receive end. The caller registers the receive
/// end's fd with the poller and calls [`drain_waker`] whenever it polls
/// readable.
pub fn waker_pair() -> io::Result<(Waker, UnixStream)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { tx }, rx))
}

/// Swallow every pending wake byte so the next `wake()` is visible.
pub fn drain_waker(rx: &UnixStream) {
    let mut buf = [0u8; 64];
    while let Ok(n) = (&*rx).read(&mut buf) {
        if n == 0 {
            break;
        }
    }
}

/// Cap a `Duration` into the millisecond int epoll takes, rounding up
/// so a short timeout cannot spin at zero.
#[cfg(any(target_os = "linux", target_os = "android"))]
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis();
            if d.subsec_nanos() % 1_000_000 != 0 {
                (ms + 1).min(i32::MAX as u128) as i32
            } else {
                ms.min(i32::MAX as u128) as i32
            }
        }
    }
}

#[cfg(any(target_os = "linux", target_os = "android"))]
mod sys {
    use super::{timeout_ms, Event};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    // epoll's event struct is packed on x86-64 only (a 32-bit mask
    // followed by a 64-bit payload with no padding); other Linux
    // targets use natural alignment.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Selector {
        epfd: i32,
    }

    impl Selector {
        pub fn new() -> io::Result<Selector> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Selector { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, readable: bool, writable: bool, token: usize) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: if readable { EPOLLIN } else { 0 } | if writable { EPOLLOUT } else { 0 },
                data: token as u64,
            };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&self, fd: RawFd, token: usize, r: bool, w: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, r, w, token)
        }

        pub fn reregister(&self, fd: RawFd, token: usize, r: bool, w: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, r, w, token)
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            // The event argument must be non-null on pre-2.6.9 kernels;
            // passing one is harmless everywhere.
            self.ctl(EPOLL_CTL_DEL, fd, false, false, 0)
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let mut buf = [EpollEvent { events: 0, data: 0 }; 1024];
            let n = unsafe {
                epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms(timeout))
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(()); // spurious empty wake
                }
                return Err(e);
            }
            for ev in buf.iter().take(n as usize) {
                // Copy packed fields by value before use.
                let mask = ev.events;
                let data = ev.data;
                out.push(Event {
                    token: data as usize,
                    // Error/hangup surfaces as readable: the owner's
                    // next read sees the real error or EOF.
                    readable: mask & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: mask & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }

        pub fn close(&self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(any(target_os = "macos", target_os = "ios"))]
mod sys {
    use super::Event;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::ptr;
    use std::time::Duration;

    // The macOS/iOS kevent ABI. (FreeBSD's differs — 64-bit fflags and
    // an ext array — which is why this arm is Apple-only and other BSDs
    // get the stub.)
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Kevent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: *mut core::ffi::c_void,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }

    const EVFILT_READ: i16 = -1;
    const EVFILT_WRITE: i16 = -2;
    const EV_ADD: u16 = 0x1;
    const EV_DELETE: u16 = 0x2;
    const EV_ENABLE: u16 = 0x4;
    const EV_DISABLE: u16 = 0x8;
    const EV_ERROR: u16 = 0x4000;
    const EV_EOF: u16 = 0x8000;

    extern "C" {
        fn kqueue() -> i32;
        fn kevent(
            kq: i32,
            changelist: *const Kevent,
            nchanges: i32,
            eventlist: *mut Kevent,
            nevents: i32,
            timeout: *const Timespec,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Selector {
        kq: i32,
    }

    impl Selector {
        pub fn new() -> io::Result<Selector> {
            let kq = unsafe { kqueue() };
            if kq < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Selector { kq })
        }

        fn change(&self, fd: RawFd, filter: i16, flags: u16, token: usize) -> io::Result<()> {
            let ch = Kevent {
                ident: fd as usize,
                filter,
                flags,
                fflags: 0,
                data: 0,
                udata: token as *mut core::ffi::c_void,
            };
            let rc = unsafe { kevent(self.kq, &ch, 1, ptr::null_mut(), 0, ptr::null()) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Both filters are always added; uninterested directions are
        /// disabled. `EV_ADD` on an existing filter is an update, so
        /// register and reregister are the same idempotent operation.
        fn set(&self, fd: RawFd, token: usize, r: bool, w: bool) -> io::Result<()> {
            let rf = EV_ADD | if r { EV_ENABLE } else { EV_DISABLE };
            let wf = EV_ADD | if w { EV_ENABLE } else { EV_DISABLE };
            self.change(fd, EVFILT_READ, rf, token)?;
            self.change(fd, EVFILT_WRITE, wf, token)
        }

        pub fn register(&self, fd: RawFd, token: usize, r: bool, w: bool) -> io::Result<()> {
            self.set(fd, token, r, w)
        }

        pub fn reregister(&self, fd: RawFd, token: usize, r: bool, w: bool) -> io::Result<()> {
            self.set(fd, token, r, w)
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            // Either filter may already be gone; that is not an error
            // for our callers.
            let _ = self.change(fd, EVFILT_READ, EV_DELETE, 0);
            let _ = self.change(fd, EVFILT_WRITE, EV_DELETE, 0);
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let ts;
            let ts_ptr = match timeout {
                None => ptr::null(),
                Some(d) => {
                    ts = Timespec {
                        tv_sec: d.as_secs().min(i64::MAX as u64) as i64,
                        tv_nsec: d.subsec_nanos() as i64,
                    };
                    &ts as *const Timespec
                }
            };
            let mut buf = [Kevent {
                ident: 0,
                filter: 0,
                flags: 0,
                fflags: 0,
                data: 0,
                udata: ptr::null_mut(),
            }; 1024];
            let n = unsafe {
                kevent(self.kq, ptr::null(), 0, buf.as_mut_ptr(), buf.len() as i32, ts_ptr)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in buf.iter().take(n as usize) {
                if ev.flags & EV_ERROR != 0 {
                    // Per-fd error: surface as readable so the owner's
                    // next read reports it.
                    out.push(Event { token: ev.udata as usize, readable: true, writable: false });
                    continue;
                }
                let eof = ev.flags & EV_EOF != 0;
                out.push(Event {
                    token: ev.udata as usize,
                    readable: ev.filter == EVFILT_READ || eof,
                    writable: ev.filter == EVFILT_WRITE,
                });
            }
            Ok(())
        }

        pub fn close(&self) {
            unsafe {
                close(self.kq);
            }
        }
    }
}

#[cfg(not(any(
    target_os = "linux",
    target_os = "android",
    target_os = "macos",
    target_os = "ios"
)))]
mod sys {
    use super::Event;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    /// No readiness backend for this platform; `repro serve` falls back
    /// to the threaded front end.
    pub struct Selector;

    fn unsupported() -> io::Error {
        io::Error::new(io::ErrorKind::Unsupported, "no epoll/kqueue backend on this platform")
    }

    impl Selector {
        pub fn new() -> io::Result<Selector> {
            Err(unsupported())
        }

        pub fn register(&self, _: RawFd, _: usize, _: bool, _: bool) -> io::Result<()> {
            Err(unsupported())
        }

        pub fn reregister(&self, _: RawFd, _: usize, _: bool, _: bool) -> io::Result<()> {
            Err(unsupported())
        }

        pub fn deregister(&self, _: RawFd) -> io::Result<()> {
            Err(unsupported())
        }

        pub fn wait(&self, _: &mut Vec<Event>, _: Option<Duration>) -> io::Result<()> {
            Err(unsupported())
        }

        pub fn close(&self) {}
    }
}

#[cfg(all(test, any(target_os = "linux", target_os = "android", target_os = "macos", target_os = "ios")))]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn readable_readiness_is_level_triggered() {
        let poller = Poller::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        poller.register(server.as_raw_fd(), 7, true, false).unwrap();

        // Nothing to read yet: a short wait returns no event for it.
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.iter().all(|e| e.token != 7));

        client.write_all(b"x").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        let ev = events.iter().find(|e| e.token == 7).expect("readable event");
        assert!(ev.readable);

        // Level-triggered: the byte is still unread, readiness repeats.
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));

        poller.deregister(server.as_raw_fd()).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.iter().all(|e| e.token != 7), "deregistered fd still reported");
    }

    #[test]
    fn write_interest_toggles_via_reregister() {
        let poller = Poller::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        // Read-only first: an idle socket reports nothing.
        poller.register(server.as_raw_fd(), 3, true, false).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.iter().all(|e| e.token != 3));

        // With write interest, an empty send buffer is instantly ready.
        poller.reregister(server.as_raw_fd(), 3, true, true).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 3 && e.writable));

        // And off again.
        poller.reregister(server.as_raw_fd(), 3, true, false).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.iter().all(|e| e.token != 3));
    }

    #[test]
    fn waker_interrupts_a_blocked_wait() {
        let poller = Poller::new().unwrap();
        let (waker, rx) = waker_pair().unwrap();
        poller.register(rx.as_raw_fd(), 0, true, false).unwrap();

        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            waker.wake();
            waker.wake(); // coalesces, must not block
        });
        let mut events = Vec::new();
        let start = std::time::Instant::now();
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        assert!(start.elapsed() < Duration::from_secs(5), "wait did not wake");
        assert!(events.iter().any(|e| e.token == 0 && e.readable));
        drain_waker(&rx);
        // Drained: no stale readiness.
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.iter().all(|e| e.token != 0));
        t.join().unwrap();
    }
}
