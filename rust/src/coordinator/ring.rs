//! Consistent-hash ring for elastic task-to-shard routing.
//!
//! The fixed `hash % shards` routing the coordinator launched with has a
//! fatal operational property: changing the shard count remaps almost
//! every task, so growing or shrinking the pool would force a full
//! re-handoff of all trained state. The ring fixes that the classic way:
//! each shard owns [`VNODES`] pseudo-random points on a 64-bit circle and
//! a task is owned by the first shard point clockwise of the task's own
//! point. Adding or removing one shard then moves only the tasks whose
//! arcs the changed shard's points cover — about `1/N` of them — and
//! every moved task moves to (or from) exactly that shard, which is what
//! makes incremental accumulator handoff possible at all.
//!
//! The ring is a pure function of the *set of shard ids*: two rings built
//! from the same ids route identically, regardless of the order of
//! `add`/`remove` calls that produced them. Shard ids are arbitrary
//! `usize` labels; the pool assigns them monotonically and never reuses
//! one, so a ring snapshot can be shipped across threads (it is `Clone`)
//! and compared (`PartialEq`).
//!
//! Hashing reuses the crate-wide FNV-1a string hash finished with the
//! murmur3 avalanche mixer, the same construction the modulo router used,
//! so point placement is deterministic across runs and platforms.

use crate::util::fnv1a;

/// Virtual nodes (ring points) per shard. 64 keeps the per-shard load
/// imbalance modest (worst observed ~1.6x over the test corpora) while
/// keeping ring rebuilds trivially cheap — a ring of 16 shards is a
/// 1024-entry sorted Vec.
pub const VNODES: usize = 64;

/// Murmur3's 64-bit finalizer: avalanche the raw FNV hash so that the
/// near-sequential hashes of similar names scatter over the full circle.
fn avalanche(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51afd7ed558ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ceb9fe1a85ec53);
    h ^= h >> 33;
    h
}

/// Position of a task name on the circle.
fn task_point(task: &str) -> u64 {
    avalanche(fnv1a(task))
}

/// Position of one virtual node of one shard on the circle.
fn vnode_point(shard: usize, vnode: usize) -> u64 {
    avalanche(fnv1a(&format!("shard-{shard}#vnode-{vnode}")))
}

/// A consistent-hash ring over a set of shard ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HashRing {
    /// Ring points sorted by (position, shard). Ties on position are
    /// broken by the lower shard id so that routing stays a pure
    /// function of the id set.
    points: Vec<(u64, usize)>,
    /// Sorted live shard ids.
    shards: Vec<usize>,
}

impl HashRing {
    /// Build a ring over the given shard ids (duplicates are ignored).
    pub fn new(ids: impl IntoIterator<Item = usize>) -> HashRing {
        let mut ring = HashRing { points: Vec::new(), shards: Vec::new() };
        for id in ids {
            ring.add(id);
        }
        ring
    }

    /// Number of live shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Sorted live shard ids.
    pub fn shard_ids(&self) -> &[usize] {
        &self.shards
    }

    pub fn contains(&self, id: usize) -> bool {
        self.shards.binary_search(&id).is_ok()
    }

    /// Add a shard's points to the ring. No-op if already present.
    pub fn add(&mut self, id: usize) {
        if let Err(pos) = self.shards.binary_search(&id) {
            self.shards.insert(pos, id);
            for v in 0..VNODES {
                let pt = (vnode_point(id, v), id);
                let at = self.points.partition_point(|p| p < &pt);
                self.points.insert(at, pt);
            }
        }
    }

    /// Remove a shard's points from the ring. No-op if absent.
    pub fn remove(&mut self, id: usize) {
        if let Ok(pos) = self.shards.binary_search(&id) {
            self.shards.remove(pos);
            self.points.retain(|&(_, s)| s != id);
        }
    }

    /// Index into `points` of the first ring point strictly clockwise of
    /// the task's position (wrapping past the top of the circle).
    fn successor_index(&self, task: &str) -> usize {
        let p = task_point(task);
        let idx = self.points.partition_point(|&(pt, _)| pt <= p);
        idx % self.points.len()
    }

    /// The shard that owns this task.
    ///
    /// Panics if the ring is empty — an empty pool cannot route anything
    /// and the coordinator refuses to reach that state.
    pub fn route(&self, task: &str) -> usize {
        assert!(!self.points.is_empty(), "routing on an empty ring");
        self.points[self.successor_index(task)].1
    }

    /// The warm-standby shard for this task: the first shard clockwise of
    /// the owner that is a *different* shard. `None` when the ring has
    /// fewer than two shards (nowhere to replicate).
    pub fn standby(&self, task: &str) -> Option<usize> {
        if self.shards.len() < 2 {
            return None;
        }
        let start = self.successor_index(task);
        let primary = self.points[start].1;
        for step in 1..self.points.len() {
            let (_, s) = self.points[(start + step) % self.points.len()];
            if s != primary {
                return Some(s);
            }
        }
        None
    }

    /// Primary and standby in one lookup.
    pub fn route2(&self, task: &str) -> (usize, Option<usize>) {
        (self.route(task), self.standby(task))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn ring_is_a_pure_function_of_the_id_set() {
        let a = HashRing::new([0, 1, 2, 3]);
        let mut b = HashRing::new([3, 1]);
        b.add(0);
        b.add(2);
        b.add(2); // duplicate add is a no-op
        assert_eq!(a, b);
        let mut c = HashRing::new([0, 1, 2, 3, 9]);
        c.remove(9);
        c.remove(9); // duplicate remove is a no-op
        assert_eq!(a, c);
    }

    #[test]
    fn single_shard_owns_everything_and_has_no_standby() {
        let ring = HashRing::new([7]);
        for i in 0..64 {
            let t = format!("task-{i}");
            assert_eq!(ring.route(&t), 7);
            assert_eq!(ring.standby(&t), None);
        }
    }

    #[test]
    fn routing_is_deterministic_total_and_reaches_every_shard() {
        run_prop("ring_routing_total", 30, |rng| {
            let n = 2 + (rng.next_u64() % 7) as usize;
            let ring = HashRing::new(0..n);
            let mut hit = vec![false; n];
            for i in 0..4096u64 {
                let t = format!("task-{}-{i}", rng.next_u64());
                let owner = ring.route(&t);
                assert!(owner < n, "owner {owner} out of range");
                assert_eq!(owner, ring.route(&t), "routing must be deterministic");
                hit[owner] = true;
            }
            assert!(hit.iter().all(|&h| h), "some shard owns no tasks: {hit:?}");
        });
    }

    #[test]
    fn standby_is_always_a_distinct_live_shard() {
        run_prop("ring_standby_distinct", 30, |rng| {
            let n = 2 + (rng.next_u64() % 7) as usize;
            let ring = HashRing::new(0..n);
            for i in 0..512u64 {
                let t = format!("job-{}-{i}", rng.next_u64());
                let (primary, standby) = ring.route2(&t);
                let standby = standby.expect("two or more shards must yield a standby");
                assert_ne!(primary, standby, "{t}");
                assert!(ring.contains(standby));
            }
        });
    }

    #[test]
    fn adding_a_shard_moves_tasks_only_to_the_new_shard() {
        run_prop("ring_add_moves_to_new", 20, |rng| {
            let n = 1 + (rng.next_u64() % 7) as usize;
            let before = HashRing::new(0..n);
            let mut after = before.clone();
            after.add(n);
            let mut moved = 0usize;
            let total = 2000usize;
            for i in 0..total {
                let t = format!("task-{}-{i}", rng.next_u64());
                let (old, new) = (before.route(&t), after.route(&t));
                if old != new {
                    moved += 1;
                    assert_eq!(new, n, "a moved task must land on the new shard");
                }
            }
            // Expected movement is total/(n+1); assert it stays in the
            // right ballpark rather than remapping everything.
            let frac = moved as f64 / total as f64;
            let expect = 1.0 / (n + 1) as f64;
            assert!(frac < 2.5 * expect + 0.05, "moved {frac} of tasks, expected ~{expect}");
        });
    }

    #[test]
    fn removing_a_shard_moves_only_its_own_tasks() {
        run_prop("ring_remove_moves_from_old", 20, |rng| {
            let n = 2 + (rng.next_u64() % 7) as usize;
            let victim = (rng.next_u64() % n as u64) as usize;
            let before = HashRing::new(0..n);
            let mut after = before.clone();
            after.remove(victim);
            for i in 0..2000u64 {
                let t = format!("task-{}-{i}", rng.next_u64());
                let (old, new) = (before.route(&t), after.route(&t));
                if old != victim {
                    assert_eq!(old, new, "tasks off the removed shard must not move");
                } else {
                    assert_ne!(new, victim);
                }
            }
        });
    }

    #[test]
    fn non_contiguous_ids_still_spread() {
        let ring = HashRing::new([0, 3, 5]);
        let mut hit = std::collections::BTreeSet::new();
        for i in 0..64 {
            hit.insert(ring.route(&format!("task-{i}")));
        }
        assert!(hit.len() > 1, "routing collapsed onto one shard: {hit:?}");
        assert!(hit.iter().all(|s| ring.contains(*s)));
    }
}
