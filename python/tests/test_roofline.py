"""Roofline estimator sanity checks."""

from __future__ import annotations

from compile import roofline
from compile.kernels import ols


def test_all_kernels_fit_vmem():
    for e in roofline.estimates():
        assert e.fits_vmem, f"{e.name} needs {e.vmem_per_step} B of VMEM"


def test_bandwidth_bound():
    # Every kernel sits far below a ~100 flop/byte ridge.
    for e in roofline.estimates():
        assert e.intensity < 10.0, f"{e.name} intensity {e.intensity}"


def test_small_bucket_moves_less_data():
    es = {e.name: e for e in roofline.estimates()}
    big = es[f"fit b{ols.FIT_B} n{ols.FIT_N}"]
    small = es[f"fit b{ols.FIT_B} n{ols.FIT_N_SMALL} (small)"]
    assert small.hbm_bytes * 4 < big.hbm_bytes
    assert small.est_runtime_s < big.est_runtime_s


def test_runtime_estimates_subsecond():
    for e in roofline.estimates():
        assert 0.0 < e.est_runtime_s < 0.01, e.name
