//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf):
//!
//!   L3 native  : segmentation (heap vs quadratic oracle), observe vs
//!                retrain, plan math, simulator step rate
//!   L3 service : coordinator plan throughput/latency, native vs PJRT
//!   L1/L2 PJRT : batched fit / predict / fused / wastage artifact cost
//!
//! Run: `cargo bench --bench hotpath` (artifacts required for the PJRT
//! section; it is skipped with a notice when absent).
//!
//! Machine-readable output: set `KSPLUS_BENCH_JSON=BENCH_hotpath.json`
//! to write the headline numbers (segmentation ns/op + speedup,
//! observe/s, plans/s p50/p99 per shard count) in the
//! `ksplus-bench-hotpath/v1` schema. Set `KSPLUS_BENCH_QUICK=1` for a
//! reduced-iteration CI smoke run.

use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
use ksplus::coordinator::{Backend, BackendSpec, ModelStore, PlanScratch, PredictorPolicy};
use ksplus::predictor::regression::{FitEngine, NativeFit};
use ksplus::predictor::{by_name, Predictor};
use ksplus::segments::algorithm::{get_segments, get_segments_quadratic};
use ksplus::sim::run_task;
use ksplus::trace::workflow::Workflow;
use ksplus::util::bench::{bench, black_box};
use ksplus::util::json::Json;
use ksplus::util::rng::Rng;

fn quick() -> bool {
    std::env::var_os("KSPLUS_BENCH_QUICK").is_some()
}

/// (warmup, iters) scaled down for CI smoke runs.
fn reps(warmup: usize, iters: usize) -> (usize, usize) {
    if quick() {
        (1, iters.div_ceil(10).max(2))
    } else {
        (warmup, iters)
    }
}

/// A 10k-step noisy rising envelope: the adversarial shape for the merge
/// loop — thousands of envelope runs (a fresh maximum every few samples).
fn noisy_envelope_10k() -> Vec<f64> {
    let mut rng = Rng::new(7);
    let mut trend = 1.0f64;
    (0..10_000)
        .map(|_| {
            trend += rng.uniform(0.0, 0.002);
            trend * (1.0 - 0.005 * rng.f64())
        })
        .collect()
}

fn main() {
    let wf = Workflow::eager();
    let trace = wf.generate(42, 200);
    let bwa = trace.task("bwa").unwrap().clone();

    // ---- L3 native hot paths -------------------------------------------
    println!("== L3 native ==");
    let series: Vec<&Vec<f64>> = bwa.executions.iter().map(|e| &e.samples).collect();
    let total_samples: usize = series.iter().map(|s| s.len()).sum();
    let (w, i) = reps(3, 20);
    let r = bench("segmentation/k4/60-traces", w, i, || {
        for s in &series {
            black_box(get_segments(s, 4));
        }
    });
    println!("  -> {}", r.throughput_line(total_samples as f64, "samples"));

    // Acceptance bench: the heap merge vs the retained quadratic oracle
    // on a 10k-step noisy envelope at k=4 (thousands of merge steps).
    let noisy = noisy_envelope_10k();
    let (w, i) = reps(3, 20);
    let r_heap = bench("segmentation/10k-noisy/k4/heap", w, i, || {
        black_box(get_segments(&noisy, 4));
    });
    let (w, i) = reps(1, 5);
    let r_quad = bench("segmentation/10k-noisy/k4/quadratic-oracle", w, i, || {
        black_box(get_segments_quadratic(&noisy, 4));
    });
    let seg_speedup = r_quad.median_s / r_heap.median_s;
    println!(
        "  -> heap {:.0} ns/op vs quadratic {:.0} ns/op: {:.1}x speedup",
        r_heap.ns_per_op(1.0),
        r_quad.ns_per_op(1.0),
        seg_speedup
    );

    // Incremental observe vs batch retrain: the observe path segments
    // only the new execution and updates 2k O(1) accumulators, so its
    // per-execution cost must not grow with history size.
    let mut store = ModelStore::new(4, 128.0, Backend::Native);
    store.train("bwa", &bwa.executions);
    let (w, i) = reps(5, 50);
    let r_observe = bench("store/observe/60-fold", w, i, || {
        for e in &bwa.executions {
            black_box(store.observe("bwa", e));
        }
    });
    let observe_per_s = r_observe.per_s(bwa.executions.len() as f64);
    println!("  -> {}", r_observe.throughput_line(bwa.executions.len() as f64, "observes"));
    let (w, i) = reps(3, 20);
    let r_retrain = bench("store/train-from-scratch/60", w, i, || {
        store.train("bwa", &bwa.executions);
        black_box(&store);
    });
    println!(
        "  -> one observe {:.0} ns vs full retrain {:.0} ns",
        r_observe.ns_per_op(bwa.executions.len() as f64),
        r_retrain.ns_per_op(1.0)
    );

    let mut pred = by_name("ksplus", 4, 128.0).unwrap();
    pred.train(&bwa.executions);
    let (w, i) = reps(10, 50);
    let r = bench("ksplus/plan", w, i, || {
        for e in bwa.executions.iter().take(32) {
            black_box(pred.plan(e.input_mb));
        }
    });
    println!("  -> {}", r.throughput_line(32.0, "plans"));

    let (w, i) = reps(3, 20);
    let r = bench("sim/run_task/60-traces", w, i, || {
        for e in &bwa.executions {
            black_box(run_task(pred.as_ref(), e, 10));
        }
    });
    println!("  -> {}", r.throughput_line(total_samples as f64, "trace-samples"));

    // Per-task policy plan paths: the KS+ fast path (batched backend
    // predict over the sufficient-stat models) vs a baseline policy
    // served through the Predictor seam. Confirms the policy layer adds
    // no overhead to the KS+ hot path and prices the alternative.
    {
        let mut pstore = ModelStore::new(4, 128.0, Backend::Native);
        pstore.train("bwa", &bwa.executions);
        pstore.configure("bwa-witt", PredictorPolicy::WittLr);
        pstore.train("bwa-witt", &bwa.executions);
        let reqs_ks: Vec<(&str, f64)> =
            (0..64).map(|i| ("bwa", 2000.0 + i as f64 * 100.0)).collect();
        let reqs_w: Vec<(&str, f64)> =
            (0..64).map(|i| ("bwa-witt", 2000.0 + i as f64 * 100.0)).collect();
        let mut scratch = PlanScratch::default();
        let (w, i) = reps(5, 50);
        let r = bench("store/plan_batch/ksplus-64", w, i, || {
            pstore.plan_batch_into(&reqs_ks, &mut scratch);
            black_box(&scratch.plans);
        });
        println!("  -> {}", r.throughput_line(64.0, "plans"));
        let r = bench("store/plan_batch/witt-lr-64", w, i, || {
            pstore.plan_batch_into(&reqs_w, &mut scratch);
            black_box(&scratch.plans);
        });
        println!("  -> {}", r.throughput_line(64.0, "plans"));
    }

    let (w, i) = reps(3, 20);
    let r = bench("native-ols/512rows-x-128obs", w, i, || {
        let mut rng = Rng::new(1);
        let rows: Vec<(Vec<f64>, Vec<f64>)> = (0..512)
            .map(|_| {
                let xs: Vec<f64> = (0..128).map(|_| rng.f64()).collect();
                let ys: Vec<f64> = (0..128).map(|_| rng.f64()).collect();
                (xs, ys)
            })
            .collect();
        black_box(NativeFit.fit_batch(&rows));
    });
    println!("  -> {}", r.throughput_line(512.0, "fits"));

    // ---- coordinator service (native backend, shipped defaults) ---------
    // Comparable to the PJRT L3 section below: identical config, only the
    // backend differs.
    println!("== L3 coordinator (native backend) ==");
    coordinator_bench(
        BackendSpec::Native,
        &trace,
        1,
        CoordinatorConfig::default().batch_delay,
    );

    // ---- coordinator service: sharded vs single-worker contention -------
    // Same closed-loop client count at every width: the sharded pool
    // should sustain a multiple of the single worker's plans/sec on
    // multi-core (shards=1 is the original single-worker coordinator).
    // Linger disabled for this sweep only, so it measures pool capacity
    // rather than the single-request straggler poll.
    println!("== L3 coordinator sharded vs single (native backend) ==");
    let mut plan_rows = Vec::new();
    for shards in [1, 2, 4] {
        plan_rows.push(coordinator_bench(
            BackendSpec::Native,
            &trace,
            shards,
            std::time::Duration::ZERO,
        ));
    }

    // ---- machine-readable summary ---------------------------------------
    if let Some(path) = std::env::var_os("KSPLUS_BENCH_JSON") {
        let doc = Json::obj(vec![
            ("schema", "ksplus-bench-hotpath/v1".into()),
            ("source", "bench-hotpath".into()),
            ("quick", quick().into()),
            (
                "segmentation",
                Json::obj(vec![
                    ("series_len", 10_000usize.into()),
                    ("k", 4usize.into()),
                    ("heap_ns_per_op", r_heap.ns_per_op(1.0).into()),
                    ("quadratic_ns_per_op", r_quad.ns_per_op(1.0).into()),
                    ("speedup", seg_speedup.into()),
                ]),
            ),
            (
                "observe",
                Json::obj(vec![
                    ("per_s", observe_per_s.into()),
                    (
                        "ns_per_op",
                        r_observe.ns_per_op(bwa.executions.len() as f64).into(),
                    ),
                    ("retrain_60_ns", r_retrain.ns_per_op(1.0).into()),
                ]),
            ),
            (
                "plans",
                Json::Arr(
                    plan_rows
                        .iter()
                        .map(|&(shards, plans_per_s, p50, p99)| {
                            Json::obj(vec![
                                ("shards", shards.into()),
                                ("plans_per_s", plans_per_s.into()),
                                ("p50_us", p50.into()),
                                ("p99_us", p99.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let path = std::path::PathBuf::from(path);
        std::fs::write(&path, doc.to_string()).expect("write KSPLUS_BENCH_JSON");
        println!("wrote {}", path.display());
    }

    // ---- PJRT sections (feature-gated) ----------------------------------
    pjrt_sections(&trace, &bwa);
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_sections(_trace: &ksplus::trace::WorkflowTrace, _bwa: &ksplus::trace::TaskTraces) {
    println!("SKIP PJRT section: built without the 'pjrt' feature");
}

#[cfg(feature = "pjrt")]
fn pjrt_sections(trace: &ksplus::trace::WorkflowTrace, bwa: &ksplus::trace::TaskTraces) {
    use ksplus::runtime::{default_artifacts_dir, Runtime};

    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP PJRT section: artifacts not built (make artifacts)");
        return;
    }
    println!("== L1/L2 PJRT artifacts ==");
    let rt = Runtime::load(&dir).expect("runtime");
    let mut rng = Rng::new(2);
    let b = rt.manifest().fit_b;
    let rows: Vec<(Vec<f64>, Vec<f64>)> = (0..b)
        .map(|_| {
            let xs: Vec<f64> = (0..128).map(|_| rng.uniform(0.0, 1000.0)).collect();
            let ys: Vec<f64> = xs.iter().map(|x| 0.01 * x + 1.0).collect();
            (xs, ys)
        })
        .collect();
    let r = bench(&format!("pjrt/fit/{b}x128"), 3, 20, || {
        black_box(rt.fit_batch(&rows).unwrap());
    });
    println!("  -> {}", r.throughput_line(b as f64, "fits"));

    // Typical training history (<= 64 obs) hits the small bucket.
    let rows_small: Vec<(Vec<f64>, Vec<f64>)> = rows
        .iter()
        .map(|(xs, ys)| (xs[..40].to_vec(), ys[..40].to_vec()))
        .collect();
    let r = bench(&format!("pjrt/fit/{b}x40-small-bucket"), 3, 20, || {
        black_box(rt.fit_batch(&rows_small).unwrap());
    });
    println!("  -> {}", r.throughput_line(b as f64, "fits"));

    let models = rt.fit_batch(&rows).unwrap();
    let pb = rt.manifest().predict_b;
    let models_big: Vec<_> = (0..pb).map(|i| models[i % models.len()]).collect();
    let xq: Vec<f64> = (0..pb).map(|i| i as f64).collect();
    let scale = vec![1.1; pb];
    let r = bench(&format!("pjrt/predict/{pb}"), 3, 50, || {
        black_box(rt.predict_batch(&models_big, &xq, &scale).unwrap());
    });
    println!("  -> {}", r.throughput_line(pb as f64, "predictions"));

    let xq_b: Vec<f64> = (0..b).map(|i| i as f64).collect();
    let scale_b = vec![1.1; b];
    bench(&format!("pjrt/fit_predict-fused/{b}x128"), 3, 20, || {
        black_box(rt.fit_predict(&rows, &xq_b, &scale_b).unwrap());
    });
    bench(&format!("pjrt/fit+predict-two-step/{b}x128"), 3, 20, || {
        let m = rt.fit_batch(&rows).unwrap();
        black_box(rt.predict_batch(&m, &xq_b, &scale_b).unwrap());
    });

    let wrows: Vec<(Vec<f64>, Vec<f64>, f64)> = bwa
        .executions
        .iter()
        .map(|e| {
            let alloc = vec![e.peak(); e.samples.len()];
            (alloc, e.samples.clone(), e.dt)
        })
        .collect();
    let n_samples: usize = wrows.iter().map(|r| r.0.len()).sum();
    let r = bench("pjrt/wastage/60-traces", 3, 20, || {
        black_box(rt.wastage_batch(&wrows).unwrap());
    });
    println!("  -> {}", r.throughput_line(n_samples as f64, "samples"));

    // ---- coordinator service (PJRT backend) -----------------------------
    println!("== L3 coordinator (PJRT backend) ==");
    coordinator_bench(
        BackendSpec::Pjrt(Some(dir)),
        trace,
        1,
        CoordinatorConfig::default().batch_delay,
    );
}

/// Returns (shards, plans_per_s, p50_us, p99_us) for the JSON summary.
fn coordinator_bench(
    spec: BackendSpec,
    trace: &ksplus::trace::WorkflowTrace,
    shards: usize,
    batch_delay: std::time::Duration,
) -> (usize, f64, f64, f64) {
    let coord = Coordinator::start(
        CoordinatorConfig { shards, batch_delay, ..Default::default() },
        spec,
    )
    .expect("start coordinator");
    let client = coord.client();
    for t in &trace.tasks {
        client.train(&t.task, t.executions.clone());
    }
    // Closed-loop from 8 threads to exercise the per-shard batchers.
    let n_per_thread = if quick() { 50 } else { 200 };
    let threads = 8;
    let (w, i) = reps(1, 5);
    let r = bench(&format!("coordinator/plan-closed-loop/shards{shards}"), w, i, || {
        let mut handles = Vec::new();
        for t in 0..threads {
            let c = coord.client();
            let tasks: Vec<(String, f64)> = trace
                .tasks
                .iter()
                .map(|tt| (tt.task.clone(), tt.executions[t % tt.executions.len()].input_mb))
                .collect();
            handles.push(std::thread::spawn(move || {
                for i in 0..n_per_thread {
                    let (task, input) = &tasks[i % tasks.len()];
                    black_box(c.plan(task, *input));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    println!(
        "  -> {}",
        r.throughput_line((n_per_thread * threads) as f64, "plans")
    );
    let stats = client.stats();
    println!(
        "  -> mean batch {:.1}, p50 latency {:.0} us, p99 {:.0} us",
        stats.mean_batch_size(),
        stats.latency_percentile_us(50.0),
        stats.latency_percentile_us(99.0)
    );
    (
        shards,
        r.per_s((n_per_thread * threads) as f64),
        stats.latency_percentile_us(50.0),
        stats.latency_percentile_us(99.0),
    )
}
