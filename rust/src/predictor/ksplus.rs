//! KS+ (the paper's contribution): variable-size segments, per-segment
//! linear models on input size, safety offsets, and the segment-rescaling
//! retry strategy (Sections II-A..II-C).

use crate::predictor::regression::{FitEngine, LinModel, NativeFit};
use crate::predictor::{sanitize_plan, Predictor};
use crate::segments::algorithm::get_segments;
use crate::segments::StepPlan;
use crate::trace::Execution;

/// Safety offsets from Section II-B.
pub const MEM_OVERPREDICT: f64 = 1.10;
pub const TIME_UNDERPREDICT: f64 = 0.85;
/// Last-segment boost when a failure happens in the final segment (II-C).
pub const LAST_SEGMENT_BOOST: f64 = 1.20;

/// KS+ predictor for one task type.
pub struct KsPlus {
    k: usize,
    capacity: f64,
    mem_offset: f64,
    time_offset: f64,
    /// Per-segment models: start-time (index 0 unused: start_0 == 0).
    start_models: Vec<LinModel>,
    peak_models: Vec<LinModel>,
    trained: bool,
    /// Fallback when training produced no usable signal.
    fallback_peak: f64,
}

impl KsPlus {
    pub fn new(k: usize, capacity: f64) -> Self {
        assert!(k >= 1);
        KsPlus {
            k,
            capacity,
            mem_offset: MEM_OVERPREDICT,
            time_offset: TIME_UNDERPREDICT,
            start_models: Vec::new(),
            peak_models: Vec::new(),
            trained: false,
            fallback_peak: 2.0,
        }
    }

    /// Builder for the offset-ablation bench.
    pub fn with_offsets(mut self, mem_offset: f64, time_offset: f64) -> Self {
        self.mem_offset = mem_offset;
        self.time_offset = time_offset;
        self
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Per-execution segment parameters aligned to exactly `k` slots:
    /// executions whose envelope has fewer steps repeat their last
    /// segment (start = duration, peak = final peak), so all regressions
    /// see one observation per execution.
    ///
    /// ONE `get_segments` call per execution — shared by batch training
    /// here and by the coordinator's incremental `ModelStore::observe`,
    /// which folds the k starts and k peaks into its sufficient-statistic
    /// accumulators.
    pub fn aligned_rows(k: usize, e: &Execution) -> (Vec<f64>, Vec<f64>) {
        let seg = get_segments(&e.samples, k);
        let offsets = seg.start_offsets();
        let mut starts = Vec::with_capacity(k);
        let mut peaks = Vec::with_capacity(k);
        for j in 0..k {
            if j < seg.peaks.len() {
                starts.push(offsets[j] as f64 * e.dt);
                peaks.push(seg.peaks[j]);
            } else {
                starts.push(e.duration());
                peaks.push(*seg.peaks.last().unwrap());
            }
        }
        (starts, peaks)
    }

    /// Assemble the 2k regression problems for a training set as one
    /// shared x-column (the input sizes) plus 2k y-columns (k segment
    /// starts, then k segment peaks). Each execution is segmented once;
    /// the x-column is shared instead of cloned per regression.
    pub fn regression_cols(k: usize, history: &[Execution]) -> (Vec<f64>, Vec<Vec<f64>>) {
        let xs: Vec<f64> = history.iter().map(|e| e.input_mb).collect();
        let per_exec: Vec<(Vec<f64>, Vec<f64>)> =
            history.iter().map(|e| Self::aligned_rows(k, e)).collect();
        let mut cols = Vec::with_capacity(2 * k);
        for j in 0..k {
            cols.push(per_exec.iter().map(|(s, _)| s[j]).collect());
        }
        for j in 0..k {
            cols.push(per_exec.iter().map(|(_, p)| p[j]).collect());
        }
        (xs, cols)
    }

    /// Train using an explicit fit engine (native or PJRT).
    pub fn train_with_engine(&mut self, history: &[Execution], engine: &dyn FitEngine) {
        if history.is_empty() {
            self.trained = false;
            return;
        }
        let (xs, cols) = Self::regression_cols(self.k, history);
        let models = engine.fit_shared(&xs, &cols);
        self.start_models = models[..self.k].to_vec();
        self.peak_models = models[self.k..].to_vec();
        self.fallback_peak =
            history.iter().map(|e| e.peak()).fold(0.0, f64::max).max(0.1);
        self.trained = true;
    }

    /// Build the plan from raw model outputs (used by both `plan` and the
    /// PJRT coordinator, which evaluates the models remotely).
    pub fn assemble_plan(
        starts_raw: &[f64],
        peaks_raw: &[f64],
        mem_offset: f64,
        time_offset: f64,
        capacity: f64,
    ) -> StepPlan {
        let k = peaks_raw.len();
        let mut starts = Vec::with_capacity(k);
        let mut peaks = Vec::with_capacity(k);
        for j in 0..k {
            // Underpredict start times (never the first segment), and
            // overpredict memory; clamp negatives.
            let s = if j == 0 { 0.0 } else { (starts_raw[j] * time_offset).max(0.0) };
            let p = (peaks_raw[j] * mem_offset).max(1e-3);
            starts.push(s);
            peaks.push(p);
        }
        sanitize_plan(starts, peaks, capacity)
    }
}

impl Predictor for KsPlus {
    fn name(&self) -> &'static str {
        "ksplus"
    }

    fn train(&mut self, history: &[Execution]) {
        self.train_with_engine(history, &NativeFit);
    }

    fn plan(&self, input_mb: f64) -> StepPlan {
        if !self.trained {
            return StepPlan::flat(self.fallback_peak.min(self.capacity));
        }
        let starts_raw: Vec<f64> =
            self.start_models.iter().map(|m| m.predict(input_mb)).collect();
        let peaks_raw: Vec<f64> =
            self.peak_models.iter().map(|m| m.predict(input_mb)).collect();
        Self::assemble_plan(
            &starts_raw,
            &peaks_raw,
            self.mem_offset,
            self.time_offset,
            self.capacity,
        )
    }

    /// Section II-C: when the execution OOMs at `fail_time`, it most
    /// likely reached the *next* segment earlier than predicted. Rescale
    /// the start times of all succeeding segments by
    /// `fail_time / next_start` so the next segment begins exactly at the
    /// failure time. Only when the failure is already in the last segment
    /// is its peak raised (by 20 %).
    fn on_failure(&self, prev: &StepPlan, fail_time: f64, _attempt: usize) -> StepPlan {
        if prev.k() == 0 {
            // Degenerate empty plan: fall back to a flat allocation.
            return StepPlan::flat(self.fallback_peak.min(self.capacity));
        }
        let i = prev.segment_at(fail_time);
        if i + 1 >= prev.k() {
            // Failure in the last segment: raise the final peak.
            let mut peaks = prev.peaks.clone();
            let last = peaks.len() - 1;
            peaks[last] = (peaks[last] * LAST_SEGMENT_BOOST).min(self.capacity);
            return sanitize_plan(prev.starts.clone(), peaks, self.capacity);
        }
        let next_start = prev.starts[i + 1];
        let factor = if next_start > 1e-9 { (fail_time / next_start).min(1.0) } else { 0.0 };
        let mut starts = prev.starts.clone();
        for j in (i + 1)..starts.len() {
            starts[j] *= factor;
        }
        // Collapsed segments (factor == 0 or equal starts) are merged by
        // sanitize_plan, which keeps the larger peak — so allocation only
        // moves earlier, never lower.
        sanitize_plan(starts, prev.peaks.clone(), self.capacity)
    }

    fn capacity(&self) -> f64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth::eager_archetypes;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn two_phase_exec(input: f64, rng: &mut Rng) -> Execution {
        // Phase 1: input*0.01 s at input*0.0005 GB; phase 2: input*0.003 s
        // at input*0.001 GB. dt = 1 s.
        let d1 = (input * 0.01) as usize;
        let d2 = (input * 0.003) as usize;
        let mut s = vec![input * 0.0005; d1.max(2)];
        s.extend(vec![input * 0.001; d2.max(1)]);
        // Tiny noise so regressions are not perfectly degenerate.
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new("t", input, 1.0, s)
    }

    fn trained(k: usize) -> (KsPlus, Vec<Execution>) {
        let mut rng = Rng::new(1);
        let hist: Vec<Execution> =
            (0..40).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut p = KsPlus::new(k, 128.0);
        p.train(&hist);
        (p, hist)
    }

    #[test]
    fn untrained_falls_back_flat() {
        let p = KsPlus::new(4, 128.0);
        let plan = p.plan(5000.0);
        assert_eq!(plan.k(), 1);
        assert!(plan.is_valid());
    }

    #[test]
    fn plan_has_two_segments_for_two_phase_task() {
        let (p, _) = trained(2);
        let plan = p.plan(8000.0);
        assert!(plan.is_valid());
        assert_eq!(plan.k(), 2);
        // Peaks near 0.0005*8000*1.1 = 4.4 and 0.001*8000*1.1 = 8.8.
        assert!((plan.peaks[0] - 4.4).abs() < 0.5, "{:?}", plan.peaks);
        assert!((plan.peaks[1] - 8.8).abs() < 0.9, "{:?}", plan.peaks);
        // Second segment starts near 80 s * 0.85 = 68.
        assert!((plan.starts[1] - 68.0).abs() < 10.0, "{:?}", plan.starts);
    }

    #[test]
    fn plan_scales_with_input() {
        let (p, _) = trained(2);
        let small = p.plan(3000.0);
        let large = p.plan(12000.0);
        assert!(large.peaks.last().unwrap() > small.peaks.last().unwrap());
        assert!(large.starts[1] > small.starts[1]);
    }

    #[test]
    fn covers_unseen_executions() {
        // The safety offsets should make most test executions succeed.
        let (p, _) = trained(2);
        let mut rng = Rng::new(99);
        let mut covered = 0;
        let total = 50;
        for _ in 0..total {
            let e = two_phase_exec(rng.uniform(2500.0, 11000.0), &mut rng);
            if p.plan(e.input_mb).covers(&e) {
                covered += 1;
            }
        }
        assert!(covered >= total * 8 / 10, "only {covered}/{total} covered");
    }

    #[test]
    fn retry_rescales_segment_starts() {
        // Plan: seg0 [0,100) @2, seg1 [100,..) @8. Failure at t=60 in
        // seg0 -> factor 0.6; seg1 now starts at 60.
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let p = KsPlus::new(2, 128.0);
        let retry = p.on_failure(&prev, 60.0, 1);
        assert!(retry.is_valid());
        assert_eq!(retry.starts, vec![0.0, 60.0]);
        assert_eq!(retry.peaks, vec![2.0, 8.0]);
    }

    #[test]
    fn retry_rescales_all_succeeding_segments() {
        let prev = StepPlan::new(vec![0.0, 100.0, 200.0], vec![2.0, 4.0, 8.0]);
        let p = KsPlus::new(3, 128.0);
        let retry = p.on_failure(&prev, 50.0, 1);
        // factor = 0.5 applied to starts 100 and 200.
        assert_eq!(retry.starts, vec![0.0, 50.0, 100.0]);
        assert_eq!(retry.peaks, vec![2.0, 4.0, 8.0]);
    }

    #[test]
    fn retry_in_last_segment_boosts_peak() {
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let p = KsPlus::new(2, 128.0);
        let retry = p.on_failure(&prev, 150.0, 1);
        assert_eq!(retry.starts, vec![0.0, 100.0]);
        assert!((retry.peaks[1] - 9.6).abs() < 1e-9);
        assert_eq!(retry.peaks[0], 2.0);
    }

    #[test]
    fn retry_failure_at_time_zero_promotes_next_segment() {
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let p = KsPlus::new(2, 128.0);
        let retry = p.on_failure(&prev, 0.0, 1);
        assert!(retry.is_valid());
        // factor 0 -> the 8 GB segment starts immediately.
        assert_eq!(retry.alloc_at(0.0), 8.0);
    }

    #[test]
    fn retry_respects_capacity() {
        let prev = StepPlan::new(vec![0.0, 10.0], vec![100.0, 120.0]);
        let p = KsPlus::new(2, 128.0);
        let retry = p.on_failure(&prev, 20.0, 1);
        assert!(retry.peaks.iter().all(|&x| x <= 128.0));
    }

    #[test]
    fn repeated_retries_converge_to_coverage() {
        // Apply the retry loop the way the simulator does and verify a
        // demanding execution eventually gets covered.
        let (p, _) = trained(2);
        let mut rng = Rng::new(123);
        // Much faster execution than predicted (Fig 3 red cross).
        let input = 10000.0;
        let mut e = two_phase_exec(input, &mut rng);
        let cut = e.samples.len() / 3; // runs 3x faster
        e.samples = e
            .samples
            .iter()
            .step_by(3)
            .copied()
            .take(cut.max(4))
            .collect();
        let mut plan = p.plan(input);
        for _ in 0..10 {
            match plan.first_oom(&e) {
                None => break,
                Some((t, _)) => plan = p.on_failure(&plan, t, 1),
            }
        }
        assert!(plan.covers(&e), "retry loop never covered the execution");
    }

    #[test]
    fn works_on_synthetic_bwa() {
        // End-to-end through the OOM/retry loop on the Fig-1 BWA
        // archetype: every instance finishes, and total wastage
        // (including failed-attempt cost) beats a maximal flat
        // allocation. Single-shot coverage is *expected* to be partial —
        // the paper's retry strategy exists precisely because segment
        // start times are hard to predict (Fig 3).
        use crate::predictor::DefaultLimits;
        use crate::sim::{run_task, MAX_RETRIES};

        let a = eager_archetypes().into_iter().find(|a| a.name == "bwa").unwrap();
        let mut rng = Rng::new(5);
        let hist: Vec<Execution> = (0..60).map(|_| a.generate(&mut rng, 200)).collect();
        let mut p = KsPlus::new(4, 128.0);
        p.train(&hist);
        let test: Vec<Execution> = (0..30).map(|_| a.generate(&mut rng, 200)).collect();

        let covered = test.iter().filter(|e| p.plan(e.input_mb).covers(e)).count();
        assert!(covered >= 10, "only {covered}/30 covered single-shot");

        let max_peak = hist.iter().map(|e| e.peak()).fold(0.0, f64::max);
        let flat = DefaultLimits::with_limit(128.0, max_peak * 1.1);
        let mut w_ks = 0.0;
        let mut w_flat = 0.0;
        for e in &test {
            let (o_ks, _) = run_task(&p, e, MAX_RETRIES);
            assert!(o_ks.success, "KS+ retry loop failed to finish a task");
            w_ks += o_ks.wastage_gbs;
            let (o_flat, _) = run_task(&flat, e, MAX_RETRIES);
            w_flat += o_flat.wastage_gbs;
        }
        assert!(
            w_ks < w_flat * 0.8,
            "KS+ {w_ks:.0} GBs not clearly below flat {w_flat:.0} GBs"
        );
    }

    #[test]
    fn prop_plans_always_valid() {
        run_prop("ksplus_plan_valid", 100, |rng| {
            let k = 1 + rng.below(6);
            let hist: Vec<Execution> = (0..5 + rng.below(20))
                .map(|_| {
                    let n = 3 + rng.below(60);
                    let input = rng.uniform(100.0, 10000.0);
                    let samples: Vec<f64> =
                        (0..n).map(|_| rng.uniform(0.05, 12.0)).collect();
                    Execution::new("t", input, rng.uniform(0.5, 5.0), samples)
                })
                .collect();
            let mut p = KsPlus::new(k, 128.0);
            p.train(&hist);
            let plan = p.plan(rng.uniform(50.0, 20000.0));
            assert!(plan.is_valid(), "invalid plan {plan:?}");
            assert!(plan.k() <= k);
            // Retries stay valid too.
            let retry = p.on_failure(&plan, rng.uniform(0.0, 500.0), 1);
            assert!(retry.is_valid(), "invalid retry {retry:?}");
        });
    }

    #[test]
    fn regression_cols_shape() {
        let mut rng = Rng::new(3);
        let hist: Vec<Execution> =
            (0..7).map(|_| two_phase_exec(rng.uniform(1000.0, 9000.0), &mut rng)).collect();
        let (xs, cols) = KsPlus::regression_cols(3, &hist);
        assert_eq!(xs.len(), 7); // one shared x-column
        assert_eq!(cols.len(), 6); // k start cols + k peak cols
        assert!(cols.iter().all(|c| c.len() == 7));
        // First start column is all zeros (segment 0 starts at 0).
        assert!(cols[0].iter().all(|&s| s == 0.0));
        // The shared x-column is the input sizes in history order.
        for (x, e) in xs.iter().zip(&hist) {
            assert_eq!(*x, e.input_mb);
        }
    }
}
