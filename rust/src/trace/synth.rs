//! Synthetic trace generators (DESIGN.md Section 5 substitution).
//!
//! The paper evaluates on recorded traces of two nf-core workflows. We
//! reproduce their *relevant statistics* with parametric task archetypes:
//!
//! - multi-phase plateau memory profiles (Fig 1b: BWA holds ~5.1 GB for
//!   ~80 % of its runtime, then jumps to ~10.7 GB),
//! - peak memory and phase durations that scale linearly with the
//!   aggregated input size plus heteroscedastic noise (Figs 1a, 3),
//! - per-execution global timing noise with occasional strong outliers
//!   (the red-cross execution of Fig 3),
//! - workflow-level statistics (Fig 5: eager mean peak ~2.31 GB over 9
//!   predicted task types; sarek more instances, mean peak ~1.67 GB).
//!
//! All draws come from an explicit `Rng`, so every workflow trace is a
//! pure function of its seed.

use crate::trace::{Execution, TaskTraces};
use crate::util::rng::Rng;

/// How memory behaves within a phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Ramp {
    /// Constant plateau at the phase level.
    Plateau,
    /// Linear climb from the previous phase's level to this level
    /// (e.g. an input-loading phase).
    Linear,
}

/// One phase of a task's execution profile.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Duration model: seconds = dur_base_s + dur_per_mb * input_mb.
    pub dur_base_s: f64,
    pub dur_per_mb: f64,
    /// Lognormal sigma on the phase duration.
    pub dur_noise: f64,
    /// Memory plateau model: GB = mem_base_gb + mem_per_mb * input_mb.
    pub mem_base_gb: f64,
    pub mem_per_mb: f64,
    /// Lognormal sigma on the plateau level.
    pub mem_noise: f64,
    pub ramp: Ramp,
}

impl Phase {
    pub fn plateau(
        dur_base_s: f64,
        dur_per_mb: f64,
        mem_base_gb: f64,
        mem_per_mb: f64,
    ) -> Phase {
        Phase {
            dur_base_s,
            dur_per_mb,
            dur_noise: 0.10,
            mem_base_gb,
            mem_per_mb,
            mem_noise: 0.05,
            ramp: Ramp::Plateau,
        }
    }

    pub fn linear(
        dur_base_s: f64,
        dur_per_mb: f64,
        mem_base_gb: f64,
        mem_per_mb: f64,
    ) -> Phase {
        Phase { ramp: Ramp::Linear, ..Phase::plateau(dur_base_s, dur_per_mb, mem_base_gb, mem_per_mb) }
    }
}

/// Reusable per-phase scratch for `Archetype::generate_with_input_into`.
#[derive(Debug, Default)]
pub struct GenScratch {
    durs: Vec<f64>,
    levels: Vec<f64>,
}

/// A task type's generative model.
#[derive(Debug, Clone)]
pub struct Archetype {
    pub name: &'static str,
    /// Median aggregated input size, MB (lognormal).
    pub input_median_mb: f64,
    /// Lognormal sigma of the input size distribution.
    pub input_sigma: f64,
    pub phases: Vec<Phase>,
    /// Workflow developers' default memory limit (the Default baseline).
    pub default_limit_gb: f64,
    /// Per-execution global timing factor sigma (Fig 3 spread).
    pub slowdown_sigma: f64,
    /// Probability of a strong timing outlier (Fig 3 red cross).
    pub outlier_prob: f64,
    /// Relative downward within-phase sample jitter.
    pub sample_jitter: f64,
}

impl Archetype {
    fn base(name: &'static str, input_median_mb: f64, phases: Vec<Phase>, default_limit_gb: f64) -> Self {
        Archetype {
            name,
            input_median_mb,
            input_sigma: 0.20,
            phases,
            default_limit_gb,
            slowdown_sigma: 0.12,
            outlier_prob: 0.03,
            sample_jitter: 0.04,
        }
    }

    /// Expected peak memory for a given input size (no noise), GB.
    pub fn expected_peak(&self, input_mb: f64) -> f64 {
        self.phases
            .iter()
            .map(|p| p.mem_base_gb + p.mem_per_mb * input_mb)
            .fold(0.0, f64::max)
    }

    /// Generate one execution. `target_samples` bounds the series length
    /// so traces fit the AOT wastage bucket (N = 512) without truncation.
    pub fn generate(&self, rng: &mut Rng, target_samples: usize) -> Execution {
        let input_mb = self.input_median_mb * rng.log_normal(0.0, self.input_sigma);
        self.generate_with_input(rng, input_mb, target_samples)
    }

    pub fn generate_with_input(
        &self,
        rng: &mut Rng,
        input_mb: f64,
        target_samples: usize,
    ) -> Execution {
        let mut scratch = GenScratch::default();
        let mut out = Execution::new("", 0.0, 0.0, Vec::new());
        self.generate_with_input_into(rng, input_mb, target_samples, &mut scratch, &mut out);
        out
    }

    /// Buffer-reusing variant of [`Archetype::generate_with_input`] for
    /// streaming callers (the scenario engine): the execution is written
    /// into `out` and per-phase scratch lives in `scratch`, so repeated
    /// calls allocate nothing after warm-up. Draws the RNG in exactly the
    /// same order as the allocating API (which is a thin wrapper), so
    /// both produce bit-identical traces from the same RNG state.
    pub fn generate_with_input_into(
        &self,
        rng: &mut Rng,
        input_mb: f64,
        target_samples: usize,
        scratch: &mut GenScratch,
        out: &mut Execution,
    ) {
        // Global timing factor: lognormal plus rare strong outliers.
        let mut speed = rng.log_normal(0.0, self.slowdown_sigma);
        if rng.f64() < self.outlier_prob {
            speed *= if rng.f64() < 0.5 { rng.uniform(0.35, 0.6) } else { rng.uniform(1.7, 2.4) };
        }

        // Realised per-phase durations and levels.
        let durs = &mut scratch.durs;
        let levels = &mut scratch.levels;
        durs.clear();
        levels.clear();
        for p in &self.phases {
            let d = (p.dur_base_s + p.dur_per_mb * input_mb)
                * speed
                * rng.log_normal(0.0, p.dur_noise);
            let l = (p.mem_base_gb + p.mem_per_mb * input_mb) * rng.log_normal(0.0, p.mem_noise);
            durs.push(d.max(1.0));
            levels.push(l.max(0.01));
        }
        let total: f64 = durs.iter().sum();
        let dt = (total / target_samples as f64).max(0.25);
        let n = (total / dt).ceil() as usize;

        out.task.clear();
        out.task.push_str(self.name);
        out.input_mb = input_mb;
        out.dt = dt;
        let samples = &mut out.samples;
        samples.clear();
        samples.reserve(n);
        let mut phase_idx = 0usize;
        let mut phase_start = 0.0f64;
        for i in 0..n {
            let t = i as f64 * dt;
            while phase_idx + 1 < durs.len() && t >= phase_start + durs[phase_idx] {
                phase_start += durs[phase_idx];
                phase_idx += 1;
            }
            let level = levels[phase_idx];
            let base = match self.phases[phase_idx].ramp {
                Ramp::Plateau => level,
                Ramp::Linear => {
                    let prev = if phase_idx == 0 { 0.05 } else { levels[phase_idx - 1] };
                    let frac = ((t - phase_start) / durs[phase_idx]).clamp(0.0, 1.0);
                    prev + (level - prev) * frac
                }
            };
            // Jitter dips below the plateau (heap peaks define the level).
            samples.push(base * (1.0 - self.sample_jitter * rng.f64()));
        }
        // Ensure the realised peak equals the top plateau (monitoring
        // always captures the high-water mark).
        let peak_level = levels.iter().copied().fold(0.0, f64::max);
        if let Some(last_phase_peak_idx) = (0..samples.len()).rev().find(|&i| {
            let t = i as f64 * dt;
            t >= total - durs.last().unwrap()
        }) {
            let max_level_phase =
                levels.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
            if max_level_phase == levels.len() - 1 {
                samples[last_phase_peak_idx] = peak_level;
            }
        }
    }

    /// Generate `n` executions as a `TaskTraces`.
    pub fn generate_many(&self, rng: &mut Rng, n: usize, target_samples: usize) -> TaskTraces {
        TaskTraces {
            task: self.name.to_string(),
            executions: (0..n).map(|_| self.generate(rng, target_samples)).collect(),
        }
    }
}

/// The nine predicted eager task types (Fig 8), parameterised to match the
/// published statistics: bwa is the two-phase heavyweight of Fig 1
/// (median peak ~10.6 GB, ~5.1 GB plateau for ~80 % of the runtime);
/// workflow mean peak ~2.31 GB.
pub fn eager_archetypes() -> Vec<Archetype> {
    vec![
        // BWA: load index (ramp to ~5.1 GB), align for the bulk of the
        // runtime, then a sort/merge phase that doubles memory to ~10.6 GB.
        Archetype {
            slowdown_sigma: 0.15,
            ..Archetype::base(
                "bwa",
                8000.0,
                vec![
                    Phase::linear(40.0, 0.004, 0.30, 0.000600),
                    Phase::plateau(120.0, 0.110, 0.30, 0.000600),
                    Phase::plateau(30.0, 0.028, 0.50, 0.001263),
                ],
                20.0,
            )
        },
        Archetype::base(
            "adapter_removal",
            6000.0,
            vec![
                Phase::plateau(20.0, 0.030, 0.15, 0.000060),
                Phase::plateau(10.0, 0.012, 0.25, 0.000160),
            ],
            4.0,
        ),
        Archetype::base(
            "fastqc",
            6000.0,
            vec![Phase::plateau(15.0, 0.020, 0.30, 0.000033)],
            2.0,
        ),
        Archetype::base(
            "samtools",
            5000.0,
            vec![
                Phase::plateau(10.0, 0.015, 0.20, 0.000050),
                Phase::plateau(20.0, 0.000, 0.35, 0.000090), // constant-duration 2nd process
            ],
            4.0,
        ),
        Archetype::base(
            "mtnucratio",
            1500.0,
            vec![Phase::plateau(25.0, 0.008, 0.10, 0.000200)],
            2.0,
        ),
        Archetype::base(
            "dedup",
            5500.0,
            vec![
                Phase::linear(20.0, 0.010, 0.20, 0.000330),
                Phase::plateau(25.0, 0.020, 0.30, 0.000400),
            ],
            8.0,
        ),
        Archetype::base(
            "damageprofiler",
            2500.0,
            vec![Phase::plateau(30.0, 0.025, 0.25, 0.000500)],
            8.0,
        ),
        Archetype::base(
            "preseq",
            2000.0,
            vec![Phase::plateau(15.0, 0.012, 0.15, 0.000275)],
            4.0,
        ),
        Archetype::base(
            "qualimap",
            3500.0,
            vec![
                Phase::plateau(20.0, 0.018, 0.30, 0.000300),
                Phase::plateau(15.0, 0.006, 0.50, 0.000371),
            ],
            8.0,
        ),
    ]
}

/// Per-task instance counts for eager (more bwa/adapter/fastqc instances,
/// fewer QC-type tasks), ~460 instances total.
pub fn eager_counts() -> Vec<(&'static str, usize)> {
    vec![
        ("bwa", 60),
        ("adapter_removal", 60),
        ("fastqc", 60),
        ("samtools", 60),
        ("mtnucratio", 40),
        ("dedup", 60),
        ("damageprofiler", 40),
        ("preseq", 40),
        ("qualimap", 40),
    ]
}

/// Twelve sarek task types; more instances than eager, mean peak ~1.67 GB.
pub fn sarek_archetypes() -> Vec<Archetype> {
    vec![
        Archetype {
            slowdown_sigma: 0.15,
            ..Archetype::base(
                "bwamem2",
                9000.0,
                vec![
                    Phase::linear(30.0, 0.003, 0.30, 0.000380),
                    Phase::plateau(90.0, 0.080, 0.30, 0.000380),
                    Phase::plateau(25.0, 0.020, 0.40, 0.000733),
                ],
                16.0,
            )
        },
        Archetype::base(
            "markduplicates",
            7000.0,
            vec![
                Phase::linear(15.0, 0.008, 0.25, 0.000260),
                Phase::plateau(20.0, 0.018, 0.40, 0.000414),
            ],
            8.0,
        ),
        Archetype::base(
            "baserecalibrator",
            6000.0,
            vec![Phase::plateau(25.0, 0.020, 0.40, 0.000183)],
            4.0,
        ),
        Archetype::base(
            "applybqsr",
            6000.0,
            vec![Phase::plateau(20.0, 0.015, 0.30, 0.000117)],
            4.0,
        ),
        Archetype::base(
            "strelka",
            4000.0,
            vec![
                Phase::plateau(20.0, 0.012, 0.30, 0.000150),
                Phase::plateau(15.0, 0.000, 0.40, 0.000200),
            ],
            4.0,
        ),
        Archetype::base(
            "mutect2",
            4500.0,
            vec![
                Phase::plateau(30.0, 0.025, 0.40, 0.000250),
                Phase::plateau(20.0, 0.010, 0.60, 0.000422),
            ],
            8.0,
        ),
        Archetype::base(
            "fastqc",
            5000.0,
            vec![Phase::plateau(15.0, 0.018, 0.25, 0.000030)],
            2.0,
        ),
        Archetype::base(
            "samtools_stats",
            5000.0,
            vec![Phase::plateau(12.0, 0.010, 0.20, 0.000080)],
            2.0,
        ),
        Archetype::base(
            "mosdepth",
            5500.0,
            vec![Phase::plateau(15.0, 0.012, 0.25, 0.000100)],
            4.0,
        ),
        Archetype::base(
            "snpeff",
            1200.0,
            vec![
                Phase::linear(10.0, 0.005, 0.40, 0.000300),
                Phase::plateau(20.0, 0.015, 0.60, 0.000750),
            ],
            6.0,
        ),
        Archetype::base(
            "vep",
            1200.0,
            vec![
                Phase::linear(12.0, 0.006, 0.50, 0.000500),
                Phase::plateau(25.0, 0.020, 0.80, 0.001833),
            ],
            8.0,
        ),
        Archetype::base(
            "tabix",
            800.0,
            vec![Phase::plateau(8.0, 0.005, 0.10, 0.000125)],
            1.0,
        ),
    ]
}

/// Per-task instance counts for sarek, ~1060 instances total.
pub fn sarek_counts() -> Vec<(&'static str, usize)> {
    vec![
        ("bwamem2", 80),
        ("markduplicates", 80),
        ("baserecalibrator", 100),
        ("applybqsr", 100),
        ("strelka", 80),
        ("mutect2", 80),
        ("fastqc", 120),
        ("samtools_stats", 100),
        ("mosdepth", 100),
        ("snpeff", 60),
        ("vep", 60),
        ("tabix", 100),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn bwa() -> Archetype {
        eager_archetypes().into_iter().find(|a| a.name == "bwa").unwrap()
    }

    #[test]
    fn generation_is_deterministic() {
        let a = bwa();
        let e1 = a.generate(&mut Rng::new(9), 200);
        let e2 = a.generate(&mut Rng::new(9), 200);
        assert_eq!(e1, e2);
    }

    #[test]
    fn bwa_matches_fig1_statistics() {
        // Median peak ~10.6 GB; first plateau ~5.1 GB holding ~80 % of
        // the runtime (Fig 1a/1b). Allow generous tolerances.
        let a = bwa();
        let mut rng = Rng::new(1);
        let traces = a.generate_many(&mut rng, 200, 200);
        let peaks = traces.peaks();
        let med = stats::median(&peaks);
        assert!((med - 10.6).abs() < 1.6, "median peak {med}");
        // Time share below 70% of peak should be the majority.
        let e = &traces.executions[0];
        let peak = e.peak();
        let below: usize = e.samples.iter().filter(|&&s| s < 0.7 * peak).count();
        let frac = below as f64 / e.samples.len() as f64;
        assert!(frac > 0.6, "low-plateau fraction {frac}");
    }

    #[test]
    fn into_variant_matches_allocating_api() {
        // Same RNG state, dirty reused buffers: bit-identical output.
        let a = bwa();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let mut scratch = GenScratch::default();
        let mut out = Execution::new("stale-task-name", 1.0, 1.0, vec![9.9; 300]);
        for i in 0..20 {
            let input = 4000.0 + 500.0 * i as f64;
            let e = a.generate_with_input(&mut r1, input, 200);
            a.generate_with_input_into(&mut r2, input, 200, &mut scratch, &mut out);
            assert_eq!(e, out);
        }
    }

    #[test]
    fn peaks_scale_with_input() {
        let a = bwa();
        let mut rng = Rng::new(2);
        let small = a.generate_with_input(&mut rng, 4000.0, 200);
        let big = a.generate_with_input(&mut rng, 16000.0, 200);
        assert!(big.peak() > small.peak() * 1.8, "{} vs {}", big.peak(), small.peak());
        assert!(big.duration() > small.duration() * 1.5);
    }

    #[test]
    fn samples_bounded_by_bucket() {
        for a in eager_archetypes().iter().chain(sarek_archetypes().iter()) {
            let mut rng = Rng::new(3);
            for _ in 0..20 {
                let e = a.generate(&mut rng, 200);
                assert!(
                    e.samples.len() <= 512,
                    "{}: {} samples exceeds wastage bucket",
                    a.name,
                    e.samples.len()
                );
                assert!(!e.samples.is_empty());
            }
        }
    }

    #[test]
    fn eager_mean_peak_near_paper() {
        let mut rng = Rng::new(4);
        let mut peaks = Vec::new();
        let arch = eager_archetypes();
        for (name, n) in eager_counts() {
            let a = arch.iter().find(|a| a.name == name).unwrap();
            let t = a.generate_many(&mut rng, n, 150);
            peaks.extend(t.peaks());
        }
        let mean = stats::mean(&peaks);
        assert!((mean - 2.31).abs() < 0.45, "eager mean peak {mean} (paper: 2.31)");
    }

    #[test]
    fn sarek_mean_peak_near_paper() {
        let mut rng = Rng::new(5);
        let mut peaks = Vec::new();
        let arch = sarek_archetypes();
        for (name, n) in sarek_counts() {
            let a = arch.iter().find(|a| a.name == name).unwrap();
            let t = a.generate_many(&mut rng, n, 150);
            peaks.extend(t.peaks());
        }
        let mean = stats::mean(&peaks);
        assert!((mean - 1.67).abs() < 0.35, "sarek mean peak {mean} (paper: 1.67)");
    }

    #[test]
    fn sarek_has_more_instances_than_eager() {
        let e: usize = eager_counts().iter().map(|(_, n)| n).sum();
        let s: usize = sarek_counts().iter().map(|(_, n)| n).sum();
        assert!(s > e);
    }

    #[test]
    fn defaults_cover_typical_peaks() {
        // The developer default should cover the expected peak at the
        // median input for every archetype (it is an overestimate).
        for a in eager_archetypes().iter().chain(sarek_archetypes().iter()) {
            let p = a.expected_peak(a.input_median_mb);
            assert!(
                a.default_limit_gb > p * 1.2,
                "{}: default {} vs expected peak {p}",
                a.name,
                a.default_limit_gb
            );
        }
    }

    #[test]
    fn monotone_ramp_phase_climbs() {
        let a = Archetype::base(
            "ramp",
            1000.0,
            vec![Phase::linear(100.0, 0.0, 1.0, 0.001), Phase::plateau(50.0, 0.0, 2.0, 0.001)],
            8.0,
        );
        let e = a.generate(&mut Rng::new(7), 150);
        // First-phase samples should be increasing on average.
        let q1 = e.samples[e.samples.len() / 8];
        let q3 = e.samples[e.samples.len() / 3];
        assert!(q3 > q1, "ramp should climb: {q1} vs {q3}");
    }

    #[test]
    fn input_sizes_lognormal_spread() {
        let a = bwa();
        let mut rng = Rng::new(11);
        let t = a.generate_many(&mut rng, 300, 100);
        let inputs = t.input_sizes();
        let med = stats::median(&inputs);
        assert!((med / 8000.0 - 1.0).abs() < 0.15, "median input {med}");
        let max = inputs.iter().cloned().fold(0.0, f64::max);
        let min = inputs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 1.5, "spread too small: {min}..{max}");
    }
}
