//! Discrete-event cluster simulator: multiple nodes, time-varying memory
//! reservations (the step-function plans), FIFO admission, OOM-driven
//! restarts.
//!
//! This translates per-task memory efficiency into the cluster-level
//! throughput the paper's introduction motivates: tighter plans admit
//! more concurrent tasks per node, shortening the makespan. Admission is
//! conservative: a task starts only if the *combined future reservation
//! profile* of the node never exceeds capacity — dynamic plans are
//! honoured exactly, not flattened to their peak.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

use crate::metrics::{TaskOutcome, WastageReport};
use crate::predictor::Predictor;
use crate::segments::StepPlan;
use crate::sim::MAX_RETRIES;
use crate::trace::Execution;

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub node_capacity_gb: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // The paper's testbed: one 128 GB node; examples scale this up.
        ClusterConfig { nodes: 1, node_capacity_gb: 128.0 }
    }
}

#[derive(Debug, Clone)]
struct Running {
    start_abs: f64,
    end_abs: f64,
    plan: StepPlan,
    job: usize,
}

#[derive(Debug, Clone)]
struct Job {
    exec: Execution,
    plan: StepPlan,
    attempt: usize,
    wastage_gbs: f64,
}

/// Cluster-level result.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    pub makespan_s: f64,
    pub outcomes: Vec<TaskOutcome>,
    pub report: WastageReport,
    /// Tasks completed per simulated hour.
    pub throughput_per_h: f64,
    /// Mean queue wait, seconds.
    pub mean_wait_s: f64,
    /// Peak simultaneous reservation observed per node, GB.
    pub peak_reserved_gb: Vec<f64>,
}

#[derive(Debug, PartialEq)]
struct Ev(f64, usize); // (time, node)
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

/// Source of trained predictors, one per task type.
pub trait PredictorSource {
    fn get(&self, task: &str) -> Option<&dyn Predictor>;
}

impl PredictorSource for std::collections::BTreeMap<String, Box<dyn Predictor>> {
    fn get(&self, task: &str) -> Option<&dyn Predictor> {
        std::collections::BTreeMap::get(self, task).map(|p| p.as_ref())
    }
}

/// A single predictor used for every task type (tests, quick demos).
pub struct SinglePredictor<P: Predictor>(pub P);

impl<P: Predictor> PredictorSource for SinglePredictor<P> {
    fn get(&self, _task: &str) -> Option<&dyn Predictor> {
        Some(&self.0)
    }
}

/// Simulate a batch of executions on the cluster with per-task-type
/// predictors. `predictors` maps task name -> trained predictor.
pub fn run_cluster(
    cfg: &ClusterConfig,
    predictors: &dyn PredictorSource,
    executions: &[Execution],
) -> ClusterResult {
    let mut queue: VecDeque<usize> = (0..executions.len()).collect();
    let mut jobs: Vec<Job> = executions
        .iter()
        .map(|e| {
            let pred = predictors.get(&e.task).expect("no predictor for task");
            Job {
                exec: e.clone(),
                plan: pred.plan(e.input_mb).clamped(cfg.node_capacity_gb),
                attempt: 0,
                wastage_gbs: 0.0,
            }
        })
        .collect();
    let mut submit_time = vec![0.0f64; executions.len()];
    let mut wait_total = 0.0f64;
    let mut running: Vec<Vec<Running>> = vec![Vec::new(); cfg.nodes];
    let mut peak_reserved = vec![0.0f64; cfg.nodes];
    let mut events: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut outcomes: Vec<Option<TaskOutcome>> = vec![None; executions.len()];
    let mut now = 0.0f64;
    let mut done = 0usize;

    // Reservation of a node at absolute time t.
    let reserved_at = |running: &[Running], t: f64| -> f64 {
        running
            .iter()
            .filter(|r| r.start_abs <= t && t < r.end_abs)
            .map(|r| r.plan.alloc_at(t - r.start_abs))
            .sum()
    };
    // Would adding (plan, start, end) ever exceed capacity on this node?
    let fits = |running: &[Running], plan: &StepPlan, start: f64, end: f64, cap: f64| -> bool {
        // Check at every breakpoint of the combined profile in [start,end).
        let mut points: Vec<f64> = vec![start];
        for s in &plan.starts {
            let t = start + s;
            if t < end {
                points.push(t);
            }
        }
        for r in running {
            for s in &r.plan.starts {
                let t = r.start_abs + s;
                if t >= start && t < end {
                    points.push(t);
                }
            }
            if r.start_abs > start && r.start_abs < end {
                points.push(r.start_abs);
            }
        }
        points.iter().all(|&t| {
            reserved_at(running, t) + plan.alloc_at(t - start) <= cap + 1e-9
        })
    };

    loop {
        // Admit every queued job FIFO at its earliest feasible start:
        // candidate start times are `now` plus every breakpoint/end of
        // already-placed reservations (the combined profile only changes
        // there). Jobs may be placed in the future; completions and
        // OOM restarts re-enter the queue and are re-planned here.
        while let Some(&job_idx) = queue.front() {
            let job = &jobs[job_idx];
            // Attempt runtime: until OOM or completion.
            let end_rel = match job.plan.first_oom(&job.exec) {
                Some((t, _)) => t.max(job.exec.dt),
                None => job.exec.duration(),
            };
            // Earliest feasible (node, start).
            let mut best: Option<(usize, f64)> = None;
            for (n, r) in running.iter().enumerate() {
                let mut cands: Vec<f64> = vec![now];
                for run in r {
                    for s in &run.plan.starts {
                        let t = run.start_abs + s;
                        if t > now {
                            cands.push(t);
                        }
                    }
                    if run.end_abs > now {
                        cands.push(run.end_abs);
                    }
                }
                cands.sort_by(|a, b| a.total_cmp(b));
                cands.dedup();
                for &t0 in &cands {
                    if fits(r, &job.plan, t0, t0 + end_rel, cfg.node_capacity_gb) {
                        if best.map_or(true, |(_, bt)| t0 < bt) {
                            best = Some((n, t0));
                        }
                        break;
                    }
                }
            }
            let Some((n, t0)) = best else {
                break; // plan alone exceeds capacity; handled below
            };
            queue.pop_front();
            wait_total += t0 - submit_time[job_idx];
            running[n].push(Running {
                start_abs: t0,
                end_abs: t0 + end_rel,
                plan: jobs[job_idx].plan.clone(),
                job: job_idx,
            });
            let res = reserved_at(&running[n], t0);
            peak_reserved[n] = peak_reserved[n].max(res);
            events.push(Reverse(Ev(t0 + end_rel, n)));
        }

        if done == executions.len() {
            break;
        }
        let Some(Reverse(Ev(t, node))) = events.pop() else {
            // Nothing running but jobs remain: a job alone exceeds the
            // node; force-fail it to completion accounting.
            if let Some(job_idx) = queue.pop_front() {
                let job = &mut jobs[job_idx];
                outcomes[job_idx] = Some(TaskOutcome {
                    task: job.exec.task.clone(),
                    input_mb: job.exec.input_mb,
                    attempts: job.attempt + 1,
                    success: false,
                    wastage_gbs: job.wastage_gbs,
                    alloc_gbs: 0.0,
                    used_gbs: job.exec.used_gbs(),
                });
                done += 1;
                continue;
            }
            break;
        };
        now = t;
        // Complete every run ending at t on this node.
        let finished: Vec<Running> = {
            let r = &mut running[node];
            let (f, keep): (Vec<Running>, Vec<Running>) =
                r.drain(..).partition(|x| (x.end_abs - t).abs() < 1e-9);
            *r = keep;
            f
        };
        for run in finished {
            let job_idx = run.job;
            let job = &mut jobs[job_idx];
            match job.plan.first_oom(&job.exec) {
                None => {
                    job.wastage_gbs += job.plan.wastage_gbs(&job.exec);
                    outcomes[job_idx] = Some(TaskOutcome {
                        task: job.exec.task.clone(),
                        input_mb: job.exec.input_mb,
                        attempts: job.attempt + 1,
                        success: true,
                        wastage_gbs: job.wastage_gbs,
                        alloc_gbs: job.plan.alloc_gbs(job.exec.duration()),
                        used_gbs: job.exec.used_gbs(),
                    });
                    done += 1;
                }
                Some((t_fail, _)) => {
                    job.wastage_gbs += job.plan.alloc_gbs(t_fail.max(job.exec.dt));
                    job.attempt += 1;
                    if job.attempt > MAX_RETRIES {
                        outcomes[job_idx] = Some(TaskOutcome {
                            task: job.exec.task.clone(),
                            input_mb: job.exec.input_mb,
                            attempts: job.attempt,
                            success: false,
                            wastage_gbs: job.wastage_gbs,
                            alloc_gbs: 0.0,
                            used_gbs: job.exec.used_gbs(),
                        });
                        done += 1;
                    } else {
                        let pred = predictors.get(&job.exec.task).expect("predictor");
                        job.plan = if job.attempt == MAX_RETRIES {
                            StepPlan::flat(cfg.node_capacity_gb)
                        } else {
                            pred.on_failure(&job.plan, t_fail, job.attempt)
                                .clamped(cfg.node_capacity_gb)
                        };
                        submit_time[job_idx] = now;
                        queue.push_back(job_idx);
                    }
                }
            }
        }
    }

    let outcomes: Vec<TaskOutcome> = outcomes.into_iter().flatten().collect();
    let report = WastageReport::from_outcomes(&outcomes);
    let makespan = now;
    ClusterResult {
        makespan_s: makespan,
        throughput_per_h: if makespan > 0.0 {
            outcomes.len() as f64 / (makespan / 3600.0)
        } else {
            0.0
        },
        mean_wait_s: if outcomes.is_empty() { 0.0 } else { wait_total / outcomes.len() as f64 },
        peak_reserved_gb: peak_reserved,
        outcomes,
        report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::DefaultLimits;
    use crate::predictor::Predictor;
    use crate::trace::Execution;

    fn exec(task: &str, samples: Vec<f64>) -> Execution {
        Execution::new(task, 100.0, 1.0, samples)
    }

    fn with_pred<R>(limit: f64, f: impl FnOnce(&dyn PredictorSource) -> R) -> R {
        let src = SinglePredictor(DefaultLimits::with_limit(128.0, limit));
        f(&src)
    }

    #[test]
    fn single_task_completes() {
        let cfg = ClusterConfig { nodes: 1, node_capacity_gb: 128.0 };
        with_pred(8.0, |preds| {
            let r = run_cluster(&cfg, preds, &[exec("a", vec![1.0, 2.0, 3.0])]);
            assert_eq!(r.outcomes.len(), 1);
            assert!(r.outcomes[0].success);
            assert_eq!(r.makespan_s, 3.0);
            assert!(r.throughput_per_h > 0.0);
        });
    }

    #[test]
    fn capacity_limits_concurrency() {
        // Two 60 GB tasks of 10 s each on a 100 GB node must serialise:
        // makespan 20 s. On a 128 GB node they could overlap.
        let cfg = ClusterConfig { nodes: 1, node_capacity_gb: 100.0 };
        let tasks = vec![exec("a", vec![50.0; 10]), exec("a", vec![50.0; 10])];
        with_pred(60.0, |preds| {
            let r = run_cluster(&cfg, preds, &tasks);
            assert!(r.outcomes.iter().all(|o| o.success));
            assert!((r.makespan_s - 20.0).abs() < 1e-6, "makespan {}", r.makespan_s);
        });
        let cfg2 = ClusterConfig { nodes: 1, node_capacity_gb: 128.0 };
        with_pred(60.0, |preds| {
            let r = run_cluster(&cfg2, preds, &tasks);
            assert!((r.makespan_s - 10.0).abs() < 1e-6, "makespan {}", r.makespan_s);
        });
    }

    #[test]
    fn more_nodes_shorten_makespan() {
        let tasks: Vec<Execution> =
            (0..4).map(|_| exec("a", vec![50.0; 10])).collect();
        let m1 = with_pred(60.0, |preds| {
            run_cluster(&ClusterConfig { nodes: 1, node_capacity_gb: 100.0 }, preds, &tasks)
                .makespan_s
        });
        let m4 = with_pred(60.0, |preds| {
            run_cluster(&ClusterConfig { nodes: 4, node_capacity_gb: 100.0 }, preds, &tasks)
                .makespan_s
        });
        assert!(m4 < m1, "{m4} !< {m1}");
    }

    #[test]
    fn oom_restarts_and_finishes() {
        // Task needs 10 GB; default limit 4 -> OOM, retry doubles to 8,
        // then 16: succeeds on third attempt.
        let cfg = ClusterConfig::default();
        with_pred(4.0, |preds| {
            let r = run_cluster(&cfg, preds, &[exec("a", vec![2.0, 10.0, 10.0])]);
            assert_eq!(r.outcomes.len(), 1);
            let o = &r.outcomes[0];
            assert!(o.success);
            assert_eq!(o.attempts, 3);
            assert!(o.wastage_gbs > 0.0);
        });
    }

    #[test]
    fn dynamic_plans_pack_tighter_than_flat() {
        // Step plans (small first segment) overlap where flat peaks
        // cannot: 2 tasks, each 2 GB for 90 s then 60 GB for 10 s, on a
        // 100 GB node.
        struct StepPred;
        impl Predictor for StepPred {
            fn name(&self) -> &'static str {
                "step"
            }
            fn train(&mut self, _h: &[Execution]) {}
            fn plan(&self, _i: f64) -> StepPlan {
                StepPlan::new(vec![0.0, 90.0], vec![2.5, 62.0])
            }
            fn on_failure(&self, p: &StepPlan, _t: f64, _a: usize) -> StepPlan {
                StepPlan::flat(p.last_peak_or(1.0) * 2.0)
            }
        }
        struct FlatPred;
        impl Predictor for FlatPred {
            fn name(&self) -> &'static str {
                "flat"
            }
            fn train(&mut self, _h: &[Execution]) {}
            fn plan(&self, _i: f64) -> StepPlan {
                StepPlan::flat(62.0)
            }
            fn on_failure(&self, p: &StepPlan, _t: f64, _a: usize) -> StepPlan {
                StepPlan::flat(p.last_peak_or(1.0) * 2.0)
            }
        }
        let mut samples = vec![2.0; 90];
        samples.extend(vec![60.0; 10]);
        let tasks = vec![exec("a", samples.clone()), exec("a", samples)];
        let cfg = ClusterConfig { nodes: 1, node_capacity_gb: 100.0 };
        let step_r = run_cluster(&cfg, &SinglePredictor(StepPred), &tasks);
        let flat_r = run_cluster(&cfg, &SinglePredictor(FlatPred), &tasks);
        assert!(step_r.outcomes.iter().all(|o| o.success));
        assert!(
            step_r.makespan_s < flat_r.makespan_s,
            "step {} !< flat {}",
            step_r.makespan_s,
            flat_r.makespan_s
        );
    }

    #[test]
    fn on_failure_survives_empty_step_plan() {
        // Regression: `p.peaks.last().unwrap()` aborted on a degenerate
        // (empty) plan. An empty plan cannot come out of StepPlan::new —
        // it asserts — but the fields are public, so a buggy caller (or
        // deserialized garbage) could still hand one to a retry path.
        // Every retry strategy must fall back to a default allocation.
        use crate::predictor::{all_methods, by_name};
        let empty = StepPlan { starts: vec![], peaks: vec![] };
        for m in all_methods() {
            let p = by_name(m, 4, 128.0).unwrap();
            let retry = p.on_failure(&empty, 10.0, 1);
            assert!(retry.is_valid(), "{m}: invalid fallback {retry:?}");
            assert!(retry.peaks.iter().all(|&x| x <= 128.0));
        }
        // The shared accessor behind those fallbacks.
        assert_eq!(empty.last_peak_or(3.5), 3.5);
        assert_eq!(StepPlan::flat(7.0).last_peak_or(3.5), 7.0);
    }

    #[test]
    fn impossible_task_reported_unfinished() {
        // 300 GB usage can never fit 128 GB: after MAX_RETRIES it is
        // reported unsuccessful, and the simulation terminates.
        let cfg = ClusterConfig::default();
        with_pred(4.0, |preds| {
            let r = run_cluster(&cfg, preds, &[exec("a", vec![300.0, 300.0])]);
            assert_eq!(r.outcomes.len(), 1);
            assert!(!r.outcomes[0].success);
        });
    }

    #[test]
    fn wait_time_accounted() {
        let cfg = ClusterConfig { nodes: 1, node_capacity_gb: 100.0 };
        let tasks = vec![exec("a", vec![50.0; 10]), exec("a", vec![50.0; 10])];
        with_pred(60.0, |preds| {
            let r = run_cluster(&cfg, preds, &tasks);
            // Second task waits 10 s; mean = 5 s.
            assert!((r.mean_wait_s - 5.0).abs() < 1e-6, "wait {}", r.mean_wait_s);
        });
    }
}
