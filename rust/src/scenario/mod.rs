//! Declarative scenario engine: stress evaluation beyond the paper.
//!
//! A scenario turns a base execution source — a named synthetic workflow
//! or an ingested nf-core long-form CSV — into a lazy, seeded stream of
//! perturbed task executions, and replays it through the offline OOM/retry
//! simulator under every serving policy. The result is the per-
//! (scenario × policy) wastage/failure/retry matrix behind
//! `repro scenarios --matrix` and `BENCH_scenarios.json`.
//!
//! Each scenario is a [`ScenarioSpec`]: a pure value parsed from the same
//! `name=...,param=...` grammar as `coordinator::faults::FaultSpec`, and
//! every random draw comes from RNG streams forked from `seed` — the same
//! spec always reproduces a bit-identical stream and matrix row.
//!
//! Built-in scenarios ([`SCENARIO_NAMES`]):
//!
//! - `baseline`      — the unperturbed source distribution;
//! - `heavy-tail`    — Pareto-tailed input sizes (shape `alpha`, capped);
//! - `drift`         — concept drift: after `at`·n executions the
//!   memory-per-input relationship shifts by `factor` (models must
//!   degrade, then recover as they retrain on the post-drift window);
//! - `correlated`    — co-located groups of `group` consecutive
//!   executions share one input-size multiplier (lognormal `rho`);
//! - `retry-storm`   — a `prob` fraction of executions spike to
//!   `factor`× memory, driving clustered OOM/retry loops;
//! - `stragglers`    — a `prob` fraction of executions run `slow`×
//!   longer, stretching DAG stage makespans (see `engine::run_scenario_dag`).

pub mod engine;
pub mod stream;

use std::path::PathBuf;

use anyhow::{bail, Result};

/// The built-in scenario names, in matrix order.
pub const SCENARIO_NAMES: [&str; 6] =
    ["baseline", "heavy-tail", "drift", "correlated", "retry-storm", "stragglers"];

/// Which perturbation a scenario applies to its base stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Baseline,
    HeavyTail,
    Drift,
    Correlated,
    RetryStorm,
    Stragglers,
}

impl Kind {
    pub fn from_name(name: &str) -> Option<Kind> {
        Some(match name {
            "baseline" => Kind::Baseline,
            "heavy-tail" => Kind::HeavyTail,
            "drift" => Kind::Drift,
            "correlated" => Kind::Correlated,
            "retry-storm" => Kind::RetryStorm,
            "stragglers" => Kind::Stragglers,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Kind::Baseline => "baseline",
            Kind::HeavyTail => "heavy-tail",
            Kind::Drift => "drift",
            Kind::Correlated => "correlated",
            Kind::RetryStorm => "retry-storm",
            Kind::Stragglers => "stragglers",
        }
    }
}

/// A fully-specified, seeded scenario. Everything the stream and the
/// replay engine do is a pure function of this value.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Scenario name (one of [`SCENARIO_NAMES`]).
    pub name: String,
    /// Synthetic source workflow (`eager` or `sarek`); ignored when
    /// `trace` is set.
    pub workflow: String,
    /// Ingested trace CSV (either supported header shape) as the base
    /// distribution instead of the synthetic workflow.
    pub trace: Option<PathBuf>,
    /// Executions to replay per (scenario, policy).
    pub n: usize,
    pub seed: u64,
    /// Target samples per synthetic execution (bounded by the wastage
    /// bucket, as everywhere else).
    pub target_samples: usize,
    /// Synthetic training executions per task.
    pub train_per_task: usize,
    /// Train fraction for trace sources (`split_train_test`).
    pub train_frac: f64,
    /// Refit a task's predictor after this many stream occurrences of the
    /// task (0 disables online retraining).
    pub retrain_every: usize,
    /// Sliding-window size (executions) the refits train on.
    pub window: usize,
    /// Segment count for the segment-based policies.
    pub k: usize,
    /// Node capacity, GB.
    pub capacity_gb: f64,
    /// heavy-tail: Pareto shape (> 1 keeps the mean finite).
    pub alpha: f64,
    /// drift: fraction of the run after which the shift applies, (0,1).
    pub at: f64,
    /// drift / retry-storm: memory multiplier.
    pub factor: f64,
    /// correlated: consecutive executions per co-located group.
    pub group: usize,
    /// correlated: lognormal sigma of the shared group multiplier.
    pub rho: f64,
    /// retry-storm / stragglers: per-execution perturbation probability.
    pub prob: f64,
    /// stragglers: duration multiplier for perturbed executions.
    pub slow: f64,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            name: "baseline".to_string(),
            workflow: "eager".to_string(),
            trace: None,
            n: 10_000,
            seed: 42,
            target_samples: 200,
            train_per_task: 48,
            train_frac: 0.5,
            retrain_every: 32,
            window: 96,
            k: 4,
            capacity_gb: 128.0,
            alpha: 1.3,
            at: 0.5,
            factor: 2.0,
            group: 8,
            rho: 0.4,
            prob: 0.05,
            slow: 4.0,
        }
    }
}

impl ScenarioSpec {
    /// Parse the `name=...,param=...` grammar (same shape as
    /// `coordinator::faults::FaultSpec::parse`). `name` is required;
    /// every other key overrides a default.
    pub fn parse(s: &str) -> Result<ScenarioSpec> {
        fn num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T> {
            match value.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!("scenario spec: {key}={value} is not a valid number"),
            }
        }
        let mut spec = ScenarioSpec::default();
        let mut saw_name = false;
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, value)) = part.split_once('=') else {
                bail!("scenario spec: '{part}' is not key=value");
            };
            let (key, value) = (key.trim(), value.trim());
            match key {
                "name" => {
                    if Kind::from_name(value).is_none() {
                        bail!(
                            "unknown scenario '{value}' (valid: {})",
                            SCENARIO_NAMES.join(", ")
                        );
                    }
                    spec.name = value.to_string();
                    saw_name = true;
                }
                "workflow" => spec.workflow = value.to_string(),
                "trace" => spec.trace = Some(PathBuf::from(value)),
                "n" => spec.n = num(key, value)?,
                "seed" => spec.seed = num(key, value)?,
                "target-samples" => spec.target_samples = num(key, value)?,
                "train-per-task" => spec.train_per_task = num(key, value)?,
                "train-frac" => spec.train_frac = num(key, value)?,
                "retrain-every" => spec.retrain_every = num(key, value)?,
                "window" => spec.window = num(key, value)?,
                "k" => spec.k = num(key, value)?,
                "capacity" => spec.capacity_gb = num(key, value)?,
                "alpha" => spec.alpha = num(key, value)?,
                "at" => spec.at = num(key, value)?,
                "factor" => spec.factor = num(key, value)?,
                "group" => spec.group = num(key, value)?,
                "rho" => spec.rho = num(key, value)?,
                "prob" => spec.prob = num(key, value)?,
                "slow" => spec.slow = num(key, value)?,
                _ => bail!(
                    "scenario spec: unknown key '{key}' (valid: name, workflow, trace, n, \
                     seed, target-samples, train-per-task, train-frac, retrain-every, \
                     window, k, capacity, alpha, at, factor, group, rho, prob, slow)"
                ),
            }
        }
        if !saw_name {
            bail!("scenario spec needs name=<scenario> (valid: {})", SCENARIO_NAMES.join(", "));
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Range-check every parameter; `parse` calls this, and programmatic
    /// constructors should too.
    pub fn validate(&self) -> Result<()> {
        if Kind::from_name(&self.name).is_none() {
            bail!("unknown scenario '{}'", self.name);
        }
        if self.trace.is_none() && crate::trace::workflow::Workflow::by_name(&self.workflow).is_none()
        {
            bail!("unknown workflow '{}' (valid: eager, sarek)", self.workflow);
        }
        if self.n == 0 {
            bail!("scenario spec: n must be >= 1");
        }
        if self.target_samples == 0 {
            bail!("scenario spec: target-samples must be >= 1");
        }
        if self.train_per_task < 2 {
            bail!("scenario spec: train-per-task must be >= 2");
        }
        if !(self.train_frac > 0.0 && self.train_frac < 1.0) {
            bail!("scenario spec: train-frac must be in (0,1)");
        }
        if self.window < 2 {
            bail!("scenario spec: window must be >= 2");
        }
        if self.k == 0 {
            bail!("scenario spec: k must be >= 1");
        }
        if self.capacity_gb <= 0.0 {
            bail!("scenario spec: capacity must be positive");
        }
        if self.alpha <= 1.0 {
            bail!("scenario spec: alpha must be > 1 (finite-mean Pareto)");
        }
        if !(self.at > 0.0 && self.at < 1.0) {
            bail!("scenario spec: at must be in (0,1)");
        }
        if self.factor <= 0.0 {
            bail!("scenario spec: factor must be positive");
        }
        if self.group == 0 {
            bail!("scenario spec: group must be >= 1");
        }
        if self.rho < 0.0 {
            bail!("scenario spec: rho must be >= 0");
        }
        if !(0.0..=1.0).contains(&self.prob) {
            bail!("scenario spec: prob must be in [0,1]");
        }
        if self.slow < 1.0 {
            bail!("scenario spec: slow must be >= 1");
        }
        Ok(())
    }

    /// The perturbation kind; valid after `validate`.
    pub fn kind(&self) -> Kind {
        Kind::from_name(&self.name).expect("validated scenario name")
    }
}

/// The six built-in scenarios with default parameters.
pub fn presets() -> Vec<ScenarioSpec> {
    SCENARIO_NAMES
        .iter()
        .map(|n| ScenarioSpec { name: n.to_string(), ..ScenarioSpec::default() })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_defaults_and_overrides() {
        let s = ScenarioSpec::parse("name=heavy-tail, alpha=1.7, n=500, seed=7").unwrap();
        assert_eq!(s.kind(), Kind::HeavyTail);
        assert_eq!(s.n, 500);
        assert_eq!(s.seed, 7);
        assert!((s.alpha - 1.7).abs() < 1e-12);
        // Untouched keys keep their defaults.
        assert_eq!(s.workflow, "eager");
        assert_eq!(s.window, 96);
    }

    #[test]
    fn parse_accepts_every_preset() {
        for name in SCENARIO_NAMES {
            let s = ScenarioSpec::parse(&format!("name={name}")).unwrap();
            assert_eq!(s.name, name);
            assert_eq!(s.kind().name(), name);
        }
        assert_eq!(presets().len(), SCENARIO_NAMES.len());
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "",                              // missing name
            "n=100",                         // missing name
            "name=unheard-of",               // unknown scenario
            "name=drift,at=1.5",             // at out of range
            "name=drift,bogus=1",            // unknown key
            "name=drift,at",                 // not key=value
            "name=heavy-tail,alpha=0.5",     // infinite-mean tail
            "name=heavy-tail,alpha=abc",     // not a number
            "name=baseline,workflow=nope",   // unknown workflow
            "name=retry-storm,prob=1.5",     // prob out of range
            "name=stragglers,slow=0.5",      // speed-up is not a straggler
            "name=baseline,n=0",             // empty run
            "name=baseline,train-frac=1.0",  // no test set
        ] {
            assert!(ScenarioSpec::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn trace_spec_skips_workflow_validation() {
        let s = ScenarioSpec::parse("name=baseline,trace=some/file.csv,workflow=whatever")
            .unwrap();
        assert_eq!(s.trace.as_deref(), Some(std::path::Path::new("some/file.csv")));
    }
}
