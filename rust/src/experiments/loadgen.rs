//! Closed-loop service load generator: measures the sharded coordinator
//! the way a workflow engine would drive it — M client threads, each
//! blocking on its previous plan before submitting the next — and reports
//! plans/sec and latency percentiles per shard count.
//!
//! This is the scaling proof for the worker pool: at equal client count,
//! `shards: N` on an N-core machine should sustain a multiple of the
//! single-shard throughput because every shard owns an independent model
//! store, backend, and batcher. Exposed as `repro loadgen`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::service::{Coordinator, CoordinatorConfig, ServiceStats};
use crate::coordinator::{BackendSpec, PredictorPolicy};
use crate::trace::workflow::Workflow;
use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Coordinator worker shards.
    pub shards: usize,
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Total plan requests (split across clients, rounded up per client).
    pub requests: usize,
    /// Probability in [0, 1] that a client folds an `observe` (one
    /// finished execution, O(k) incremental model update) in front of a
    /// plan request — the online-retraining mix. 0 reproduces the pure
    /// plan workload.
    pub observe_frac: f64,
    /// Segments per task model.
    pub k: usize,
    /// Workflow whose task mix drives the request stream.
    pub workflow: String,
    /// Numeric backend for every shard.
    pub spec: BackendSpec,
    /// Predictor policy every task trains and serves under — measures a
    /// baseline-serving workload instead of the KS+ default.
    pub policy: PredictorPolicy,
    /// Chaos mode: crash-and-restore this many shards (round-robin, one
    /// at a time, spaced through the run) while the clients hammer the
    /// pool. Each kill amnesia-wipes one shard and restores it from its
    /// ring-standby replicas; the run still fails if a single
    /// observation is lost or an invalid plan is served. Requires
    /// `shards >= 2` (a lone shard has no standby).
    pub chaos_kills: usize,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            shards: 1,
            clients: 8,
            requests: 5000,
            observe_frac: 0.0,
            k: 4,
            workflow: "eager".to_string(),
            spec: BackendSpec::Native,
            policy: PredictorPolicy::KsPlus,
            chaos_kills: 0,
        }
    }
}

/// One load-generation run's measurements.
#[derive(Debug, Clone)]
pub struct LoadGenReport {
    pub shards: usize,
    pub clients: usize,
    /// Policy the workload trained and served under.
    pub policy: &'static str,
    /// Plan requests actually issued (>= the configured total after
    /// per-client rounding).
    pub requests: u64,
    pub elapsed_s: f64,
    pub plans_per_s: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// `observe` ops issued alongside the plan stream.
    pub observes: u64,
    pub observes_per_s: f64,
    /// Plan requests each shard served, in shard order.
    pub per_shard_requests: Vec<u64>,
    /// Shard crash/restore cycles performed during the run.
    pub chaos_kills: u64,
}

impl LoadGenReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", self.shards.into()),
            ("clients", self.clients.into()),
            ("policy", self.policy.into()),
            ("requests", (self.requests as usize).into()),
            ("elapsed_s", self.elapsed_s.into()),
            ("plans_per_s", self.plans_per_s.into()),
            ("p50_us", self.p50_us.into()),
            ("p99_us", self.p99_us.into()),
            ("batches", (self.batches as usize).into()),
            ("mean_batch_size", self.mean_batch_size.into()),
            ("observes", (self.observes as usize).into()),
            ("observes_per_s", self.observes_per_s.into()),
            (
                "per_shard_requests",
                Json::Arr(
                    self.per_shard_requests.iter().map(|&r| (r as usize).into()).collect(),
                ),
            ),
            ("chaos_kills", (self.chaos_kills as usize).into()),
        ])
    }
}

/// Write the sweep's reports as the machine-readable `BENCH_hotpath.json`
/// "plans" section (schema shared with `cargo bench --bench hotpath`).
///
/// Merges into an existing schema-compatible file instead of clobbering
/// it, so running the hotpath bench (which owns the segmentation/observe
/// sections) and then this sweep leaves both sets of numbers in place.
pub fn write_bench_json(path: &std::path::Path, reports: &[LoadGenReport]) -> Result<()> {
    const SCHEMA: &str = "ksplus-bench-hotpath/v1";
    let mut doc = match std::fs::read_to_string(path).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(existing) if existing.get("schema").and_then(Json::as_str) == Some(SCHEMA) => {
            existing
        }
        _ => Json::obj(vec![("schema", SCHEMA.into())]),
    };
    if let Json::Obj(map) = &mut doc {
        map.insert("source".to_string(), "repro-loadgen".into());
        map.insert(
            "plans".to_string(),
            Json::Arr(reports.iter().map(LoadGenReport::to_json).collect()),
        );
    }
    // A nested output path must not lose the sweep at the very end:
    // create the parent directories before writing.
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Train every task of the workflow, then hammer the coordinator from
/// `clients` closed-loop threads and collect the merged service stats.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    anyhow::ensure!(cfg.clients >= 1, "loadgen needs at least one client");
    anyhow::ensure!(cfg.requests >= 1, "loadgen needs at least one request");
    anyhow::ensure!(
        (0.0..=1.0).contains(&cfg.observe_frac),
        "observe_frac must be in [0, 1]"
    );
    anyhow::ensure!(
        cfg.chaos_kills == 0 || cfg.shards >= 2,
        "chaos kills need at least 2 shards (a lone shard has no standby to restore from)"
    );
    let wf = Workflow::by_name(&cfg.workflow)
        .with_context(|| format!("unknown workflow '{}'", cfg.workflow))?;
    let trace = wf.generate(42, 150);
    let coord = Coordinator::start(
        CoordinatorConfig {
            k: cfg.k,
            shards: cfg.shards,
            // No straggler linger: closed-loop clients would otherwise
            // serialize on the poll whenever a shard has one pending
            // request, and the sweep would measure the linger knob
            // instead of pool capacity. The drain loop still batches.
            batch_delay: Duration::ZERO,
            default_policy: cfg.policy,
            ..Default::default()
        },
        cfg.spec.clone(),
    )
    .context("start coordinator")?;
    let client = coord.client();
    // With an observe mix, train on a held-out prefix: the tail of each
    // task's trace is kept back so `observe` streams genuinely unseen
    // executions (true online retraining, not a duplicate replay). At
    // observe_frac == 0 the full history is trained, keeping the pure
    // plan workload identical to earlier sweeps.
    let holdout = if cfg.observe_frac > 0.0 { 8 } else { 0 };
    let mut obs_mix: Vec<(String, crate::trace::Execution)> = Vec::new();
    for t in &trace.tasks {
        let split = t.executions.len().saturating_sub(holdout).max(1).min(t.executions.len());
        client.train(&t.task, t.executions[..split].to_vec());
        for e in &t.executions[split..] {
            obs_mix.push((t.task.clone(), e.clone()));
        }
    }
    // The request mix: every task type with a spread of real input sizes.
    let mix: Vec<(String, f64)> = trace
        .tasks
        .iter()
        .flat_map(|t| {
            t.executions.iter().take(8).map(move |e| (t.task.clone(), e.input_mb))
        })
        .collect();
    anyhow::ensure!(!mix.is_empty(), "workflow produced no tasks");
    anyhow::ensure!(
        cfg.observe_frac == 0.0 || !obs_mix.is_empty(),
        "observe mix requested but every task's trace is too short to hold out executions"
    );
    // Shared read-only across clients: the held-out executions carry
    // full sample vectors, so cloning the list per thread would be the
    // only heavyweight allocation in the setup path.
    let obs_mix = Arc::new(obs_mix);

    let per_client = cfg.requests.div_ceil(cfg.clients);
    let observe_frac = cfg.observe_frac;
    let t0 = Instant::now();
    // Chaos thread: crash/restore shards round-robin while the clients
    // run. Kills are spaced so the clients interleave real traffic with
    // each amnesia-wipe-and-restore cycle.
    let chaos_handle = (cfg.chaos_kills > 0).then(|| {
        let cl = coord.client();
        let target = cfg.chaos_kills as u64;
        std::thread::spawn(move || -> Result<u64> {
            let ids = cl.shard_ids();
            let mut kills = 0u64;
            let mut i = 0usize;
            while kills < target {
                std::thread::sleep(Duration::from_millis(10));
                let id = ids[i % ids.len()];
                i += 1;
                cl.crash_restart_shard(id)
                    .with_context(|| format!("chaos crash/restore of shard {id}"))?;
                kills += 1;
            }
            Ok(kills)
        })
    });
    let mut handles = Vec::with_capacity(cfg.clients);
    for c in 0..cfg.clients {
        let cl = coord.client();
        let mix = mix.clone();
        let obs_mix = Arc::clone(&obs_mix);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xC0FFEE ^ c as u64);
            let mut invalid = 0u64;
            let mut observes = 0u64;
            for _ in 0..per_client {
                if observe_frac > 0.0 && rng.f64() < observe_frac {
                    let (task, exec) = &obs_mix[rng.below(obs_mix.len())];
                    cl.observe(task, exec.clone());
                    observes += 1;
                }
                let (task, input) = &mix[rng.below(mix.len())];
                if !cl.plan(task, *input).is_valid() {
                    invalid += 1;
                }
            }
            (invalid, observes)
        }));
    }
    let mut invalid = 0u64;
    let mut observes = 0u64;
    for h in handles {
        let (i, o) =
            h.join().map_err(|_| anyhow::anyhow!("loadgen client thread panicked"))?;
        invalid += i;
        observes += o;
    }
    // A trained (or fallback) plan is always well-formed; an invalid one
    // is a service bug, not a load characteristic — fail loudly rather
    // than skewing throughput.
    anyhow::ensure!(invalid == 0, "coordinator returned {invalid} invalid plans");
    let chaos_kills = match chaos_handle {
        Some(h) => h.join().map_err(|_| anyhow::anyhow!("chaos thread panicked"))??,
        None => 0,
    };
    let served = (per_client * cfg.clients) as u64;
    let elapsed = t0.elapsed().max(Duration::from_nanos(1));

    let per_shard = client.shard_stats();
    let stats = ServiceStats::merged(&per_shard);
    // The strongest chaos assertion available to a black-box load run:
    // every acked observation is still counted after every kill, because
    // a crash wipes a shard's models, not its ledgers, and the training
    // state itself is re-folded from the standby replicas.
    anyhow::ensure!(
        stats.observations == observes,
        "coordinator lost observations: {} issued, {} recorded",
        observes,
        stats.observations
    );
    Ok(LoadGenReport {
        shards: cfg.shards,
        clients: cfg.clients,
        policy: cfg.policy.name(),
        requests: served,
        elapsed_s: elapsed.as_secs_f64(),
        plans_per_s: served as f64 / elapsed.as_secs_f64(),
        p50_us: stats.latency_percentile_us(50.0),
        p99_us: stats.latency_percentile_us(99.0),
        batches: stats.batches,
        mean_batch_size: stats.mean_batch_size(),
        observes,
        observes_per_s: observes as f64 / elapsed.as_secs_f64(),
        per_shard_requests: per_shard.iter().map(|s| s.requests).collect(),
        chaos_kills,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loadgen_smoke_single_shard() {
        let r = run(&LoadGenConfig {
            clients: 4,
            requests: 64,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.shards, 1);
        assert_eq!(r.requests, 64);
        assert_eq!(r.per_shard_requests, vec![64]);
        assert!(r.plans_per_s > 0.0);
        assert!(r.p99_us >= r.p50_us);
    }

    #[test]
    fn loadgen_sharded_spreads_requests() {
        let r = run(&LoadGenConfig {
            shards: 4,
            clients: 4,
            requests: 200,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.per_shard_requests.len(), 4);
        // Every plan request is accounted for by exactly one shard.
        assert_eq!(r.per_shard_requests.iter().sum::<u64>(), r.requests);
        // The eager workflow's task names spread over multiple shards.
        assert!(
            r.per_shard_requests.iter().filter(|&&n| n > 0).count() > 1,
            "{:?}",
            r.per_shard_requests
        );
        let j = r.to_json();
        assert_eq!(j.get("shards").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn loadgen_mixes_observes_into_the_stream() {
        let r = run(&LoadGenConfig {
            clients: 4,
            requests: 128,
            observe_frac: 0.5,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 128);
        assert!(r.observes > 0, "no observes issued at frac 0.5");
        assert!(r.observes_per_s > 0.0);
        let j = r.to_json();
        assert_eq!(j.get("observes").and_then(Json::as_usize), Some(r.observes as usize));
    }

    #[test]
    fn loadgen_rejects_degenerate_configs() {
        assert!(run(&LoadGenConfig { clients: 0, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { requests: 0, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { workflow: "nope".into(), ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { shards: 0, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { observe_frac: 1.5, ..Default::default() }).is_err());
        assert!(run(&LoadGenConfig { observe_frac: -0.1, ..Default::default() }).is_err());
        // Chaos on a single shard: no standby, refused up front.
        assert!(run(&LoadGenConfig { shards: 1, chaos_kills: 1, ..Default::default() }).is_err());
    }

    #[test]
    fn loadgen_survives_chaos_kills_without_losing_observes() {
        // Shards die and come back from their replicas mid-run; the run's
        // own invariants (zero invalid plans, zero lost observations) do
        // the asserting.
        let r = run(&LoadGenConfig {
            shards: 3,
            clients: 4,
            requests: 300,
            observe_frac: 0.5,
            chaos_kills: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.requests, 300);
        assert_eq!(r.chaos_kills, 3);
        assert!(r.observes > 0, "no observes issued at frac 0.5");
        assert_eq!(
            r.to_json().get("chaos_kills").and_then(Json::as_usize),
            Some(3)
        );
    }

    #[test]
    fn bench_json_writes_schema() {
        let r = run(&LoadGenConfig { clients: 2, requests: 32, ..Default::default() }).unwrap();
        let path = std::env::temp_dir().join(format!(
            "ksplus_bench_{}.json",
            std::process::id()
        ));
        write_bench_json(&path, &[r]).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(
            back.get("schema").and_then(Json::as_str),
            Some("ksplus-bench-hotpath/v1")
        );
        assert_eq!(back.get("plans").and_then(Json::as_arr).map(|a| a.len()), Some(1));
    }

    #[test]
    fn bench_json_creates_parent_directories() {
        // A nested --bench-json path used to fail the whole run at the
        // very end (after the sweep) when the directory did not exist.
        let r = run(&LoadGenConfig { clients: 2, requests: 16, ..Default::default() }).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "ksplus_bench_nested_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("a").join("b").join("bench.json");
        write_bench_json(&path, &[r]).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(
            back.get("schema").and_then(Json::as_str),
            Some("ksplus-bench-hotpath/v1")
        );
    }

    #[test]
    fn loadgen_serves_non_default_policies() {
        for policy in [PredictorPolicy::WittLr, PredictorPolicy::DefaultLimits] {
            let r = run(&LoadGenConfig {
                clients: 2,
                requests: 32,
                observe_frac: 0.25,
                policy,
                ..Default::default()
            })
            .unwrap();
            assert_eq!(r.requests, 32, "{policy:?}");
            assert_eq!(r.policy, policy.name());
            let j = r.to_json();
            assert_eq!(j.get("policy").and_then(Json::as_str), Some(policy.name()));
        }
    }
}
