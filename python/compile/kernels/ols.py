"""Layer-1 Pallas kernels for KS+.

The numeric hot spot of KS+ is thousands of *independent, tiny* ordinary
least-squares problems: one (start-time, peak-memory) regression pair per
task x segment model, each fitted over the historical executions of that
task and evaluated for every new task instance. We batch them: one batch
row == one regression model, padded to a bucket shape and masked.

Kernels (all interpret=True -- CPU PJRT cannot execute Mosaic lowerings):

  fit      : (x[B,N], y[B,N], m[B,N])                  -> coef[B,2]
  predict  : (coef[B,2], xq[B], scale[B])              -> yhat[B]
  wastage  : (alloc[B,N], used[B,N], m[B,N], dt[B])    -> gbs[B]

TPU mapping (DESIGN.md SectionHardware-Adaptation): rows are tiled over the
batch dimension in VMEM-resident blocks; every reduction is a lane-wise
sum over the observation axis, i.e. VPU work on (8,128) tiles. There is
no matmul, so the kernels are HBM-bandwidth bound; block sizes are chosen
so one (BLOCK_B, N) f32 tile of each operand fits VMEM comfortably
(3 operands x 128 x 512 x 4 B = 768 KiB << 16 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default bucket shapes, shared with aot.py and the rust runtime manifest.
FIT_B, FIT_N = 256, 512
# Small-observation bucket: typical training histories have <= 64
# executions, so the runtime picks this bucket and does 1/8 of the work.
FIT_N_SMALL = 64
PREDICT_B = 1024
WASTAGE_B, WASTAGE_N = 256, 512
# Max segments per plan for the plan_wastage kernel.
PLAN_K = 8

# Batch-dimension block: one grid step for the default bucket (256 rows
# x 512 obs x 3 operands x 4 B = 1.5 MiB, comfortably VMEM-resident);
# perf pass measured ~8 % over 128-row blocks on the CPU interpret path
# and halves the grid-loop overhead.
BLOCK_B = 256

# Guard for degenerate regressions (n < 2 observations or zero variance).
_EPS = 1e-12


def _fit_kernel(x_ref, y_ref, m_ref, o_ref):
    """Masked closed-form OLS per row.

    slope = (n*Sxy - Sx*Sy) / (n*Sxx - Sx^2); intercept = (Sy - slope*Sx)/n.
    Degenerate rows (n < 2 or ~zero x-variance) fall back to slope = 0,
    intercept = mean(y) -- exactly what the rust-side reference predictor
    does for tasks with a single historical execution.
    """
    m = m_ref[...]
    x = x_ref[...] * m
    y = y_ref[...] * m
    n = jnp.sum(m, axis=-1)
    sx = jnp.sum(x, axis=-1)
    sy = jnp.sum(y, axis=-1)
    sxy = jnp.sum(x * y, axis=-1)
    sxx = jnp.sum(x * x, axis=-1)
    denom = n * sxx - sx * sx
    ok = (n >= 2.0) & (jnp.abs(denom) > _EPS)
    safe = jnp.where(ok, denom, 1.0)
    slope = jnp.where(ok, (n * sxy - sx * sy) / safe, 0.0)
    nz = jnp.maximum(n, 1.0)
    intercept = jnp.where(ok, (sy - slope * sx) / nz, sy / nz)
    o_ref[...] = jnp.stack([slope, intercept], axis=-1)


def _predict_kernel(coef_ref, xq_ref, scale_ref, o_ref):
    """yhat = (slope * xq + intercept) * scale, clamped at >= 0.

    `scale` carries the KS+ safety offsets (1.10 for segment peaks, 0.85
    for segment start times), one factor per row so a single artifact
    serves both model families.
    """
    coef = coef_ref[...]
    yhat = coef[:, 0] * xq_ref[...] + coef[:, 1]
    o_ref[...] = jnp.maximum(yhat * scale_ref[...], 0.0)


def _wastage_kernel(alloc_ref, used_ref, m_ref, dt_ref, o_ref):
    """GB-seconds wastage per row: sum(max(alloc - used, 0) * m) * dt."""
    over = jnp.maximum(alloc_ref[...] - used_ref[...], 0.0) * m_ref[...]
    o_ref[...] = jnp.sum(over, axis=-1) * dt_ref[...]


def _plan_wastage_kernel(starts_ref, peaks_ref, used_ref, m_ref, dt_ref, o_ref):
    """Wastage of a step-function plan against a usage trace, per row.

    The plan is (starts[K], peaks[K]) with monotone non-decreasing peaks
    (padding: repeat the last start/peak). The allocation at sample j is
    max over segments i of peaks[i] * (starts[i] <= j*dt) -- valid
    because peaks are monotone. Wastage = sum(max(alloc - used, 0)*m)*dt.
    """
    starts = starts_ref[...]  # [BB, K]
    peaks = peaks_ref[...]  # [BB, K]
    used = used_ref[...]  # [BB, N]
    m = m_ref[...]  # [BB, N]
    dt = dt_ref[...]  # [BB]
    n = used.shape[-1]
    t = jnp.arange(n, dtype=jnp.float32)[None, :] * dt[:, None]  # [BB, N]
    active = starts[:, None, :] <= t[:, :, None]  # [BB, N, K]
    alloc = jnp.max(jnp.where(active, peaks[:, None, :], 0.0), axis=-1)  # [BB, N]
    over = jnp.maximum(alloc - used, 0.0) * m
    o_ref[...] = jnp.sum(over, axis=-1) * dt


def _row_blocks(b: int) -> tuple[int, int]:
    bb = min(BLOCK_B, b)
    assert b % bb == 0, f"batch {b} not divisible by block {bb}"
    return b // bb, bb


def fit(x, y, m):
    """Batched masked OLS. x, y, m: f32[B, N] -> coef f32[B, 2]."""
    b, n = x.shape
    grid, bb = _row_blocks(b)
    spec2 = pl.BlockSpec((bb, n), lambda i: (i, 0))
    return pl.pallas_call(
        _fit_kernel,
        grid=(grid,),
        in_specs=[spec2, spec2, spec2],
        out_specs=pl.BlockSpec((bb, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 2), jnp.float32),
        interpret=True,
    )(x, y, m)


def predict(coef, xq, scale):
    """Batched affine predict with safety scale. -> f32[B]."""
    b = xq.shape[0]
    grid, bb = _row_blocks(b)
    return pl.pallas_call(
        _predict_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((bb, 2), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(coef, xq, scale)


def wastage(alloc, used, m, dt):
    """Batched over-allocation integral. -> f32[B] (GB-seconds)."""
    b, n = alloc.shape
    grid, bb = _row_blocks(b)
    spec2 = pl.BlockSpec((bb, n), lambda i: (i, 0))
    spec1 = pl.BlockSpec((bb,), lambda i: (i,))
    return pl.pallas_call(
        _wastage_kernel,
        grid=(grid,),
        in_specs=[spec2, spec2, spec2, spec1],
        out_specs=spec1,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(alloc, used, m, dt)


def plan_wastage(starts, peaks, used, m, dt):
    """Step-plan-vs-trace wastage without materialising the allocation.

    starts, peaks: f32[B, K]; used, m: f32[B, N]; dt: f32[B] -> f32[B].
    """
    b, n = used.shape
    k = starts.shape[1]
    grid, bb = _row_blocks(b)
    speck = pl.BlockSpec((bb, k), lambda i: (i, 0))
    spec2 = pl.BlockSpec((bb, n), lambda i: (i, 0))
    spec1 = pl.BlockSpec((bb,), lambda i: (i,))
    return pl.pallas_call(
        _plan_wastage_kernel,
        grid=(grid,),
        in_specs=[speck, speck, spec2, spec2, spec1],
        out_specs=spec1,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(starts, peaks, used, m, dt)
