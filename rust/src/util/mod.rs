//! Offline-build substrates: RNG, JSON, CLI, stats, property testing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;

/// FNV-1a over a string: the shared cheap string hash (shard routing,
/// property-test seed derivation). Deterministic across runs and
/// platforms; not cryptographic.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
