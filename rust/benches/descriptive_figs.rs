//! Bench for the descriptive figures (1a, 1b, 2, 3, 4, 5): generation +
//! analysis cost, and a stability check that the headline statistics
//! stay near the paper's values.

use ksplus::experiments::{figs, ExpConfig};
use ksplus::util::bench::bench;

fn main() {
    let cfg = ExpConfig::default();
    for name in ["fig1a", "fig1b", "fig2", "fig3", "fig4", "fig5"] {
        bench(&format!("descriptive/{name}"), 1, 5, || {
            ksplus::experiments::run(name, &cfg, None).unwrap();
        });
    }
    // Stability: median bwa peak near the paper's 10.6 GB.
    let out = figs::fig1a(&cfg).unwrap();
    let peaks: Vec<f64> = out
        .json
        .get("fig1a_peaks_gb")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.as_f64().unwrap())
        .collect();
    let median = ksplus::util::stats::median(&peaks);
    println!("fig1a median bwa peak: {median:.2} GB (paper ~10.6)");
}
