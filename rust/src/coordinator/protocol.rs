//! Typed wire protocol **v1** for the coordinator's TCP front end:
//! `Request`/`Response` enums plus a structured error type, serialized
//! as newline-delimited JSON. Both the server (`coordinator::server`)
//! and the typed TCP client (`coordinator::remote`) speak through these
//! types, so the two ends cannot drift — a round-trip through
//! `to_json`/`parse` is identity (asserted by the tests below).
//!
//! The full schema of every op, response, and error code is specified in
//! `docs/PROTOCOL.md`. Headlines:
//!
//! * `hello` negotiates the version and advertises ops + policies.
//! * `configure` binds a task (or the service default) to a
//!   `PredictorPolicy` at runtime.
//! * `plan` responses carry provenance (`predictor`, `model_version`,
//!   `fallback_reason`) so callers can tell a trained KS+ plan from a
//!   default-limits fallback.
//! * Errors are structured — `{"ok":false,"error":{"code":...,
//!   "message":...}}` — with one specific `ErrorCode` per malformed
//!   request class, never a catch-all string.
//!
//! Numbers are serialized via the shortest-roundtrip float formatting of
//! `util::json`, so plans and executions survive the wire bit-exactly.

use std::fmt;

use crate::coordinator::{PlanOutcome, PredictorPolicy, RetryOutcome, FALLBACK_UNTRAINED};
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::json::Json;

/// Version this build speaks. `hello` is the negotiation point: servers
/// refuse clients whose `min_version` is above it (and clients whose
/// `max_version` is below it), with an `unsupported-version` error.
pub const WIRE_VERSION: usize = 1;

/// The length-prefixed binary framing (see `coordinator::wire` and
/// docs/PROTOCOL.md "Wire v2"). Negotiated per connection: a `hello`
/// with `max_version >= 2` switches the connection to binary frames
/// starting with the request *after* the hello response.
pub const WIRE_V2: usize = 2;

/// Highest wire version this build can speak.
pub const WIRE_VERSION_MAX: usize = WIRE_V2;

/// Version negotiation, shared by every server front end. Conservative
/// by design: the answer is v1 unless the client *explicitly* asks for
/// more via `max_version`, so pre-v2 clients (who send `min_version: 1`
/// or nothing at all) keep speaking JSON lines unchanged.
///
/// * `min_version > max_version` is a malformed request
///   (`invalid-field`), not a failed negotiation.
/// * A `min_version` above everything we speak, or a `max_version`
///   below v1, is `unsupported-version`.
pub fn negotiate_version(
    min_version: Option<usize>,
    max_version: Option<usize>,
) -> Result<usize, WireError> {
    if let (Some(lo), Some(hi)) = (min_version, max_version) {
        if lo > hi {
            return Err(WireError::new(
                ErrorCode::InvalidField,
                "'min_version' must not exceed 'max_version'",
            ));
        }
    }
    let lo = min_version.unwrap_or(1);
    if lo > WIRE_VERSION_MAX {
        return Err(WireError::new(
            ErrorCode::UnsupportedVersion,
            format!("server speaks versions 1..={WIRE_VERSION_MAX}, client needs >= {lo}"),
        ));
    }
    let hi = max_version.unwrap_or(lo.max(1));
    if hi < 1 {
        return Err(WireError::new(
            ErrorCode::UnsupportedVersion,
            "server speaks no version below 1".to_string(),
        ));
    }
    Ok(hi.min(WIRE_VERSION_MAX))
}

/// Every op of wire v1, in the order `hello` advertises them. The two
/// admin ops (`snapshot`, `reshard`) ride the same version behind the
/// `hello` capability list: a client that needs them checks `ops` before
/// issuing one, so older servers fail loudly with `unknown-op` instead
/// of half-working.
pub const OPS: [&str; 9] =
    ["hello", "configure", "train", "observe", "plan", "failure", "stats", "snapshot", "reshard"];

/// Client-side placeholder for provenance strings a newer server sent
/// that this build does not recognize (an unadvertised policy name, a
/// new `fallback_reason`). Decoding degrades to this instead of failing
/// the call — provenance is informational, the payload is still valid.
pub const PROVENANCE_UNKNOWN: &str = "unknown";

/// One specific code per malformed-request class. Stable wire strings —
/// clients branch on these, not on message text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The line was not parseable JSON.
    InvalidJson,
    /// `op` names no operation of this protocol version.
    UnknownOp,
    /// A required field is absent.
    MissingField,
    /// A field is present but has the wrong type or an invalid value.
    InvalidField,
    /// `train.history` is an empty array.
    EmptyHistory,
    /// An execution carries no samples (nothing to learn from).
    EmptySamples,
    /// A plan's `starts`/`peaks` are empty or of mismatched length.
    InvalidPlan,
    /// `configure.policy` names no known predictor policy.
    UnknownPolicy,
    /// Version negotiation failed (`hello.min_version` above ours, or
    /// `hello.max_version` below).
    UnsupportedVersion,
    /// A request line exceeded the server's size cap. The connection is
    /// closed after this error — the remaining bytes of the oversized
    /// frame cannot be resynchronized.
    RequestTooLarge,
    /// The server is at its configured connection limit; retry later.
    TooManyConnections,
    /// A binary (wire v2) frame could not be decoded: unknown op tag,
    /// truncated payload, or malformed field encoding. The v2 analogue
    /// of `invalid-json`.
    InvalidFrame,
    /// The server shed this request because its dispatch queue (or the
    /// connection's in-flight window) is full. Unlike the two
    /// connection-level errors above, the connection stays open — the
    /// request was rejected, not the link. Safe to retry after backoff.
    Overloaded,
    /// Server-side fault, or an unrecognized code from a newer peer.
    Internal,
}

impl ErrorCode {
    pub const ALL: [ErrorCode; 14] = [
        ErrorCode::InvalidJson,
        ErrorCode::UnknownOp,
        ErrorCode::MissingField,
        ErrorCode::InvalidField,
        ErrorCode::EmptyHistory,
        ErrorCode::EmptySamples,
        ErrorCode::InvalidPlan,
        ErrorCode::UnknownPolicy,
        ErrorCode::UnsupportedVersion,
        ErrorCode::RequestTooLarge,
        ErrorCode::TooManyConnections,
        ErrorCode::InvalidFrame,
        ErrorCode::Overloaded,
        ErrorCode::Internal,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::InvalidJson => "invalid-json",
            ErrorCode::UnknownOp => "unknown-op",
            ErrorCode::MissingField => "missing-field",
            ErrorCode::InvalidField => "invalid-field",
            ErrorCode::EmptyHistory => "empty-history",
            ErrorCode::EmptySamples => "empty-samples",
            ErrorCode::InvalidPlan => "invalid-plan",
            ErrorCode::UnknownPolicy => "unknown-policy",
            ErrorCode::UnsupportedVersion => "unsupported-version",
            ErrorCode::RequestTooLarge => "request-too-large",
            ErrorCode::TooManyConnections => "too-many-connections",
            ErrorCode::InvalidFrame => "invalid-frame",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.iter().copied().find(|c| c.as_str() == s)
    }
}

/// A structured wire error: code plus human-readable context.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
}

impl WireError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> WireError {
        WireError { code, message: message.into() }
    }

    /// The error-response line: `{"ok":false,"error":{code,message}}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", false.into()),
            (
                "error",
                Json::obj(vec![
                    ("code", self.code.as_str().into()),
                    ("message", self.message.as_str().into()),
                ]),
            ),
        ])
    }

    /// Client side: reconstruct from an `"ok":false` response line.
    /// Unrecognized codes (a newer server) degrade to `Internal` with
    /// the message preserved.
    pub fn from_json(j: &Json) -> WireError {
        match j.get("error") {
            Some(e) if e.get("code").is_some() => WireError {
                code: e
                    .get("code")
                    .and_then(Json::as_str)
                    .and_then(ErrorCode::parse)
                    .unwrap_or(ErrorCode::Internal),
                message: e
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            },
            // Pre-v1 servers shipped a bare string.
            Some(Json::Str(s)) => WireError::new(ErrorCode::Internal, s.clone()),
            _ => WireError::new(ErrorCode::Internal, "malformed error response"),
        }
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for WireError {}

// ---- field extraction helpers ------------------------------------------

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json, WireError> {
    j.get(key)
        .ok_or_else(|| WireError::new(ErrorCode::MissingField, format!("missing '{key}'")))
}

fn str_field(j: &Json, key: &str) -> Result<String, WireError> {
    field(j, key)?.as_str().map(str::to_string).ok_or_else(|| {
        WireError::new(ErrorCode::InvalidField, format!("'{key}' must be a string"))
    })
}

fn f64_field(j: &Json, key: &str) -> Result<f64, WireError> {
    field(j, key)?.as_f64().ok_or_else(|| {
        WireError::new(ErrorCode::InvalidField, format!("'{key}' must be a number"))
    })
}

fn f64_vec_field(j: &Json, key: &str) -> Result<Vec<f64>, WireError> {
    let arr = field(j, key)?.as_arr().ok_or_else(|| {
        WireError::new(ErrorCode::InvalidField, format!("'{key}' must be an array"))
    })?;
    arr.iter()
        .map(|v| {
            v.as_f64().ok_or_else(|| {
                WireError::new(
                    ErrorCode::InvalidField,
                    format!("'{key}' must contain only numbers"),
                )
            })
        })
        .collect()
}

/// Optional string field: absent is fine, a wrong type is not.
fn opt_str_field(j: &Json, key: &str) -> Result<Option<String>, WireError> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v.as_str().map(|s| Some(s.to_string())).ok_or_else(|| {
            WireError::new(ErrorCode::InvalidField, format!("'{key}' must be a string"))
        }),
    }
}

/// Optional non-negative integer field.
fn opt_usize_field(j: &Json, key: &str) -> Result<Option<usize>, WireError> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v.as_usize().map(Some).ok_or_else(|| {
            WireError::new(
                ErrorCode::InvalidField,
                format!("'{key}' must be a non-negative integer"),
            )
        }),
    }
}

// ---- payload (de)serialization ------------------------------------------

pub fn execution_to_json(e: &Execution) -> Json {
    Json::obj(vec![
        ("input_mb", e.input_mb.into()),
        ("dt", e.dt.into()),
        ("samples", Json::arr_f64(&e.samples)),
    ])
}

pub fn execution_from_json(task: &str, j: &Json) -> Result<Execution, WireError> {
    let input_mb = f64_field(j, "input_mb")?;
    let dt = f64_field(j, "dt")?;
    let samples = f64_vec_field(j, "samples")?;
    execution_from_parts(task, input_mb, dt, samples)
}

/// Semantic validation shared by both wires: the JSON parser above and
/// the binary decoder (`coordinator::wire`) funnel through here, so a
/// bad execution gets the identical `ErrorCode` + message whichever
/// framing carried it.
pub fn execution_from_parts(
    task: &str,
    input_mb: f64,
    dt: f64,
    samples: Vec<f64>,
) -> Result<Execution, WireError> {
    if !(dt > 0.0) {
        return Err(WireError::new(ErrorCode::InvalidField, "'dt' must be positive"));
    }
    if samples.is_empty() {
        // Nothing to segment or learn from; rejecting here keeps garbage
        // off the worker threads.
        return Err(WireError::new(
            ErrorCode::EmptySamples,
            "execution needs at least one sample",
        ));
    }
    Ok(Execution::new(task, input_mb, dt, samples))
}

pub fn plan_to_json(p: &StepPlan) -> Json {
    Json::obj(vec![
        ("starts", Json::arr_f64(&p.starts)),
        ("peaks", Json::arr_f64(&p.peaks)),
    ])
}

pub fn plan_from_json(j: &Json) -> Result<StepPlan, WireError> {
    let starts = f64_vec_field(j, "starts")?;
    let peaks = f64_vec_field(j, "peaks")?;
    plan_from_parts(starts, peaks)
}

/// Shared-by-both-wires counterpart of [`execution_from_parts`].
pub fn plan_from_parts(starts: Vec<f64>, peaks: Vec<f64>) -> Result<StepPlan, WireError> {
    if starts.is_empty() || starts.len() != peaks.len() {
        return Err(WireError::new(
            ErrorCode::InvalidPlan,
            "plan needs equal-length, non-empty 'starts' and 'peaks'",
        ));
    }
    Ok(StepPlan::new(starts, peaks))
}

/// Shared semantic check: `"*"` is the default-scope response sentinel
/// and therefore reserved as a task name on `configure`.
pub fn validate_configure_task(task: Option<String>) -> Result<Option<String>, WireError> {
    if task.as_deref() == Some("*") {
        return Err(WireError::new(
            ErrorCode::InvalidField,
            "task name '*' is reserved (omit 'task' to set the default)",
        ));
    }
    Ok(task)
}

/// Shared semantic check: `train.history` must be non-empty.
pub fn validate_history_len(n: usize) -> Result<(), WireError> {
    if n == 0 {
        return Err(WireError::new(ErrorCode::EmptyHistory, "empty history"));
    }
    Ok(())
}

/// Shared semantic check: `reshard.shards` must be at least 1 (the
/// upper bound is the service's `MAX_SHARDS`, enforced at dispatch).
pub fn validate_reshard_shards(shards: usize) -> Result<usize, WireError> {
    if shards == 0 {
        return Err(WireError::new(ErrorCode::InvalidField, "'shards' must be at least 1"));
    }
    Ok(shards)
}

/// Policy-name lookup with the wire's `unknown-policy` error (shared by
/// the JSON parser and the binary decoder).
pub fn policy_from_name(name: &str) -> Result<PredictorPolicy, WireError> {
    PredictorPolicy::parse(name).ok_or_else(|| {
        WireError::new(
            ErrorCode::UnknownPolicy,
            format!("unknown policy '{name}' (valid: {})", PredictorPolicy::names().join(", ")),
        )
    })
}

// ---- requests ------------------------------------------------------------

/// Retry-deduplication identity for a mutating request (`configure`,
/// `train`, `observe`). A client that retries mutating ops attaches a
/// per-session `nonce` and a per-op `seq`; the server remembers the last
/// `seq` applied per nonce and answers a replayed `seq` from its cached
/// response instead of applying the mutation twice. Sequence numbers
/// must be strictly increasing per nonce — a `seq` below the last
/// applied one is rejected (`invalid-field`), since its cached response
/// is gone.
#[derive(Debug, Clone, PartialEq)]
pub struct Dedup {
    /// Per-session random identity (client-chosen, opaque to the
    /// server).
    pub nonce: String,
    /// Strictly-increasing per-nonce sequence number: one per logical
    /// op, shared by all retries of that op.
    pub seq: u64,
}

/// Parse the optional dedup pair from a v1 request object: both fields
/// or neither — one without the other is malformed.
fn dedup_from_json(j: &Json) -> Result<Option<Dedup>, WireError> {
    match (j.get("nonce"), j.get("seq")) {
        (None, None) => Ok(None),
        (Some(n), Some(s)) => {
            let nonce = n.as_str().ok_or_else(|| {
                WireError::new(ErrorCode::InvalidField, "'nonce' must be a string")
            })?;
            let seq = s.as_usize().ok_or_else(|| {
                WireError::new(
                    ErrorCode::InvalidField,
                    "'seq' must be a non-negative integer",
                )
            })?;
            Ok(Some(Dedup { nonce: nonce.to_string(), seq: seq as u64 }))
        }
        _ => Err(WireError::new(
            ErrorCode::InvalidField,
            "'nonce' and 'seq' must be sent together",
        )),
    }
}

/// Encoder counterpart of [`dedup_from_json`].
fn push_dedup(pairs: &mut Vec<(&str, Json)>, dedup: &Option<Dedup>) {
    if let Some(d) = dedup {
        pairs.push(("nonce", d.nonce.as_str().into()));
        pairs.push(("seq", (d.seq as usize).into()));
    }
}

/// Every request of wire v1. `parse` maps each malformed-request class
/// to its specific `ErrorCode`; `to_json` is the client-side encoder.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Hello {
        /// Free-form client identification, echoed nowhere — logs only.
        client: Option<String>,
        min_version: Option<usize>,
        max_version: Option<usize>,
    },
    /// Bind `task` to `policy`; a task-less configure sets the
    /// service-wide default for tasks not yet pinned to a policy.
    Configure { task: Option<String>, policy: PredictorPolicy, dedup: Option<Dedup> },
    Train { task: String, history: Vec<Execution>, dedup: Option<Dedup> },
    Observe { task: String, execution: Execution, dedup: Option<Dedup> },
    Plan { task: String, input_mb: f64 },
    /// Report an OOM. With `task`, the retry uses that task's bound
    /// policy; without, the KS+ segment-rescaling strategy.
    Failure { task: Option<String>, plan: StepPlan, fail_time: f64 },
    Stats,
    /// Admin: export the full trained state as a snapshot document.
    Snapshot,
    /// Admin: resize the worker pool to exactly this many shards.
    Reshard { shards: usize },
}

impl Request {
    /// Wire op name (the `"op"` field).
    pub fn op(&self) -> &'static str {
        match self {
            Request::Hello { .. } => "hello",
            Request::Configure { .. } => "configure",
            Request::Train { .. } => "train",
            Request::Observe { .. } => "observe",
            Request::Plan { .. } => "plan",
            Request::Failure { .. } => "failure",
            Request::Stats => "stats",
            Request::Snapshot => "snapshot",
            Request::Reshard { .. } => "reshard",
        }
    }

    pub fn parse(line: &str) -> Result<Request, WireError> {
        let j = Json::parse(line)
            .map_err(|e| WireError::new(ErrorCode::InvalidJson, e.to_string()))?;
        let op = field(&j, "op")?
            .as_str()
            .ok_or_else(|| WireError::new(ErrorCode::InvalidField, "'op' must be a string"))?;
        match op {
            "hello" => Ok(Request::Hello {
                client: opt_str_field(&j, "client")?,
                min_version: opt_usize_field(&j, "min_version")?,
                max_version: opt_usize_field(&j, "max_version")?,
            }),
            "configure" => {
                // "*" is the response sentinel for the service-wide
                // default scope; a task literally named "*" would be
                // indistinguishable in the ack, so reserve it.
                let task = validate_configure_task(opt_str_field(&j, "task")?)?;
                Ok(Request::Configure {
                    task,
                    policy: policy_from_name(&str_field(&j, "policy")?)?,
                    dedup: dedup_from_json(&j)?,
                })
            }
            "train" => {
                let task = str_field(&j, "task")?;
                let arr = field(&j, "history")?.as_arr().ok_or_else(|| {
                    WireError::new(ErrorCode::InvalidField, "'history' must be an array")
                })?;
                validate_history_len(arr.len())?;
                let history = arr
                    .iter()
                    .map(|e| execution_from_json(&task, e))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(Request::Train { task, history, dedup: dedup_from_json(&j)? })
            }
            "observe" => {
                let task = str_field(&j, "task")?;
                let execution = execution_from_json(&task, field(&j, "execution")?)?;
                Ok(Request::Observe { task, execution, dedup: dedup_from_json(&j)? })
            }
            "plan" => Ok(Request::Plan {
                task: str_field(&j, "task")?,
                input_mb: f64_field(&j, "input_mb")?,
            }),
            "failure" => Ok(Request::Failure {
                task: opt_str_field(&j, "task")?,
                plan: plan_from_json(field(&j, "plan")?)?,
                fail_time: f64_field(&j, "fail_time")?,
            }),
            "stats" => Ok(Request::Stats),
            "snapshot" => Ok(Request::Snapshot),
            "reshard" => {
                let shards = field(&j, "shards")?.as_usize().ok_or_else(|| {
                    WireError::new(
                        ErrorCode::InvalidField,
                        "'shards' must be a non-negative integer",
                    )
                })?;
                Ok(Request::Reshard { shards: validate_reshard_shards(shards)? })
            }
            other => {
                Err(WireError::new(ErrorCode::UnknownOp, format!("unknown op '{other}'")))
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("op", self.op().into())];
        match self {
            Request::Hello { client, min_version, max_version } => {
                if let Some(c) = client {
                    pairs.push(("client", c.as_str().into()));
                }
                if let Some(v) = min_version {
                    pairs.push(("min_version", (*v).into()));
                }
                if let Some(v) = max_version {
                    pairs.push(("max_version", (*v).into()));
                }
            }
            Request::Configure { task, policy, dedup } => {
                if let Some(t) = task {
                    pairs.push(("task", t.as_str().into()));
                }
                pairs.push(("policy", policy.name().into()));
                push_dedup(&mut pairs, dedup);
            }
            Request::Train { task, history, dedup } => {
                pairs.push(("task", task.as_str().into()));
                pairs.push((
                    "history",
                    Json::Arr(history.iter().map(execution_to_json).collect()),
                ));
                push_dedup(&mut pairs, dedup);
            }
            Request::Observe { task, execution, dedup } => {
                pairs.push(("task", task.as_str().into()));
                pairs.push(("execution", execution_to_json(execution)));
                push_dedup(&mut pairs, dedup);
            }
            Request::Plan { task, input_mb } => {
                pairs.push(("task", task.as_str().into()));
                pairs.push(("input_mb", (*input_mb).into()));
            }
            Request::Failure { task, plan, fail_time } => {
                if let Some(t) = task {
                    pairs.push(("task", t.as_str().into()));
                }
                pairs.push(("plan", plan_to_json(plan)));
                pairs.push(("fail_time", (*fail_time).into()));
            }
            Request::Stats => {}
            Request::Snapshot => {}
            Request::Reshard { shards } => {
                pairs.push(("shards", (*shards).into()));
            }
        }
        Json::obj(pairs)
    }
}

// ---- responses -----------------------------------------------------------

/// `hello` payload: what this server speaks.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerInfo {
    pub version: usize,
    pub ops: Vec<String>,
    pub policies: Vec<String>,
    pub shards: usize,
}

/// `observe` acknowledgement with provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct ObserveAck {
    pub task: String,
    /// Executions folded into the task's model so far (its model
    /// version).
    pub executions: u64,
    /// Policy the execution was folded under.
    pub predictor: &'static str,
}

/// `stats` payload: merged counters across every shard.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsSummary {
    pub shards: usize,
    pub requests: u64,
    pub batches: u64,
    pub failures_handled: u64,
    pub tasks_trained: u64,
    pub observations: u64,
    /// Plans served by the untrained flat default — silent before this
    /// counter existed, now visible in every stats read.
    pub fallbacks: u64,
    /// Connections refused at the server's max-connections limit.
    pub conns_refused: u64,
    /// Connections closed by the server's read timeout.
    pub conn_timeouts: u64,
    /// Connections closed because their buffered responses exceeded the
    /// server's write-buffer cap (a pipelining peer that stopped
    /// reading).
    pub conns_overflowed: u64,
    /// Requests shed with `overloaded` at the dispatch-queue or
    /// per-connection in-flight cap.
    pub shed: u64,
    /// High-water mark of the dispatch queue depth.
    pub queue_depth_max: u64,
    /// Graceful drains completed (a `stop()` that finished in-flight
    /// work instead of discarding it).
    pub drains: u64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
}

/// Every success response of wire v1, one per op.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Hello(ServerInfo),
    Configured { task: Option<String>, policy: PredictorPolicy },
    Trained { task: String, executions: u64 },
    Observed(ObserveAck),
    Planned(PlanOutcome),
    Retry(RetryOutcome),
    Stats(StatsSummary),
    /// The full snapshot document, inline (same schema as the snapshot
    /// file — see `coordinator::snapshot`).
    Snapshot { doc: Json },
    /// Resharding ack: the live shard ids after the resize.
    Resharded { shard_ids: Vec<usize> },
}

impl Response {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("ok", true.into())];
        match self {
            Response::Hello(i) => {
                pairs.push(("version", i.version.into()));
                pairs.push((
                    "ops",
                    Json::Arr(i.ops.iter().map(|s| s.as_str().into()).collect()),
                ));
                pairs.push((
                    "policies",
                    Json::Arr(i.policies.iter().map(|s| s.as_str().into()).collect()),
                ));
                pairs.push(("shards", i.shards.into()));
            }
            Response::Configured { task, policy } => {
                pairs.push(("configured", task.as_deref().unwrap_or("*").into()));
                pairs.push(("policy", policy.name().into()));
            }
            Response::Trained { task, executions } => {
                pairs.push(("trained", task.as_str().into()));
                pairs.push(("executions", (*executions as usize).into()));
            }
            Response::Observed(a) => {
                pairs.push(("observed", a.task.as_str().into()));
                pairs.push(("executions", (a.executions as usize).into()));
                pairs.push(("predictor", a.predictor.into()));
            }
            Response::Planned(o) => {
                pairs.push(("plan", plan_to_json(&o.plan)));
                pairs.push(("predictor", o.predictor.into()));
                pairs.push(("model_version", (o.model_version as usize).into()));
                if let Some(reason) = o.fallback_reason {
                    pairs.push(("fallback_reason", reason.into()));
                }
            }
            Response::Retry(r) => {
                pairs.push(("plan", plan_to_json(&r.plan)));
                pairs.push(("predictor", r.predictor.into()));
            }
            Response::Stats(s) => {
                pairs.push(("shards", s.shards.into()));
                pairs.push(("requests", (s.requests as usize).into()));
                pairs.push(("batches", (s.batches as usize).into()));
                pairs.push(("failures_handled", (s.failures_handled as usize).into()));
                pairs.push(("tasks_trained", (s.tasks_trained as usize).into()));
                pairs.push(("observations", (s.observations as usize).into()));
                pairs.push(("fallbacks", (s.fallbacks as usize).into()));
                pairs.push(("conns_refused", (s.conns_refused as usize).into()));
                pairs.push(("conn_timeouts", (s.conn_timeouts as usize).into()));
                pairs.push(("conns_overflowed", (s.conns_overflowed as usize).into()));
                pairs.push(("shed", (s.shed as usize).into()));
                pairs.push(("queue_depth_max", (s.queue_depth_max as usize).into()));
                pairs.push(("drains", (s.drains as usize).into()));
                pairs.push(("latency_p50_us", s.latency_p50_us.into()));
                pairs.push(("latency_p99_us", s.latency_p99_us.into()));
            }
            Response::Snapshot { doc } => {
                pairs.push(("snapshot", doc.clone()));
            }
            Response::Resharded { shard_ids } => {
                pairs.push((
                    "shard_ids",
                    Json::Arr(shard_ids.iter().map(|&id| id.into()).collect()),
                ));
            }
        }
        Json::obj(pairs)
    }

    /// Client side: decode a response line for the given request op.
    /// `"ok":false` lines come back as the embedded `WireError`.
    pub fn from_json(j: &Json, op: &str) -> Result<Response, WireError> {
        if j.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(WireError::from_json(j));
        }
        let inv = |msg: &str| WireError::new(ErrorCode::InvalidField, msg.to_string());
        let u64_of = |key: &str| -> Result<u64, WireError> {
            j.get(key)
                .and_then(Json::as_usize)
                .map(|v| v as u64)
                .ok_or_else(|| inv(&format!("response missing numeric '{key}'")))
        };
        let str_list = |key: &str| -> Result<Vec<String>, WireError> {
            j.get(key)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Json::as_str)
                        .map(str::to_string)
                        .collect::<Vec<_>>()
                })
                .ok_or_else(|| inv(&format!("response missing array '{key}'")))
        };
        // Provenance-only strings degrade on unrecognized values (a
        // newer server's policy set) instead of failing the call — the
        // same stance WireError::from_json takes on unknown error codes.
        let predictor_of = |key: &str| -> Result<&'static str, WireError> {
            let name = j
                .get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| inv(&format!("response missing '{key}'")))?;
            Ok(PredictorPolicy::parse(name)
                .map(PredictorPolicy::name)
                .unwrap_or(PROVENANCE_UNKNOWN))
        };
        match op {
            "hello" => Ok(Response::Hello(ServerInfo {
                version: j
                    .get("version")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| inv("response missing 'version'"))?,
                ops: str_list("ops")?,
                policies: str_list("policies")?,
                shards: j
                    .get("shards")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| inv("response missing 'shards'"))?,
            })),
            "configure" => {
                let scope = j
                    .get("configured")
                    .and_then(Json::as_str)
                    .ok_or_else(|| inv("response missing 'configured'"))?;
                let task = if scope == "*" { None } else { Some(scope.to_string()) };
                let policy = policy_from_name(
                    j.get("policy")
                        .and_then(Json::as_str)
                        .ok_or_else(|| inv("response missing 'policy'"))?,
                )?;
                Ok(Response::Configured { task, policy })
            }
            "train" => Ok(Response::Trained {
                task: j
                    .get("trained")
                    .and_then(Json::as_str)
                    .ok_or_else(|| inv("response missing 'trained'"))?
                    .to_string(),
                executions: u64_of("executions")?,
            }),
            "observe" => Ok(Response::Observed(ObserveAck {
                task: j
                    .get("observed")
                    .and_then(Json::as_str)
                    .ok_or_else(|| inv("response missing 'observed'"))?
                    .to_string(),
                executions: u64_of("executions")?,
                predictor: predictor_of("predictor")?,
            })),
            "plan" => {
                let fallback_reason = match j.get("fallback_reason") {
                    None => None,
                    Some(v) => match v.as_str() {
                        Some(FALLBACK_UNTRAINED) => Some(FALLBACK_UNTRAINED),
                        // A newer server's reason: still a fallback.
                        Some(_) => Some(PROVENANCE_UNKNOWN),
                        None => return Err(inv("'fallback_reason' must be a string")),
                    },
                };
                Ok(Response::Planned(PlanOutcome {
                    plan: plan_from_json(field(j, "plan")?)?,
                    predictor: predictor_of("predictor")?,
                    model_version: u64_of("model_version")?,
                    fallback_reason,
                }))
            }
            "failure" => Ok(Response::Retry(RetryOutcome {
                plan: plan_from_json(field(j, "plan")?)?,
                predictor: predictor_of("predictor")?,
            })),
            "stats" => Ok(Response::Stats(StatsSummary {
                shards: j
                    .get("shards")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| inv("response missing 'shards'"))?,
                requests: u64_of("requests")?,
                batches: u64_of("batches")?,
                failures_handled: u64_of("failures_handled")?,
                tasks_trained: u64_of("tasks_trained")?,
                observations: u64_of("observations")?,
                fallbacks: u64_of("fallbacks")?,
                // Absent on pre-limits servers: default to 0 instead of
                // failing the whole stats read.
                conns_refused: j
                    .get("conns_refused")
                    .and_then(Json::as_usize)
                    .unwrap_or(0) as u64,
                conn_timeouts: j
                    .get("conn_timeouts")
                    .and_then(Json::as_usize)
                    .unwrap_or(0) as u64,
                conns_overflowed: j
                    .get("conns_overflowed")
                    .and_then(Json::as_usize)
                    .unwrap_or(0) as u64,
                shed: j.get("shed").and_then(Json::as_usize).unwrap_or(0) as u64,
                queue_depth_max: j
                    .get("queue_depth_max")
                    .and_then(Json::as_usize)
                    .unwrap_or(0) as u64,
                drains: j.get("drains").and_then(Json::as_usize).unwrap_or(0) as u64,
                latency_p50_us: f64_field(j, "latency_p50_us")?,
                latency_p99_us: f64_field(j, "latency_p99_us")?,
            })),
            "snapshot" => Ok(Response::Snapshot {
                doc: field(j, "snapshot")?.clone(),
            }),
            "reshard" => {
                let ids = field(j, "shard_ids")?.as_arr().ok_or_else(|| {
                    inv("'shard_ids' must be an array")
                })?;
                let shard_ids = ids
                    .iter()
                    .map(|v| {
                        v.as_usize().ok_or_else(|| {
                            inv("'shard_ids' must contain non-negative integers")
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(Response::Resharded { shard_ids })
            }
            other => Err(WireError::new(
                ErrorCode::UnknownOp,
                format!("no response decoder for op '{other}'"),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn exec(seed: u64) -> Execution {
        let mut rng = Rng::new(seed);
        let n = 3 + rng.below(6);
        Execution::new(
            "t",
            rng.uniform(100.0, 9000.0),
            1.0,
            (0..n).map(|_| rng.uniform(0.01, 12.0)).collect(),
        )
    }

    #[test]
    fn request_json_roundtrip_every_op() {
        let reqs = vec![
            Request::Hello {
                client: Some("test".into()),
                min_version: Some(1),
                max_version: Some(1),
            },
            Request::Hello { client: None, min_version: None, max_version: None },
            Request::Configure {
                task: Some("bwa".into()),
                policy: PredictorPolicy::WittLr,
                dedup: None,
            },
            Request::Configure {
                task: None,
                policy: PredictorPolicy::KsPlus,
                dedup: Some(Dedup { nonce: "cfg-nonce".into(), seq: 0 }),
            },
            // Task name matches the generator's ("t"): the parser
            // rebuilds each execution with the op's task field.
            Request::Train { task: "t".into(), history: vec![exec(1), exec(2)], dedup: None },
            Request::Train {
                task: "t".into(),
                history: vec![exec(4)],
                dedup: Some(Dedup { nonce: "sess-1".into(), seq: 7 }),
            },
            Request::Observe { task: "t".into(), execution: exec(3), dedup: None },
            Request::Observe {
                task: "t".into(),
                execution: exec(5),
                dedup: Some(Dedup { nonce: "sess-1".into(), seq: 8 }),
            },
            Request::Plan { task: "bwa".into(), input_mb: 1234.5 },
            Request::Failure {
                task: Some("bwa".into()),
                plan: StepPlan::new(vec![0.0, 10.5], vec![2.25, 8.0]),
                fail_time: 3.5,
            },
            Request::Failure {
                task: None,
                plan: StepPlan::flat(4.0),
                fail_time: 0.0,
            },
            Request::Stats,
            Request::Snapshot,
            Request::Reshard { shards: 4 },
        ];
        for req in reqs {
            let line = req.to_json().to_string();
            let back = Request::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(back, req, "roundtrip of {line}");
        }
    }

    #[test]
    fn response_json_roundtrip_every_op() {
        let cases: Vec<(&str, Response)> = vec![
            (
                "hello",
                Response::Hello(ServerInfo {
                    version: WIRE_VERSION,
                    ops: OPS.iter().map(|s| s.to_string()).collect(),
                    policies: PredictorPolicy::names()
                        .iter()
                        .map(|s| s.to_string())
                        .collect(),
                    shards: 4,
                }),
            ),
            (
                "configure",
                Response::Configured {
                    task: Some("bwa".into()),
                    policy: PredictorPolicy::TovarPpm,
                },
            ),
            (
                "configure",
                Response::Configured { task: None, policy: PredictorPolicy::KsPlus },
            ),
            ("train", Response::Trained { task: "bwa".into(), executions: 12 }),
            (
                "observe",
                Response::Observed(ObserveAck {
                    task: "bwa".into(),
                    executions: 13,
                    predictor: "ksplus",
                }),
            ),
            (
                "plan",
                Response::Planned(PlanOutcome {
                    plan: StepPlan::new(vec![0.0, 62.5], vec![4.125, 9.25]),
                    predictor: "ksplus",
                    model_version: 13,
                    fallback_reason: None,
                }),
            ),
            (
                "plan",
                Response::Planned(PlanOutcome {
                    plan: StepPlan::flat(32.0),
                    predictor: "default-limits",
                    model_version: 0,
                    fallback_reason: Some(FALLBACK_UNTRAINED),
                }),
            ),
            (
                "failure",
                Response::Retry(RetryOutcome {
                    plan: StepPlan::new(vec![0.0, 60.0], vec![2.0, 8.0]),
                    predictor: "witt-lr",
                }),
            ),
            (
                "stats",
                Response::Stats(StatsSummary {
                    shards: 2,
                    requests: 100,
                    batches: 20,
                    failures_handled: 3,
                    tasks_trained: 5,
                    observations: 7,
                    fallbacks: 2,
                    conns_refused: 4,
                    conn_timeouts: 1,
                    conns_overflowed: 6,
                    shed: 9,
                    queue_depth_max: 17,
                    drains: 1,
                    latency_p50_us: 12.5,
                    latency_p99_us: 90.25,
                }),
            ),
            (
                "snapshot",
                Response::Snapshot {
                    doc: Json::obj(vec![
                        ("schema", "ksplus-model-snapshot/v1".into()),
                        ("tasks", Json::Arr(vec![])),
                    ]),
                },
            ),
            ("reshard", Response::Resharded { shard_ids: vec![0, 2, 5] }),
        ];
        for (op, resp) in cases {
            let j = resp.to_json();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
            let back = Response::from_json(&Json::parse(&j.to_string()).unwrap(), op)
                .unwrap_or_else(|e| panic!("{op}: {e}"));
            assert_eq!(back, resp, "roundtrip for op {op}");
        }
    }

    #[test]
    fn parse_errors_map_to_specific_codes() {
        // The service-layer table: each malformed-request class maps to
        // its own ErrorCode at Request::parse — never a catch-all.
        let table: &[(&str, ErrorCode)] = &[
            ("not json", ErrorCode::InvalidJson),
            ("{", ErrorCode::InvalidJson),
            (r#"{"task":"x"}"#, ErrorCode::MissingField),
            (r#"{"op":42}"#, ErrorCode::InvalidField),
            (r#"{"op":"frobnicate"}"#, ErrorCode::UnknownOp),
            (r#"{"op":"plan"}"#, ErrorCode::MissingField),
            (r#"{"op":"plan","task":"x"}"#, ErrorCode::MissingField),
            (r#"{"op":"plan","input_mb":5}"#, ErrorCode::MissingField),
            (r#"{"op":"plan","task":7,"input_mb":5}"#, ErrorCode::InvalidField),
            (r#"{"op":"plan","task":"x","input_mb":"big"}"#, ErrorCode::InvalidField),
            (r#"{"op":"train","task":"x"}"#, ErrorCode::MissingField),
            (r#"{"op":"train","task":"x","history":5}"#, ErrorCode::InvalidField),
            (r#"{"op":"train","task":"x","history":[]}"#, ErrorCode::EmptyHistory),
            (
                r#"{"op":"train","task":"x","history":[{"input_mb":1,"dt":1,"samples":[]}]}"#,
                ErrorCode::EmptySamples,
            ),
            (
                r#"{"op":"train","task":"x","history":[{"input_mb":1,"dt":0,"samples":[1]}]}"#,
                ErrorCode::InvalidField,
            ),
            (
                r#"{"op":"train","task":"x","history":[{"dt":1,"samples":[1]}]}"#,
                ErrorCode::MissingField,
            ),
            (r#"{"op":"observe","task":"x"}"#, ErrorCode::MissingField),
            (
                r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1,"samples":[]}}"#,
                ErrorCode::EmptySamples,
            ),
            (
                r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1,"samples":["a"]}}"#,
                ErrorCode::InvalidField,
            ),
            (r#"{"op":"configure","task":"x"}"#, ErrorCode::MissingField),
            (r#"{"op":"configure","task":"x","policy":"nope"}"#, ErrorCode::UnknownPolicy),
            (r#"{"op":"configure","task":5,"policy":"ksplus"}"#, ErrorCode::InvalidField),
            // "*" is the default-scope response sentinel, reserved.
            (r#"{"op":"configure","task":"*","policy":"ksplus"}"#, ErrorCode::InvalidField),
            // Dedup is both-or-neither, and seq must be an integer.
            (
                r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1,"samples":[1]},"nonce":"n"}"#,
                ErrorCode::InvalidField,
            ),
            (
                r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1,"samples":[1]},"seq":3}"#,
                ErrorCode::InvalidField,
            ),
            (
                r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1,"samples":[1]},"nonce":"n","seq":"three"}"#,
                ErrorCode::InvalidField,
            ),
            (
                r#"{"op":"train","task":"x","history":[{"input_mb":1,"dt":1,"samples":[1]}],"nonce":7,"seq":3}"#,
                ErrorCode::InvalidField,
            ),
            (r#"{"op":"failure","fail_time":1}"#, ErrorCode::MissingField),
            (
                r#"{"op":"failure","plan":{"starts":[0],"peaks":[1]}}"#,
                ErrorCode::MissingField,
            ),
            (
                r#"{"op":"failure","plan":{"starts":[],"peaks":[]},"fail_time":1}"#,
                ErrorCode::InvalidPlan,
            ),
            (
                r#"{"op":"failure","plan":{"starts":[0,1],"peaks":[1]},"fail_time":1}"#,
                ErrorCode::InvalidPlan,
            ),
            (
                r#"{"op":"failure","plan":{"starts":[0],"peaks":["x"]},"fail_time":1}"#,
                ErrorCode::InvalidField,
            ),
            (r#"{"op":"hello","min_version":"two"}"#, ErrorCode::InvalidField),
            (r#"{"op":"reshard"}"#, ErrorCode::MissingField),
            (r#"{"op":"reshard","shards":"four"}"#, ErrorCode::InvalidField),
            (r#"{"op":"reshard","shards":0}"#, ErrorCode::InvalidField),
        ];
        for (line, want) in table {
            match Request::parse(line) {
                Err(e) => assert_eq!(e.code, *want, "req {line} -> {e}"),
                Ok(req) => panic!("{line} parsed as {req:?}, expected {want:?}"),
            }
        }
    }

    #[test]
    fn unknown_provenance_degrades_instead_of_failing() {
        // A newer server may name policies and fallback reasons this
        // build has never heard of; the plan payload must still decode.
        let line = r#"{"ok":true,"plan":{"starts":[0],"peaks":[4]},"predictor":"ppm-improved","model_version":7,"fallback_reason":"circuit-breaker"}"#;
        let j = Json::parse(line).unwrap();
        match Response::from_json(&j, "plan").unwrap() {
            Response::Planned(o) => {
                assert_eq!(o.predictor, PROVENANCE_UNKNOWN);
                assert_eq!(o.fallback_reason, Some(PROVENANCE_UNKNOWN));
                assert_eq!(o.model_version, 7);
                assert_eq!(o.plan, StepPlan::flat(4.0));
            }
            other => panic!("unexpected {other:?}"),
        }
        let line = r#"{"ok":true,"observed":"t","executions":3,"predictor":"from-the-future"}"#;
        match Response::from_json(&Json::parse(line).unwrap(), "observe").unwrap() {
            Response::Observed(a) => assert_eq!(a.predictor, PROVENANCE_UNKNOWN),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn stats_from_older_servers_default_connection_counters() {
        // A pre-limits server omits conns_refused/conn_timeouts; the
        // decode must not fail, just report zero.
        let line = r#"{"ok":true,"shards":1,"requests":5,"batches":2,"failures_handled":0,"tasks_trained":1,"observations":0,"fallbacks":0,"latency_p50_us":10.0,"latency_p99_us":20.0}"#;
        match Response::from_json(&Json::parse(line).unwrap(), "stats").unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.conns_refused, 0);
                assert_eq!(s.conn_timeouts, 0);
                assert_eq!(s.conns_overflowed, 0);
                assert_eq!(s.shed, 0);
                assert_eq!(s.queue_depth_max, 0);
                assert_eq!(s.drains, 0);
                assert_eq!(s.requests, 5);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn negotiation_is_conservative_and_refuses_bad_ranges() {
        // No explicit max: stay on v1 whatever we *could* speak — the
        // pre-v2 client population sends min_version:1 or nothing.
        assert_eq!(negotiate_version(None, None).unwrap(), 1);
        assert_eq!(negotiate_version(Some(1), None).unwrap(), 1);
        assert_eq!(negotiate_version(Some(1), Some(1)).unwrap(), 1);
        // Explicit opt-in to v2.
        assert_eq!(negotiate_version(None, Some(2)).unwrap(), WIRE_V2);
        assert_eq!(negotiate_version(Some(1), Some(2)).unwrap(), WIRE_V2);
        assert_eq!(negotiate_version(Some(2), Some(2)).unwrap(), WIRE_V2);
        // A client that *requires* v2 but set no max still gets it.
        assert_eq!(negotiate_version(Some(2), None).unwrap(), WIRE_V2);
        // A future client capped above us negotiates down to our max.
        assert_eq!(negotiate_version(None, Some(9)).unwrap(), WIRE_VERSION_MAX);
        // Failures.
        assert_eq!(
            negotiate_version(Some(3), Some(1)).unwrap_err().code,
            ErrorCode::InvalidField
        );
        assert_eq!(
            negotiate_version(Some(99), None).unwrap_err().code,
            ErrorCode::UnsupportedVersion
        );
        assert_eq!(
            negotiate_version(None, Some(0)).unwrap_err().code,
            ErrorCode::UnsupportedVersion
        );
    }

    #[test]
    fn shared_part_validators_match_the_json_parser() {
        assert_eq!(
            execution_from_parts("t", 1.0, 0.0, vec![1.0]).unwrap_err().code,
            ErrorCode::InvalidField
        );
        assert_eq!(
            execution_from_parts("t", 1.0, 1.0, vec![]).unwrap_err().code,
            ErrorCode::EmptySamples
        );
        assert_eq!(
            plan_from_parts(vec![0.0, 1.0], vec![1.0]).unwrap_err().code,
            ErrorCode::InvalidPlan
        );
        assert_eq!(
            validate_configure_task(Some("*".into())).unwrap_err().code,
            ErrorCode::InvalidField
        );
        assert_eq!(validate_history_len(0).unwrap_err().code, ErrorCode::EmptyHistory);
        assert_eq!(validate_reshard_shards(0).unwrap_err().code, ErrorCode::InvalidField);
    }

    #[test]
    fn error_codes_roundtrip() {
        for c in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(c.as_str()), Some(c));
        }
        assert_eq!(ErrorCode::parse("nope"), None);
        let e = WireError::new(ErrorCode::UnknownPolicy, "no such policy");
        let j = Json::parse(&e.to_json().to_string()).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(WireError::from_json(&j), e);
        // Legacy string-shaped errors degrade to Internal.
        let legacy = Json::parse(r#"{"ok":false,"error":"boom"}"#).unwrap();
        assert_eq!(WireError::from_json(&legacy).code, ErrorCode::Internal);
    }

    #[test]
    fn executions_and_plans_survive_the_wire_bit_exactly() {
        // Shortest-roundtrip float formatting: what goes out comes back
        // as the very same f64s — the property the KS+ parity test over
        // TCP relies on.
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let e = exec(rng.next_u64());
            let j = Json::parse(&execution_to_json(&e).to_string()).unwrap();
            let back = execution_from_json("t", &j).unwrap();
            assert_eq!(back.input_mb.to_bits(), e.input_mb.to_bits());
            assert_eq!(back.dt.to_bits(), e.dt.to_bits());
            assert_eq!(back.samples.len(), e.samples.len());
            for (a, b) in back.samples.iter().zip(&e.samples) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let p = StepPlan::new(vec![0.0, 68.279_999_999_999_99], vec![4.4, 8.800000000000001]);
        let j = Json::parse(&plan_to_json(&p).to_string()).unwrap();
        let back = plan_from_json(&j).unwrap();
        for (a, b) in back.starts.iter().zip(&p.starts) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in back.peaks.iter().zip(&p.peaks) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
