//! Report rendering: fixed-width text tables + JSON series.

use crate::util::json::Json;

/// A simple fixed-width table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self, title: &str) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {title} ==\n"));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with thousands-friendly precision.
pub fn f(x: f64) -> String {
    if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

/// Mean and population std of a slice, as "mean±std".
pub fn mean_pm_std(xs: &[f64]) -> String {
    format!(
        "{}±{}",
        f(crate::util::stats::mean(xs)),
        f(crate::util::stats::stddev(xs))
    )
}

/// Wrap a list of (key, value) series into a JSON object.
pub fn json_obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "wastage"]);
        t.row(vec!["ksplus".into(), "12.3".into()]);
        t.row(vec!["ppm-improved".into(), "456".into()]);
        let s = t.render("demo");
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // title + header + separator + 2 rows
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(12345.6), "12346");
        assert_eq!(f(45.67), "45.7");
        assert_eq!(f(1.2345), "1.234");
    }

    #[test]
    fn mean_pm_std_format() {
        let s = mean_pm_std(&[1.0, 2.0, 3.0]);
        assert!(s.starts_with("2.000±"));
    }
}
