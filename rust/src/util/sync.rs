//! Poison-recovering lock helpers.
//!
//! A panicking thread poisons every `Mutex`/`RwLock` it holds, and the
//! default `.lock().unwrap()` idiom then cascades that one panic into
//! every other thread touching the lock — a single buggy dispatch worker
//! could wedge the whole event loop. Server-side shared state in this
//! crate is counters, queues, and connection tables: all of it remains
//! structurally valid after a worker panic (the panicking code never
//! leaves a half-written entry observable, because pushes/pops are the
//! last statement under the guard). Recovering the guard and continuing
//! is therefore strictly better than dying, and these helpers make the
//! recovery explicit and greppable.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// `mutex.lock()` that survives poisoning instead of panicking.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `rwlock.read()` that survives poisoning instead of panicking.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `rwlock.write()` that survives poisoning instead of panicking.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `condvar.wait(guard)` that survives poisoning instead of panicking.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mutex_recovers_after_a_panicking_holder() {
        let m = Arc::new(Mutex::new(7u64));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_recover(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn rwlock_recovers_after_a_panicking_writer() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(read_recover(&l).len(), 3);
        write_recover(&l).push(4);
        assert_eq!(read_recover(&l).len(), 4);
    }

    #[test]
    fn condvar_wait_recovers_from_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        // Poison the mutex first so the eventual `wait` returns a
        // poisoned guard rather than panicking through the helper.
        let p2 = pair.clone();
        let _ = std::thread::spawn(move || {
            let _g = p2.0.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let p3 = pair.clone();
        let waker = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            *lock_recover(&p3.0) = true;
            p3.1.notify_all();
        });
        let (m, cv) = (&pair.0, &pair.1);
        let mut g = lock_recover(m);
        while !*g {
            g = wait_recover(cv, g);
        }
        drop(g);
        waker.join().unwrap();
    }
}
