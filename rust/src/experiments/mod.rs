//! Experiment harness: regenerates every figure of the paper's
//! evaluation (Figs 1-8) from the synthetic workflow traces.
//!
//! Each experiment prints the same rows/series the paper reports and
//! returns them as JSON for the `results/` directory. The experiment
//! index lives in DESIGN.md Section 3; EXPERIMENTS.md records
//! paper-vs-measured for each.

pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod figs;
pub mod loadgen;
pub mod report;
pub mod throughput;

use anyhow::{bail, Result};

use crate::metrics::WastageReport;
use crate::predictor::{self, Predictor};
use crate::sim;
use crate::trace::workflow::Workflow;
use crate::trace::{split_train_test, Execution, WorkflowTrace};
use crate::util::rng::Rng;

/// Shared experiment parameters (paper Section III-A).
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Train/test split seeds; the paper averages over 10.
    pub seeds: Vec<u64>,
    /// Training fractions of Fig 6/8.
    pub train_fracs: Vec<f64>,
    /// Segment count for the segment methods (Fig 7 sweeps it).
    pub k: usize,
    /// Node capacity (AMD EPYC 7282 testbed: 128 GB).
    pub capacity_gb: f64,
    /// Trace-generation seed (the "recorded dataset"; fixed, unlike the
    /// split seeds).
    pub trace_seed: u64,
    /// Target samples per trace (bounded by the wastage bucket N=512).
    pub target_samples: usize,
    /// Ingested trace CSV (either supported header shape) to evaluate on
    /// instead of the synthetic workflows (`repro experiment --trace`).
    pub trace_csv: Option<std::path::PathBuf>,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            seeds: (1..=10).collect(),
            train_fracs: vec![0.25, 0.50, 0.75],
            k: 4,
            capacity_gb: 128.0,
            trace_seed: 42,
            target_samples: 200,
            trace_csv: None,
        }
    }
}

impl ExpConfig {
    /// Smaller variant for smoke tests and benches.
    pub fn quick() -> Self {
        ExpConfig { seeds: vec![1, 2, 3], ..Default::default() }
    }
}

/// The (workflow, trace, label) list an experiment evaluates: the two
/// synthetic workflows by default, or the single ingested CSV when
/// `--trace` is set. The workflow paired with an ingested trace only
/// supplies developer limits for tasks it happens to know; everything
/// else gets a data-driven limit from its training history.
pub fn eval_traces(cfg: &ExpConfig) -> Result<Vec<(Workflow, WorkflowTrace, &'static str)>> {
    if let Some(path) = &cfg.trace_csv {
        let trace = crate::trace::load_csv_auto(path, "trace")?;
        anyhow::ensure!(
            trace.tasks.iter().any(|t| t.executions.len() >= 2),
            "{}: no task has >= 2 executions, nothing to split into train/test",
            path.display()
        );
        return Ok(vec![(Workflow::eager(), trace, "trace")]);
    }
    Ok([Workflow::eager(), Workflow::sarek()]
        .into_iter()
        .map(|wf| {
            let trace = wf.generate(cfg.trace_seed, cfg.target_samples);
            let name = wf.name;
            (wf, trace, name)
        })
        .collect())
}

/// Build a trained predictor for `method` on `train`, honouring the
/// per-task developer default for the `default` baseline.
pub fn trained_predictor(
    method: &str,
    k: usize,
    capacity: f64,
    workflow: &Workflow,
    task: &str,
    train: &[Execution],
) -> Result<Box<dyn Predictor>> {
    let mut pred: Box<dyn Predictor> = if method == "default" {
        // Tasks the workflow does not know (ingested traces, scenario
        // streams) start with no registered limit; `DefaultLimits::train`
        // then sizes one from the history (2x max observed peak), the way
        // a user would after a first run.
        let limit = workflow
            .archetype(task)
            .map(|a| a.default_limit_gb)
            .unwrap_or(0.0);
        Box::new(predictor::DefaultLimits::with_limit(capacity, limit))
    } else {
        match predictor::by_name(method, k, capacity) {
            Some(p) => p,
            None => bail!("unknown method '{method}'"),
        }
    };
    pred.train(train);
    Ok(pred)
}

/// Evaluate one method on one workflow trace for one (train_frac, seed):
/// per task, split -> train -> simulate the test set through the
/// OOM/retry loop; aggregate wastage across tasks.
///
/// The split RNG is forked per task from `seed` only, so every method
/// sees the identical split (paired comparison, as in the paper).
pub fn evaluate_method(
    method: &str,
    k: usize,
    capacity: f64,
    workflow: &Workflow,
    trace: &WorkflowTrace,
    train_frac: f64,
    seed: u64,
) -> Result<WastageReport> {
    let mut report = WastageReport::default();
    for (task_idx, task_traces) in trace.tasks.iter().enumerate() {
        let mut split_rng = Rng::new(seed).fork(task_idx as u64 + 1);
        let (train, test) = split_train_test(task_traces, train_frac, &mut split_rng);
        let pred = trained_predictor(method, k, capacity, workflow, &task_traces.task, &train)?;
        for outcome in sim::run_all(pred.as_ref(), &test) {
            report.add(&outcome);
        }
    }
    Ok(report)
}

/// Run an experiment by figure id; returns the rendered text report.
pub fn run(name: &str, cfg: &ExpConfig, out_dir: Option<&std::path::Path>) -> Result<String> {
    let result = match name {
        "fig1a" => figs::fig1a(cfg),
        "fig1b" => figs::fig1b(cfg),
        "fig2" => figs::fig2(cfg),
        "fig3" => figs::fig3(cfg),
        "fig4" => figs::fig4(cfg),
        "fig5" => figs::fig5(cfg),
        "fig6" => fig6::run(cfg),
        "fig6x" => fig6::run_extended(cfg),
        "throughput" => throughput::run(cfg),
        "fig7" => fig7::run(cfg),
        "fig8" => fig8::run(cfg),
        "all" => {
            let mut out = String::new();
            for id in
                ["fig1a", "fig1b", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "throughput"]
            {
                out.push_str(&run(id, cfg, out_dir)?);
                out.push('\n');
            }
            return Ok(out);
        }
        _ => bail!("unknown experiment '{name}' (try fig1a..fig8 or all)"),
    }?;
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.json"));
        std::fs::write(&path, result.json.to_string())?;
    }
    Ok(result.text)
}

/// An experiment's rendered output.
pub struct ExpOutput {
    pub text: String,
    pub json: crate::util::json::Json,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluate_method_runs_all_tasks() {
        let wf = Workflow::eager();
        let trace = wf.generate(42, 80);
        let r = evaluate_method("ppm-improved", 4, 128.0, &wf, &trace, 0.5, 1).unwrap();
        assert_eq!(r.per_task.len(), 9);
        assert!(r.total_wastage_gbs() > 0.0);
    }

    #[test]
    fn identical_split_across_methods() {
        // Paired evaluation: instance counts per task must match between
        // methods for the same seed.
        let wf = Workflow::eager();
        let trace = wf.generate(42, 60);
        let a = evaluate_method("ksplus", 4, 128.0, &wf, &trace, 0.5, 3).unwrap();
        let b = evaluate_method("tovar-ppm", 4, 128.0, &wf, &trace, 0.5, 3).unwrap();
        for (task, agg) in &a.per_task {
            assert_eq!(agg.instances, b.per_task[task].instances, "{task}");
        }
    }

    #[test]
    fn default_method_uses_archetype_limits() {
        let wf = Workflow::eager();
        let trace = wf.generate(42, 60);
        let r = evaluate_method("default", 4, 128.0, &wf, &trace, 0.5, 1).unwrap();
        assert!(r.total_wastage_gbs() > 0.0);
    }

    #[test]
    fn eval_traces_switches_between_synthetic_and_csv() {
        let cfg = ExpConfig::quick();
        let synth = eval_traces(&cfg).unwrap();
        assert_eq!(synth.len(), 2);
        assert_eq!(synth[0].2, "eager");
        assert_eq!(synth[1].2, "sarek");

        let csv = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../golden/traces/nfcore_rnaseq_sample.csv"
        );
        let cfg = ExpConfig { trace_csv: Some(csv.into()), ..ExpConfig::quick() };
        let ingested = eval_traces(&cfg).unwrap();
        assert_eq!(ingested.len(), 1);
        assert_eq!(ingested[0].2, "trace");
        assert_eq!(ingested[0].1.tasks.len(), 3);
        // The ingested trace evaluates under every paper method,
        // including `default` (data-driven limits for unknown tasks).
        let (wf, trace, _) = &ingested[0];
        for method in ["ksplus", "default"] {
            let r = evaluate_method(method, 4, 128.0, wf, trace, 0.5, 1).unwrap();
            assert_eq!(r.per_task.len(), 3, "{method}");
        }

        let cfg = ExpConfig {
            trace_csv: Some("/nonexistent/x.csv".into()),
            ..ExpConfig::quick()
        };
        assert!(eval_traces(&cfg).is_err());
    }

    #[test]
    fn default_method_sizes_unknown_tasks_from_history() {
        let wf = Workflow::eager();
        let train = vec![
            Execution::new("NOT_AN_ARCHETYPE", 10.0, 1.0, vec![1.0, 3.0]),
            Execution::new("NOT_AN_ARCHETYPE", 12.0, 1.0, vec![2.0, 2.5]),
        ];
        let p = trained_predictor("default", 4, 128.0, &wf, "NOT_AN_ARCHETYPE", &train).unwrap();
        let plan = p.plan(10.0);
        // 2x the max observed peak (3.0), not a hard-coded constant.
        assert!((plan.peaks[0] - 6.0).abs() < 1e-9, "{:?}", plan.peaks);
    }

    #[test]
    fn unknown_method_errors() {
        let wf = Workflow::eager();
        let trace = wf.generate(42, 40);
        assert!(evaluate_method("nope", 4, 128.0, &wf, &trace, 0.5, 1).is_err());
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("fig99", &ExpConfig::quick(), None).is_err());
    }
}
