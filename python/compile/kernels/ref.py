"""Pure-jnp oracles for the Pallas kernels in ols.py.

These are the correctness reference: pytest asserts allclose between every
kernel and its oracle across a hypothesis sweep of shapes/values, and the
rust-side unit tests pin the same closed forms.
"""

from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def fit_ref(x, y, m):
    x = jnp.asarray(x, jnp.float32) * m
    y = jnp.asarray(y, jnp.float32) * m
    n = jnp.sum(m, axis=-1)
    sx = jnp.sum(x, axis=-1)
    sy = jnp.sum(y, axis=-1)
    sxy = jnp.sum(x * y, axis=-1)
    sxx = jnp.sum(x * x, axis=-1)
    denom = n * sxx - sx * sx
    ok = (n >= 2.0) & (jnp.abs(denom) > _EPS)
    safe = jnp.where(ok, denom, 1.0)
    slope = jnp.where(ok, (n * sxy - sx * sy) / safe, 0.0)
    nz = jnp.maximum(n, 1.0)
    intercept = jnp.where(ok, (sy - slope * sx) / nz, sy / nz)
    return jnp.stack([slope, intercept], axis=-1)


def predict_ref(coef, xq, scale):
    yhat = coef[:, 0] * xq + coef[:, 1]
    return jnp.maximum(yhat * scale, 0.0)


def wastage_ref(alloc, used, m, dt):
    over = jnp.maximum(alloc - used, 0.0) * m
    return jnp.sum(over, axis=-1) * dt


def plan_wastage_ref(starts, peaks, used, m, dt):
    n = used.shape[-1]
    t = jnp.arange(n, dtype=jnp.float32)[None, :] * dt[:, None]
    active = starts[:, None, :] <= t[:, :, None]
    alloc = jnp.max(jnp.where(active, peaks[:, None, :], 0.0), axis=-1)
    over = jnp.maximum(alloc - used, 0.0) * m
    return jnp.sum(over, axis=-1) * dt
