//! Trace (de)serialisation: a compact CSV form for interchange with
//! external monitoring data, plus JSON summaries for reports.
//!
//! CSV schema (one row per execution):
//!   task,input_mb,dt,samples
//! where `samples` is a ';'-joined list of GB values. The format is
//! intentionally trivial so real nf-core monitoring exports can be
//! converted with a one-line awk script.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::trace::{Execution, TaskTraces, WorkflowTrace};

pub const CSV_HEADER: &str = "task,input_mb,dt,samples";

pub fn write_csv(path: &Path, trace: &WorkflowTrace) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    writeln!(f, "{CSV_HEADER}")?;
    for t in &trace.tasks {
        for e in &t.executions {
            let samples: Vec<String> =
                e.samples.iter().map(|s| format!("{s:.4}")).collect();
            writeln!(f, "{},{:.2},{:.3},{}", e.task, e.input_mb, e.dt, samples.join(";"))?;
        }
    }
    Ok(())
}

pub fn read_csv(path: &Path, name: &str) -> Result<WorkflowTrace> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut lines = BufReader::new(f).lines();
    match lines.next() {
        Some(Ok(h)) if h.trim() == CSV_HEADER => {}
        other => bail!("bad CSV header: {other:?}"),
    }
    let mut trace = WorkflowTrace { name: name.to_string(), tasks: Vec::new() };
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.splitn(4, ',').collect();
        if parts.len() != 4 {
            bail!("line {}: expected 4 fields, got {}", lineno + 2, parts.len());
        }
        let task = parts[0].to_string();
        let input_mb: f64 = parts[1].parse().with_context(|| format!("line {}", lineno + 2))?;
        let dt: f64 = parts[2].parse().with_context(|| format!("line {}", lineno + 2))?;
        let samples: Result<Vec<f64>, _> =
            parts[3].split(';').filter(|s| !s.is_empty()).map(|s| s.parse::<f64>()).collect();
        let samples = samples.with_context(|| format!("line {}: bad samples", lineno + 2))?;
        let exec = Execution::new(task.clone(), input_mb, dt, samples);
        match trace.tasks.iter_mut().find(|t| t.task == task) {
            Some(t) => t.executions.push(exec),
            None => trace.tasks.push(TaskTraces { task, executions: vec![exec] }),
        }
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::workflow::Workflow;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("ksplus_io_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_preserves_structure() {
        let wf = Workflow::eager();
        let trace = wf.generate(1, 50);
        let path = tmp("roundtrip.csv");
        write_csv(&path, &trace).unwrap();
        let back = read_csv(&path, "eager").unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.total_instances(), trace.total_instances());
        assert_eq!(back.tasks.len(), trace.tasks.len());
        let a = &trace.tasks[0].executions[0];
        let b = &back.tasks[0].executions[0];
        assert_eq!(a.task, b.task);
        assert!((a.input_mb - b.input_mb).abs() < 0.01);
        assert_eq!(a.samples.len(), b.samples.len());
        assert!((a.peak() - b.peak()).abs() < 1e-3);
    }

    #[test]
    fn rejects_bad_header() {
        let path = tmp("badheader.csv");
        std::fs::write(&path, "nope\n1,2,3,4\n").unwrap();
        assert!(read_csv(&path, "x").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed_row() {
        let path = tmp("badrow.csv");
        std::fs::write(&path, format!("{CSV_HEADER}\nbwa,notanumber,1.0,1;2\n")).unwrap();
        assert!(read_csv(&path, "x").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn skips_blank_lines() {
        let path = tmp("blank.csv");
        std::fs::write(&path, format!("{CSV_HEADER}\n\nbwa,10.0,1.0,1;2;3\n\n")).unwrap();
        let t = read_csv(&path, "x").unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(t.total_instances(), 1);
        assert_eq!(t.tasks[0].executions[0].samples, vec![1.0, 2.0, 3.0]);
    }
}
