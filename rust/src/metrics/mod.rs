//! Wastage accounting (the paper's evaluation metric) and aggregation.
//!
//! Wastage of one task execution, in GB-seconds (Section III-A):
//!   * successful attempt: integral of (requested - used) over time;
//!   * each failed attempt: the *entire* allocated memory over time up to
//!     the failure, since the work is discarded on restart.

use std::collections::BTreeMap;

/// Outcome of simulating one task instance under one predictor.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskOutcome {
    pub task: String,
    pub input_mb: f64,
    /// Total attempts (1 = no failure).
    pub attempts: usize,
    pub success: bool,
    /// Total wastage over all attempts, GB*s.
    pub wastage_gbs: f64,
    /// Allocation integral of the successful attempt, GB*s.
    pub alloc_gbs: f64,
    /// Usage integral of the task itself, GB*s.
    pub used_gbs: f64,
}

/// Aggregated per-task and total statistics over many outcomes.
#[derive(Debug, Clone, Default)]
pub struct WastageReport {
    pub per_task: BTreeMap<String, TaskAgg>,
}

#[derive(Debug, Clone, Default)]
pub struct TaskAgg {
    pub instances: usize,
    pub failures: usize,
    pub unfinished: usize,
    pub wastage_gbs: f64,
    pub alloc_gbs: f64,
    pub used_gbs: f64,
}

impl WastageReport {
    pub fn add(&mut self, o: &TaskOutcome) {
        let agg = self.per_task.entry(o.task.clone()).or_default();
        agg.instances += 1;
        agg.failures += o.attempts - 1;
        if !o.success {
            agg.unfinished += 1;
        }
        agg.wastage_gbs += o.wastage_gbs;
        agg.alloc_gbs += o.alloc_gbs;
        agg.used_gbs += o.used_gbs;
    }

    pub fn from_outcomes<'a>(outcomes: impl IntoIterator<Item = &'a TaskOutcome>) -> Self {
        let mut r = WastageReport::default();
        for o in outcomes {
            r.add(o);
        }
        r
    }

    /// Total wastage across tasks, GB*s (Fig 6 quantity).
    pub fn total_wastage_gbs(&self) -> f64 {
        self.per_task.values().map(|a| a.wastage_gbs).sum()
    }

    pub fn total_failures(&self) -> usize {
        self.per_task.values().map(|a| a.failures).sum()
    }

    pub fn total_instances(&self) -> usize {
        self.per_task.values().map(|a| a.instances).sum()
    }

    /// Fraction of allocated GB*s that was actually used (efficiency).
    pub fn efficiency(&self) -> f64 {
        let alloc: f64 = self.per_task.values().map(|a| a.alloc_gbs).sum();
        let used: f64 = self.per_task.values().map(|a| a.used_gbs).sum();
        if alloc <= 0.0 {
            0.0
        } else {
            used / alloc
        }
    }

    pub fn task_wastage(&self, task: &str) -> f64 {
        self.per_task.get(task).map(|a| a.wastage_gbs).unwrap_or(0.0)
    }
}

/// Relative reduction of `ours` vs `baseline`, as a fraction in [-inf, 1].
/// (0.38 == "38 % less wastage than the baseline".)
pub fn relative_reduction(ours: f64, baseline: f64) -> f64 {
    if baseline <= 0.0 {
        0.0
    } else {
        1.0 - ours / baseline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(task: &str, attempts: usize, wastage: f64) -> TaskOutcome {
        TaskOutcome {
            task: task.into(),
            input_mb: 1.0,
            attempts,
            success: true,
            wastage_gbs: wastage,
            alloc_gbs: wastage + 10.0,
            used_gbs: 10.0,
        }
    }

    #[test]
    fn report_aggregates_by_task() {
        let outs =
            vec![outcome("a", 1, 5.0), outcome("a", 2, 7.0), outcome("b", 1, 3.0)];
        let r = WastageReport::from_outcomes(&outs);
        assert_eq!(r.total_instances(), 3);
        assert_eq!(r.total_failures(), 1);
        assert!((r.total_wastage_gbs() - 15.0).abs() < 1e-12);
        assert!((r.task_wastage("a") - 12.0).abs() < 1e-12);
        assert_eq!(r.task_wastage("zzz"), 0.0);
    }

    #[test]
    fn efficiency_ratio() {
        let outs = vec![outcome("a", 1, 10.0)]; // alloc 20, used 10
        let r = WastageReport::from_outcomes(&outs);
        assert!((r.efficiency() - 0.5).abs() < 1e-12);
        assert_eq!(WastageReport::default().efficiency(), 0.0);
    }

    #[test]
    fn unfinished_counted() {
        let mut o = outcome("a", 3, 50.0);
        o.success = false;
        let r = WastageReport::from_outcomes(&[o]);
        assert_eq!(r.per_task["a"].unfinished, 1);
    }

    #[test]
    fn relative_reduction_matches_paper_usage() {
        assert!((relative_reduction(62.0, 100.0) - 0.38).abs() < 1e-12);
        assert_eq!(relative_reduction(10.0, 0.0), 0.0);
        assert!(relative_reduction(150.0, 100.0) < 0.0);
    }
}
