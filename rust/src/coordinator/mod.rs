//! Online memory-prediction service: the deployment surface a workflow
//! engine (Nextflow/Airflow/Snakemake) would call before submitting each
//! task to the resource manager.
//!
//! Architecture (std threads + channels; see DESIGN.md Section 5b). The
//! coordinator is a pool of `shards` identical workers; every worker
//! owns its own model store, numeric backend, and dynamic batcher:
//!
//! ```text
//!                ┌─hash(task)──▶ worker 0 (store + backend + batcher)
//!   clients ──┬──┤              worker 1 (store + backend + batcher)
//!             │  └─hash(task)──▶ ...
//!             │                 worker N-1 (store + backend + batcher)
//!             │   each worker:
//!             │     ├─ Train    : fold of Observe over the history
//!             │     ├─ Observe  : O(k) incremental update — segment ONE
//!             │     │             new execution, fold it into the 2k
//!             │     │             OLS sufficient-stat accumulators,
//!             │     │             refit the closed forms
//!             │     ├─ Plan     : dynamic batcher — collects up to
//!             │     │             `batch_max` requests or `batch_delay`,
//!             │     │             then ONE batched predict over the
//!             │     │             queued task×segment models
//!             │     └─ Failure  : KS+ segment-rescaling retry
//!             │                   (stateless; round-robin over shards)
//!             └──fan-out───────▶ Stats : merged across every shard
//! ```
//!
//! `Train`, `Observe`, and `Plan` route by a consistent-hash ring over
//! the live shard ids (`ring::HashRing`), so one shard owns each task's
//! models and its plan traffic; `shards: 1` (the default) reproduces the
//! original single-worker coordinator. The ring makes the pool *elastic*
//! — shards can be added and removed at runtime, moving only ~1/N of the
//! tasks, whose accumulators are handed off through the worker channels —
//! and every state-changing message is dual-sent to the task's standby
//! (next distinct shard clockwise), so a killed worker is restored from
//! its neighbors with zero lost training (`service::Client::
//! crash_restart_shard`). The full trained state snapshots to a
//! versioned JSON document (`snapshot`) for restart-with-memory
//! (`repro serve --snapshot-dir`).
//!
//! Every task is bound to a named **predictor policy**
//! (`PredictorPolicy`): `ksplus` (the default, served by the fast path
//! below), or one of the paper's baselines — `witt-lr`, `tovar-ppm`,
//! `ksegments`, `default-limits` — served through the offline
//! `Predictor` trait with refit-on-observe. Policies are set per task
//! (or service-wide) via `configure`, and every served plan carries
//! provenance (`PlanOutcome`): which policy computed it, its model
//! version, and whether it was an untrained fallback.
//!
//! KS+ training is *incremental*: the
//! store keeps per-task sufficient statistics (n, Σx, Σy, Σx², Σxy) for
//! every one of the 2k regressions, so observing a finished execution
//! costs one segmentation of that execution plus O(k) accumulator
//! updates — history is never re-segmented — and a batch `Train` is
//! literally a fold of `Observe`, making the two bit-identical. Each
//! per-shard batcher is the L3 hot path: with the `pjrt` cargo feature
//! every flush is a single PJRT execution of `predict_b{B}.hlo.txt`
//! covering every queued request's 2k regression evaluations; in default
//! (native-only) builds the same flush runs the closed-form OLS
//! in-process. The Python stack is never invoked either way.

#[cfg(unix)]
pub mod eventloop;
pub mod faults;
#[cfg(unix)]
pub mod poll;
pub mod protocol;
pub mod remote;
pub mod ring;
pub mod server;
pub mod service;
pub mod session;
pub mod snapshot;
pub mod timer;
pub mod wire;

use crate::predictor::ksplus::{KsPlus, MEM_OVERPREDICT, TIME_UNDERPREDICT};
use crate::predictor::regression::{LinModel, OlsStats};
use crate::predictor::Predictor;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::segments::StepPlan;
use crate::trace::Execution;

/// Named predictor strategy a task (or the service-wide default) can be
/// bound to. `ksplus` is the fast default: it is served by the dedicated
/// 2k sufficient-statistics path in `TaskModels` with O(k) incremental
/// `observe`. The other strategies go through the offline `Predictor`
/// trait — their math has no incremental closed form, so an `observe`
/// refits them from the task's retained history.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorPolicy {
    /// KS+ variable segments (the paper's contribution).
    KsPlus,
    /// Witt et al. linear-regression peak predictor (mean + sigma offset).
    WittLr,
    /// Tovar et al. peak-probability first allocation (machine-max retry).
    TovarPpm,
    /// k equal-sized segments with the selective retry strategy.
    KSegments,
    /// The workflow developers' static default limits (doubling retry).
    DefaultLimits,
}

impl PredictorPolicy {
    /// Every policy, in the order `hello` advertises them.
    pub const ALL: [PredictorPolicy; 5] = [
        PredictorPolicy::KsPlus,
        PredictorPolicy::WittLr,
        PredictorPolicy::TovarPpm,
        PredictorPolicy::KSegments,
        PredictorPolicy::DefaultLimits,
    ];

    /// Stable wire name (`configure.policy`, plan provenance).
    pub fn name(self) -> &'static str {
        match self {
            PredictorPolicy::KsPlus => "ksplus",
            PredictorPolicy::WittLr => "witt-lr",
            PredictorPolicy::TovarPpm => "tovar-ppm",
            PredictorPolicy::KSegments => "ksegments",
            PredictorPolicy::DefaultLimits => "default-limits",
        }
    }

    pub fn parse(s: &str) -> Option<PredictorPolicy> {
        PredictorPolicy::ALL.iter().copied().find(|p| p.name() == s)
    }

    pub fn names() -> Vec<&'static str> {
        PredictorPolicy::ALL.iter().map(|p| p.name()).collect()
    }

    /// Build the offline predictor implementing this strategy (used for
    /// every policy except the KS+ sufficient-statistics fast path).
    fn build(self, k: usize, capacity: f64) -> Box<dyn Predictor> {
        use crate::predictor::{ksegments, tovar, witt, DefaultLimits};
        match self {
            PredictorPolicy::KsPlus => Box::new(KsPlus::new(k, capacity)),
            PredictorPolicy::WittLr => {
                Box::new(witt::WittLr::new(capacity, witt::Offset::MeanSigma))
            }
            PredictorPolicy::TovarPpm => {
                Box::new(tovar::TovarPpm::new(capacity, tovar::RetryMode::MachineMax))
            }
            PredictorPolicy::KSegments => {
                Box::new(ksegments::KSegments::new(k, capacity, ksegments::RetryMode::Selective))
            }
            PredictorPolicy::DefaultLimits => Box::new(DefaultLimits::new(capacity)),
        }
    }
}

/// `PlanOutcome::fallback_reason` when the bound policy had no trained
/// model for the task and the capacity-safe flat default was served.
pub const FALLBACK_UNTRAINED: &str = "untrained-task";

/// A served plan plus its provenance: which policy actually computed it,
/// how many executions the serving model had folded in, and whether it
/// was a fallback rather than a trained prediction. This is what the
/// wire `plan` response carries, so callers can tell a trained KS+ plan
/// from a default-limits fallback.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanOutcome {
    pub plan: StepPlan,
    /// Policy that computed the plan (`"default-limits"` for fallbacks).
    pub predictor: &'static str,
    /// Executions folded into the serving model (0 for a fallback).
    pub model_version: u64,
    /// `Some(FALLBACK_UNTRAINED)` iff the plan is the untrained default.
    pub fallback_reason: Option<&'static str>,
}

/// A retry plan plus the policy whose failure strategy produced it.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryOutcome {
    pub plan: StepPlan,
    pub predictor: &'static str,
}

/// Numeric backend for the coordinator. PJRT handles are thread-affine
/// (`Rc`): the service constructs its backend *inside* the worker thread
/// from a `BackendSpec`. The PJRT variant only exists when the crate is
/// compiled with the `pjrt` feature; `Backend::Native` is always there.
#[derive(Clone)]
pub enum Backend {
    /// In-process closed form (tests, environments without artifacts).
    Native,
    /// AOT Pallas kernels through PJRT (production path, `pjrt` feature).
    #[cfg(feature = "pjrt")]
    Pjrt(std::rc::Rc<Runtime>),
}

/// Send-able description of a backend, resolved on the worker thread.
///
/// `BackendSpec::Pjrt` is always available to *describe* — callers such
/// as the CLI and the wire protocol compile unchanged either way — but
/// `build()` returns a runtime error in a native-only build.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    Native,
    /// Load artifacts from this directory (or the default location).
    Pjrt(Option<std::path::PathBuf>),
}

impl BackendSpec {
    /// Whether this spec can be built in this binary (the native backend
    /// always can; PJRT needs the `pjrt` cargo feature).
    pub fn available(&self) -> bool {
        match self {
            BackendSpec::Native => true,
            BackendSpec::Pjrt(_) => cfg!(feature = "pjrt"),
        }
    }

    pub fn build(&self) -> anyhow::Result<Backend> {
        match self {
            BackendSpec::Native => Ok(Backend::Native),
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt(dir) => {
                let dir = dir
                    .clone()
                    .unwrap_or_else(crate::runtime::default_artifacts_dir);
                Ok(Backend::Pjrt(std::rc::Rc::new(Runtime::load(&dir)?)))
            }
            #[cfg(not(feature = "pjrt"))]
            BackendSpec::Pjrt(_) => anyhow::bail!(
                "the PJRT backend was not compiled into this binary; rebuild \
                 with `cargo build --features pjrt`, or use BackendSpec::Native"
            ),
        }
    }
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Evaluate `models[i]` at `xq[i]`, scaled by `scale[i]` and clamped
    /// at zero, into `out` (cleared first). The reusable `out` buffer is
    /// what lets a steady-state batcher flush avoid fresh allocations.
    fn predict_into(&self, models: &[LinModel], xq: &[f64], scale: &[f64], out: &mut Vec<f64>) {
        out.clear();
        match self {
            Backend::Native => out.extend(
                models
                    .iter()
                    .zip(xq.iter().zip(scale))
                    .map(|(m, (x, s))| (m.predict(*x) * s).max(0.0)),
            ),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => {
                out.extend(rt.predict_batch(models, xq, scale).expect("PJRT predict"))
            }
        }
    }
}

/// Per-task model state: the 2k sufficient-statistic accumulators
/// (k segment starts, then k segment peaks) plus the closed-form models
/// refit from them after every observation.
#[derive(Debug, Clone)]
pub struct TaskModels {
    /// Sufficient statistics for the 2k regressions.
    stats: Vec<OlsStats>,
    pub start_models: Vec<LinModel>,
    pub peak_models: Vec<LinModel>,
    /// Highest peak seen so far. Exposed for introspection (mirrors the
    /// KsPlus batch rule max(peaks…, 0.1)); the store's unknown-task
    /// fallback can never consult it, because an unknown task has no
    /// `TaskModels` entry at all.
    pub fallback_peak: f64,
    /// Executions folded in so far.
    pub observed: u64,
}

impl TaskModels {
    fn empty(k: usize) -> TaskModels {
        TaskModels {
            stats: vec![OlsStats::default(); 2 * k],
            start_models: Vec::new(),
            peak_models: Vec::new(),
            // Matches the batch rule max(peaks… , 0.1) once peaks fold in.
            fallback_peak: 0.1,
            observed: 0,
        }
    }

    /// Refit the 2k closed forms from the accumulators. O(k).
    fn refit(&mut self, k: usize) {
        self.start_models.clear();
        self.start_models.extend(self.stats[..k].iter().map(OlsStats::fit));
        self.peak_models.clear();
        self.peak_models.extend(self.stats[k..].iter().map(OlsStats::fit));
    }
}

/// Per-request routing decision of one `plan_batch_into` call. The KS+
/// variant carries no plan — its 2k model evaluations ride the single
/// batched backend predict; the others are resolved directly.
#[derive(Debug)]
enum PlanMeta {
    /// Trained KS+ task: consume 2k slots from the batched predict.
    Ks { version: u64 },
    /// Plan computed directly by a non-KS+ policy predictor.
    Direct { plan: StepPlan, predictor: &'static str, version: u64 },
    /// No trained model under the bound policy: flat capacity-safe default.
    Fallback,
}

/// Reusable buffers for `plan_batch_into`. Each coordinator worker owns
/// one, so a steady-state batcher flush performs no per-request `String`
/// clones and reuses every intermediate numeric buffer across flushes
/// (what remains per flush: one request-tuple `Vec` of borrowed names,
/// plus the returned plans themselves).
#[derive(Debug, Default)]
pub struct PlanScratch {
    models: Vec<LinModel>,
    xq: Vec<f64>,
    scale: Vec<f64>,
    meta: Vec<PlanMeta>,
    flat: Vec<f64>,
    /// Served plans with provenance, in request order, after
    /// `plan_batch_into`.
    pub plans: Vec<PlanOutcome>,
}

/// How many executions a non-KS+ task retains for refitting. These
/// strategies have no incremental closed form, so the service keeps a
/// bounded sliding window instead of every execution ever observed —
/// a long-running coordinator must not grow per-observe memory (the
/// KS+ path's O(1)-space property, approximated for the baselines).
pub const ALT_HISTORY_CAP: usize = 512;

/// Trained state for a task bound to a non-KS+ policy: the boxed
/// predictor plus the (bounded) history window it was fitted from. An
/// `observe` appends to the window and refits — O(window) per observe,
/// versus KS+'s O(k). The window is policy-independent, which lets
/// `configure` switch a task between strategies and refit the new one
/// from the same data.
struct AltModel {
    policy: PredictorPolicy,
    pred: Box<dyn Predictor>,
    /// Most recent executions, oldest first, at most `ALT_HISTORY_CAP`.
    history: Vec<Execution>,
    /// Executions ever folded in (the task's model version; keeps
    /// counting past the retention cap).
    observed: u64,
}

/// Model store + pure prediction logic, shared by the threaded service
/// and the batch experiment path. Every task is bound to a
/// `PredictorPolicy` (explicitly via `configure`, or pinned to the
/// store-wide default the first time it is trained/observed); plans,
/// observes, and failure retries route by that binding.
pub struct ModelStore {
    pub k: usize,
    pub capacity_gb: f64,
    backend: Backend,
    models: std::collections::BTreeMap<String, TaskModels>,
    /// Per-task policy bindings; tasks absent here use `default_policy`.
    policies: std::collections::BTreeMap<String, PredictorPolicy>,
    /// Trained state for tasks bound to non-KS+ policies.
    alt: std::collections::BTreeMap<String, AltModel>,
    default_policy: PredictorPolicy,
}

impl ModelStore {
    pub fn new(k: usize, capacity_gb: f64, backend: Backend) -> Self {
        ModelStore {
            k,
            capacity_gb,
            backend,
            models: Default::default(),
            policies: Default::default(),
            alt: Default::default(),
            default_policy: PredictorPolicy::KsPlus,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn has_task(&self, task: &str) -> bool {
        self.models.contains_key(task) || self.alt.contains_key(task)
    }

    pub fn tasks(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.extend(self.alt.keys().filter(|t| !self.models.contains_key(*t)).cloned());
        v.sort();
        v
    }

    /// Policy that would serve this task right now.
    pub fn policy_of(&self, task: &str) -> PredictorPolicy {
        self.policies.get(task).copied().unwrap_or(self.default_policy)
    }

    /// Policy new (unbound) tasks are pinned to when first trained.
    pub fn default_policy(&self) -> PredictorPolicy {
        self.default_policy
    }

    pub fn set_default_policy(&mut self, policy: PredictorPolicy) {
        self.default_policy = policy;
    }

    /// Bind `task` to `policy`, returning the previous effective policy.
    /// Trained state is kept per strategy family: rebinding back to KS+
    /// re-serves any existing sufficient-statistics models; rebinding to
    /// another strategy refits its predictor from the task's retained
    /// non-KS+ history (if any).
    pub fn configure(&mut self, task: &str, policy: PredictorPolicy) -> PredictorPolicy {
        let prev = self.policy_of(task);
        self.policies.insert(task.to_string(), policy);
        if policy != PredictorPolicy::KsPlus {
            if let Some(am) = self.alt.get_mut(task) {
                if am.policy != policy {
                    let mut pred = policy.build(self.k, self.capacity_gb);
                    if !am.history.is_empty() {
                        pred.train(&am.history);
                    }
                    am.policy = policy;
                    am.pred = pred;
                }
            }
        }
        prev
    }

    /// Resolve the task's policy, pinning the current default for a task
    /// seen for the first time — changing the store default later only
    /// reroutes tasks that have no recorded binding yet.
    fn bind_policy(&mut self, task: &str) -> PredictorPolicy {
        if let Some(p) = self.policies.get(task) {
            return *p;
        }
        let p = self.default_policy;
        self.policies.insert(task.to_string(), p);
        p
    }

    /// Fold one execution's aligned segment rows into the task's
    /// accumulators WITHOUT refitting the closed forms. Returns whether
    /// anything was folded (sample-less executions are no-ops).
    fn fold_observation(&mut self, task: &str, e: &Execution) -> bool {
        if e.samples.is_empty() {
            return false;
        }
        let k = self.k;
        // Steady state allocates no task-name String: only the first
        // observation of a task inserts a key.
        if !self.models.contains_key(task) {
            self.models.insert(task.to_string(), TaskModels::empty(k));
        }
        let tm = self.models.get_mut(task).expect("inserted above");
        let (starts, peaks) = KsPlus::aligned_rows(k, e);
        for j in 0..k {
            tm.stats[j].push(e.input_mb, starts[j]);
            tm.stats[k + j].push(e.input_mb, peaks[j]);
        }
        tm.fallback_peak = tm.fallback_peak.max(e.peak());
        tm.observed += 1;
        true
    }

    /// Fold ONE finished execution into the task's models under its
    /// bound policy. For KS+ this segments only the new execution (a
    /// single `get_segments` call) and updates the 2k
    /// sufficient-statistic accumulators + closed-form refits in O(k) —
    /// history is never revisited. Non-KS+ policies have no incremental
    /// closed form: the execution is appended to the task's retained
    /// history and the predictor is refitted. Returns `(folded, count)`:
    /// whether the execution was actually folded in (sample-less
    /// executions are ignored — nothing to learn) and the task's total
    /// observation count. `folded` is the single source of truth for
    /// "did the models change", so callers counting observations never
    /// drift from the store's skip policy.
    pub fn observe(&mut self, task: &str, e: &Execution) -> (bool, u64) {
        match self.bind_policy(task) {
            PredictorPolicy::KsPlus => {
                let folded = self.fold_observation(task, e);
                let k = self.k;
                match self.models.get_mut(task) {
                    None => (false, 0),
                    Some(tm) => {
                        if folded {
                            tm.refit(k);
                        }
                        (folded, tm.observed)
                    }
                }
            }
            policy => {
                if e.samples.is_empty() {
                    let count = self.alt.get(task).map(|am| am.observed).unwrap_or(0);
                    return (false, count);
                }
                let (k, capacity) = (self.k, self.capacity_gb);
                let am = self.alt.entry(task.to_string()).or_insert_with(|| AltModel {
                    policy,
                    pred: policy.build(k, capacity),
                    history: Vec::new(),
                    observed: 0,
                });
                am.history.push(e.clone());
                if am.history.len() > ALT_HISTORY_CAP {
                    // Sliding retention window: drop the oldest.
                    am.history.remove(0);
                }
                am.observed += 1;
                am.pred.train(&am.history);
                (true, am.observed)
            }
        }
    }

    /// Train (or retrain) one task from scratch under its bound policy:
    /// discards any prior state for the task and fits the history fresh.
    /// For KS+ this folds into fresh accumulators and refits once at the
    /// end — bit-identical to streaming the same history through
    /// `observe` (the refit is a pure function of the accumulators). A
    /// history with nothing to learn from (empty, or containing only
    /// sample-less executions) keeps existing models (unchanged
    /// empty-history policy).
    pub fn train(&mut self, task: &str, history: &[Execution]) {
        if !history.iter().any(|e| !e.samples.is_empty()) {
            return;
        }
        match self.bind_policy(task) {
            PredictorPolicy::KsPlus => {
                self.models.remove(task);
                for e in history {
                    self.fold_observation(task, e);
                }
                let k = self.k;
                if let Some(tm) = self.models.get_mut(task) {
                    tm.refit(k);
                }
            }
            policy => {
                let mut filtered: Vec<Execution> =
                    history.iter().filter(|e| !e.samples.is_empty()).cloned().collect();
                let observed = filtered.len() as u64;
                // Retention window: keep (and fit) the most recent cap.
                if filtered.len() > ALT_HISTORY_CAP {
                    filtered.drain(..filtered.len() - ALT_HISTORY_CAP);
                }
                let mut pred = policy.build(self.k, self.capacity_gb);
                pred.train(&filtered);
                self.alt.insert(
                    task.to_string(),
                    AltModel { policy, pred, history: filtered, observed },
                );
            }
        }
    }

    /// Plan a batch of requests; all trained-KS+ requests share ONE
    /// backend predict call. Tasks with no trained model under their
    /// bound policy get a capacity-safe flat fallback. Convenience
    /// wrapper over `plan_batch_into` that drops provenance.
    pub fn plan_batch(&self, requests: &[(&str, f64)]) -> Vec<StepPlan> {
        self.plan_batch_outcomes(requests).into_iter().map(|o| o.plan).collect()
    }

    /// Like `plan_batch`, but keeps per-plan provenance.
    pub fn plan_batch_outcomes(&self, requests: &[(&str, f64)]) -> Vec<PlanOutcome> {
        let mut scratch = PlanScratch::default();
        self.plan_batch_into(requests, &mut scratch);
        scratch.plans
    }

    /// Allocation-lean batch planning: task names are borrowed and every
    /// intermediate buffer lives in the caller's reusable `scratch`;
    /// results land in `scratch.plans` in request order. Requests route
    /// by each task's bound policy: trained KS+ tasks ride the single
    /// batched backend predict exactly as before the policy seam (the
    /// model/scale sequence is unchanged, keeping KS+ plans
    /// bit-identical); non-KS+ tasks are served by their own predictor;
    /// anything untrained gets the flat capacity-safe default.
    pub fn plan_batch_into(&self, requests: &[(&str, f64)], s: &mut PlanScratch) {
        s.models.clear();
        s.xq.clear();
        s.scale.clear();
        s.meta.clear();
        s.plans.clear();
        for (task, input) in requests {
            match self.policy_of(*task) {
                PredictorPolicy::KsPlus => match self.models.get(*task) {
                    None => s.meta.push(PlanMeta::Fallback),
                    Some(tm) => {
                        for m in &tm.start_models {
                            s.models.push(*m);
                            s.xq.push(*input);
                            s.scale.push(TIME_UNDERPREDICT);
                        }
                        for m in &tm.peak_models {
                            s.models.push(*m);
                            s.xq.push(*input);
                            s.scale.push(MEM_OVERPREDICT);
                        }
                        s.meta.push(PlanMeta::Ks { version: tm.observed });
                    }
                },
                policy => match self.alt.get(*task) {
                    Some(am) if am.observed > 0 => s.meta.push(PlanMeta::Direct {
                        plan: am.pred.plan(*input),
                        predictor: policy.name(),
                        version: am.observed,
                    }),
                    _ => s.meta.push(PlanMeta::Fallback),
                },
            }
        }
        self.backend.predict_into(&s.models, &s.xq, &s.scale, &mut s.flat);
        let mut off = 0usize;
        for meta in s.meta.drain(..) {
            match meta {
                PlanMeta::Ks { version } => {
                    let starts = &s.flat[off..off + self.k];
                    let peaks = &s.flat[off + self.k..off + 2 * self.k];
                    off += 2 * self.k;
                    // Offsets already applied via `scale`; identity here.
                    s.plans.push(PlanOutcome {
                        plan: KsPlus::assemble_plan(starts, peaks, 1.0, 1.0, self.capacity_gb),
                        predictor: PredictorPolicy::KsPlus.name(),
                        model_version: version,
                        fallback_reason: None,
                    });
                }
                PlanMeta::Direct { plan, predictor, version } => s.plans.push(PlanOutcome {
                    plan,
                    predictor,
                    model_version: version,
                    fallback_reason: None,
                }),
                PlanMeta::Fallback => {
                    // Nothing learned for this task under its policy:
                    // serve the capacity-safe flat default and say so.
                    let peak = self.capacity_gb / 4.0;
                    s.plans.push(PlanOutcome {
                        plan: StepPlan::flat(peak.min(self.capacity_gb)),
                        predictor: PredictorPolicy::DefaultLimits.name(),
                        model_version: 0,
                        fallback_reason: Some(FALLBACK_UNTRAINED),
                    });
                }
            }
        }
    }

    /// KS+ retry strategy (Section II-C) for a reported OOM — the
    /// policy-agnostic legacy entry point.
    pub fn on_failure(&self, prev: &StepPlan, fail_time: f64) -> StepPlan {
        self.on_failure_for(None, prev, fail_time).plan
    }

    /// Retry strategy routed by the failed task's bound policy. A
    /// task-less report (and any task bound to KS+) gets the KS+
    /// segment-rescaling strategy; other policies use their own retry
    /// (Witt/DefaultLimits double, Tovar-PPM jumps to the machine max,
    /// k-Segments offsets the failed segment).
    pub fn on_failure_for(
        &self,
        task: Option<&str>,
        prev: &StepPlan,
        fail_time: f64,
    ) -> RetryOutcome {
        let policy = task.map(|t| self.policy_of(t)).unwrap_or(PredictorPolicy::KsPlus);
        let plan = match policy {
            // Stateless plan math: delegate to a throwaway KsPlus with
            // our capacity. (The strategy uses no trained state.)
            PredictorPolicy::KsPlus => {
                KsPlus::new(self.k, self.capacity_gb).on_failure(prev, fail_time, 1)
            }
            p => match task.and_then(|t| self.alt.get(t)) {
                // A trained instance may carry state the retry uses
                // (e.g. Tovar's first allocation as the doubling base).
                Some(am) => am.pred.on_failure(prev, fail_time, 1),
                None => p.build(self.k, self.capacity_gb).on_failure(prev, fail_time, 1),
            },
        };
        RetryOutcome { plan, predictor: policy.name() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::Predictor;
    use crate::util::rng::Rng;

    fn two_phase_exec(input: f64, rng: &mut Rng) -> Execution {
        let d1 = ((input * 0.01) as usize).max(2);
        let d2 = ((input * 0.003) as usize).max(1);
        let mut s = vec![input * 0.0005; d1];
        s.extend(vec![input * 0.001; d2]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new("bwa", input, 1.0, s)
    }

    #[test]
    fn backend_spec_availability_tracks_feature() {
        assert!(BackendSpec::Native.available());
        assert_eq!(BackendSpec::Pjrt(None).available(), cfg!(feature = "pjrt"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_is_runtime_error_without_feature() {
        let err = BackendSpec::Pjrt(None).build().err().expect("must not build");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
    }

    #[test]
    fn store_matches_ksplus_predictor() {
        let mut rng = Rng::new(1);
        let hist: Vec<Execution> =
            (0..30).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let mut pred = KsPlus::new(2, 128.0);
        pred.train(&hist);
        let plans = store.plan_batch(&[("bwa", 8000.0)]);
        let want = pred.plan(8000.0);
        assert_eq!(plans[0].k(), want.k());
        for i in 0..want.k() {
            assert!((plans[0].starts[i] - want.starts[i]).abs() < 1e-9, "{plans:?} vs {want:?}");
            assert!((plans[0].peaks[i] - want.peaks[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn unknown_task_gets_fallback() {
        let store = ModelStore::new(2, 128.0, Backend::Native);
        let plans = store.plan_batch(&[("mystery", 100.0)]);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].k(), 1);
        assert!(plans[0].peaks[0] <= 128.0);
    }

    #[test]
    fn batch_of_mixed_tasks() {
        let mut rng = Rng::new(2);
        let hist: Vec<Execution> =
            (0..20).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let reqs: Vec<(&str, f64)> =
            vec![("bwa", 4000.0), ("mystery", 1.0), ("bwa", 8000.0)];
        let plans = store.plan_batch(&reqs);
        assert_eq!(plans.len(), 3);
        assert!(plans[0].peaks.last() < plans[2].peaks.last());
        assert!(plans.iter().all(|p| p.is_valid()));
    }

    #[test]
    fn scratch_reuse_matches_fresh_plan_batch() {
        // plan_batch_into over a dirty, reused scratch must produce the
        // same plans as a fresh plan_batch call, batch after batch.
        let mut rng = Rng::new(9);
        let hist: Vec<Execution> =
            (0..20).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(3, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let mut scratch = PlanScratch::default();
        for round in 0..4 {
            let reqs: Vec<(&str, f64)> = vec![
                ("bwa", 3000.0 + round as f64 * 500.0),
                ("mystery", 1.0),
                ("bwa", 9000.0 - round as f64 * 250.0),
            ];
            store.plan_batch_into(&reqs, &mut scratch);
            let fresh = store.plan_batch(&reqs);
            assert_eq!(scratch.plans.len(), fresh.len(), "round {round}");
            for (o, f) in scratch.plans.iter().zip(&fresh) {
                assert_eq!(&o.plan, f, "round {round}");
            }
        }
    }

    #[test]
    fn observe_fold_is_bit_identical_to_batch_train() {
        // The tentpole equivalence: batch train == fold of observe, with
        // exactly equal (not merely close) model outputs.
        let mut rng = Rng::new(4);
        let hist: Vec<Execution> =
            (0..25).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut batch = ModelStore::new(3, 128.0, Backend::Native);
        batch.train("bwa", &hist);
        let mut incr = ModelStore::new(3, 128.0, Backend::Native);
        for (i, e) in hist.iter().enumerate() {
            assert_eq!(incr.observe("bwa", e), (true, i as u64 + 1));
        }
        for input in [1500.0, 4000.0, 8000.0, 13000.0] {
            let a = batch.plan_batch(&[("bwa", input)]);
            let b = incr.plan_batch(&[("bwa", input)]);
            assert_eq!(a[0].starts, b[0].starts, "input {input}");
            assert_eq!(a[0].peaks, b[0].peaks, "input {input}");
        }
    }

    #[test]
    fn observe_interleaved_matches_scratch_retrained_ksplus() {
        // Observing one execution at a time must track a KsPlus predictor
        // retrained from scratch on the same prefix, within 1e-9.
        let mut rng = Rng::new(6);
        let hist: Vec<Execution> =
            (0..16).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        for (i, e) in hist.iter().enumerate() {
            store.observe("bwa", e);
            let mut scratch = KsPlus::new(2, 128.0);
            scratch.train(&hist[..=i]);
            let want = scratch.plan(6000.0);
            let got = store.plan_batch(&[("bwa", 6000.0)]);
            assert_eq!(got[0].k(), want.k(), "after {} observations", i + 1);
            for j in 0..want.k() {
                assert!((got[0].starts[j] - want.starts[j]).abs() < 1e-9);
                assert!((got[0].peaks[j] - want.peaks[j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn observe_segments_only_the_new_execution() {
        // The O(k) claim, asserted by op count: one observe = exactly one
        // get_segments call, no matter how much history is accumulated.
        use crate::segments::algorithm::SEG_CALLS;
        let mut rng = Rng::new(8);
        let hist: Vec<Execution> =
            (0..40).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(4, 128.0, Backend::Native);
        store.train("bwa", &hist);
        for e in hist.iter().take(5) {
            let before = SEG_CALLS.with(|c| c.get());
            store.observe("bwa", e);
            let after = SEG_CALLS.with(|c| c.get());
            assert_eq!(after - before, 1, "observe re-segmented history");
        }
        // Batch train over n executions segments each exactly once.
        let before = SEG_CALLS.with(|c| c.get());
        store.train("bwa", &hist);
        let after = SEG_CALLS.with(|c| c.get());
        assert_eq!(after - before, hist.len() as u64);
    }

    #[test]
    fn observe_ignores_empty_executions() {
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        assert_eq!(
            store.observe("bwa", &Execution::new("bwa", 100.0, 1.0, vec![])),
            (false, 0)
        );
        assert!(!store.has_task("bwa"));
        let mut rng = Rng::new(10);
        store.observe("bwa", &two_phase_exec(4000.0, &mut rng));
        assert_eq!(
            store.observe("bwa", &Execution::new("bwa", 100.0, 1.0, vec![])),
            (false, 1)
        );
        assert!(store.plan_batch(&[("bwa", 4000.0)])[0].is_valid());
    }

    #[test]
    fn train_with_nothing_to_learn_keeps_existing_models() {
        // A retrain whose history carries no usable samples must not
        // delete the task's learned models (same policy as an empty
        // history) — neither fully empty nor all-sample-less histories.
        let mut rng = Rng::new(12);
        let hist: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let before = store.plan_batch(&[("bwa", 5000.0)]);
        store.train("bwa", &[]);
        store.train("bwa", &[Execution::new("bwa", 100.0, 1.0, vec![])]);
        assert!(store.has_task("bwa"));
        let after = store.plan_batch(&[("bwa", 5000.0)]);
        assert_eq!(before, after);
    }

    #[test]
    fn failure_rescaling_delegates_to_ksplus() {
        let store = ModelStore::new(2, 128.0, Backend::Native);
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let next = store.on_failure(&prev, 60.0);
        assert_eq!(next.starts, vec![0.0, 60.0]);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in PredictorPolicy::ALL {
            assert_eq!(PredictorPolicy::parse(p.name()), Some(p), "{p:?}");
        }
        assert_eq!(PredictorPolicy::parse("nope"), None);
        assert_eq!(PredictorPolicy::names().len(), PredictorPolicy::ALL.len());
        // Default policy is the KS+ fast path.
        let store = ModelStore::new(2, 128.0, Backend::Native);
        assert_eq!(store.default_policy(), PredictorPolicy::KsPlus);
        assert_eq!(store.policy_of("anything"), PredictorPolicy::KsPlus);
    }

    #[test]
    fn ksplus_outcome_carries_provenance() {
        let mut rng = Rng::new(21);
        let hist: Vec<Execution> =
            (0..15).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let out = store.plan_batch_outcomes(&[("bwa", 5000.0), ("mystery", 10.0)]);
        assert_eq!(out[0].predictor, "ksplus");
        assert_eq!(out[0].model_version, 15);
        assert_eq!(out[0].fallback_reason, None);
        assert_eq!(out[1].predictor, "default-limits");
        assert_eq!(out[1].model_version, 0);
        assert_eq!(out[1].fallback_reason, Some(FALLBACK_UNTRAINED));
        // Fallback plan stays the capacity-safe flat quarter.
        assert_eq!(out[1].plan, StepPlan::flat(32.0));
    }

    #[test]
    fn witt_policy_trains_plans_and_matches_offline_predictor() {
        use crate::predictor::witt::{Offset, WittLr};
        let mut rng = Rng::new(22);
        let hist: Vec<Execution> =
            (0..20).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        assert_eq!(store.configure("bwa", PredictorPolicy::WittLr), PredictorPolicy::KsPlus);
        store.train("bwa", &hist);
        let out = store.plan_batch_outcomes(&[("bwa", 6000.0)]);
        assert_eq!(out[0].predictor, "witt-lr");
        assert_eq!(out[0].model_version, 20);
        assert_eq!(out[0].fallback_reason, None);
        let mut want = WittLr::new(128.0, Offset::MeanSigma);
        want.train(&hist);
        assert_eq!(out[0].plan, want.plan(6000.0));
        // KS+ state for other tasks is untouched and still batched.
        store.train("other", &hist);
        let both = store.plan_batch_outcomes(&[("other", 6000.0), ("bwa", 6000.0)]);
        assert_eq!(both[0].predictor, "ksplus");
        assert_eq!(both[1].predictor, "witt-lr");
    }

    #[test]
    fn alt_policy_observe_refits_incrementally() {
        use crate::predictor::witt::{Offset, WittLr};
        let mut rng = Rng::new(23);
        let hist: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.configure("bwa", PredictorPolicy::WittLr);
        for (i, e) in hist.iter().enumerate() {
            assert_eq!(store.observe("bwa", e), (true, i as u64 + 1));
            let got = store.plan_batch_outcomes(&[("bwa", 5000.0)]);
            let mut want = WittLr::new(128.0, Offset::MeanSigma);
            want.train(&hist[..=i]);
            assert_eq!(got[0].plan, want.plan(5000.0), "after {} observes", i + 1);
            assert_eq!(got[0].model_version, i as u64 + 1);
        }
        // Sample-less executions are ignored, as on the KS+ path.
        assert_eq!(
            store.observe("bwa", &Execution::new("bwa", 1.0, 1.0, vec![])),
            (false, 10)
        );
    }

    #[test]
    fn alt_history_retention_is_bounded() {
        use crate::predictor::witt::{Offset, WittLr};
        // Past the cap, the model version keeps counting but the refit
        // window slides: the served model matches a predictor trained on
        // only the most recent ALT_HISTORY_CAP executions.
        let total = ALT_HISTORY_CAP + 24;
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.configure("bwa", PredictorPolicy::WittLr);
        let execs: Vec<Execution> = (0..total)
            .map(|i| {
                let input = 1000.0 + i as f64;
                Execution::new("bwa", input, 1.0, vec![0.001 * input, 0.002 * input])
            })
            .collect();
        for (i, e) in execs.iter().enumerate() {
            assert_eq!(store.observe("bwa", e), (true, i as u64 + 1));
        }
        let out = store.plan_batch_outcomes(&[("bwa", 5000.0)]);
        assert_eq!(out[0].model_version, total as u64);
        let mut want = WittLr::new(128.0, Offset::MeanSigma);
        want.train(&execs[total - ALT_HISTORY_CAP..]);
        assert_eq!(out[0].plan, want.plan(5000.0));
        // A batch train beyond the cap fits the most recent window too.
        store.train("bwa", &execs);
        let retrained = store.plan_batch_outcomes(&[("bwa", 5000.0)]);
        assert_eq!(retrained[0].model_version, total as u64);
        assert_eq!(retrained[0].plan, want.plan(5000.0));
    }

    #[test]
    fn default_policy_pins_at_first_training() {
        let mut rng = Rng::new(24);
        let hist: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.set_default_policy(PredictorPolicy::TovarPpm);
        store.train("bwa", &hist);
        // Switching the default later must not reroute the trained task.
        store.set_default_policy(PredictorPolicy::KsPlus);
        assert_eq!(store.policy_of("bwa"), PredictorPolicy::TovarPpm);
        let out = store.plan_batch_outcomes(&[("bwa", 5000.0)]);
        assert_eq!(out[0].predictor, "tovar-ppm");
        assert_eq!(out[0].plan.k(), 1, "tovar serves a flat first allocation");
    }

    #[test]
    fn configure_switch_refits_from_retained_history() {
        use crate::predictor::tovar::{RetryMode, TovarPpm};
        let mut rng = Rng::new(25);
        let hist: Vec<Execution> =
            (0..12).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.configure("bwa", PredictorPolicy::WittLr);
        store.train("bwa", &hist);
        // Rebinding to tovar refits immediately from the retained history.
        assert_eq!(store.configure("bwa", PredictorPolicy::TovarPpm), PredictorPolicy::WittLr);
        let out = store.plan_batch_outcomes(&[("bwa", 5000.0)]);
        assert_eq!(out[0].predictor, "tovar-ppm");
        assert_eq!(out[0].model_version, 12);
        let mut want = TovarPpm::new(128.0, RetryMode::MachineMax);
        want.train(&hist);
        assert_eq!(out[0].plan, want.plan(5000.0));
    }

    #[test]
    fn failure_routed_by_task_policy() {
        let mut rng = Rng::new(26);
        let hist: Vec<Execution> =
            (0..8).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.configure("wt", PredictorPolicy::WittLr);
        store.train("wt", &hist);
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        // Task-less and KS+-bound reports rescale segment starts.
        let ks = store.on_failure_for(None, &prev, 60.0);
        assert_eq!(ks.predictor, "ksplus");
        assert_eq!(ks.plan.starts, vec![0.0, 60.0]);
        assert_eq!(store.on_failure_for(Some("untrained"), &prev, 60.0).predictor, "ksplus");
        // A Witt-bound task doubles the failed peak instead.
        let wt = store.on_failure_for(Some("wt"), &prev, 60.0);
        assert_eq!(wt.predictor, "witt-lr");
        assert_eq!(wt.plan, StepPlan::flat(16.0));
    }

    #[test]
    fn retrain_replaces_models() {
        let mut rng = Rng::new(3);
        let h1: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(3000.0, &mut rng)).collect();
        let h2: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(9000.0, &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &h1);
        let p1 = store.plan_batch(&[("bwa", 5000.0)]);
        store.train("bwa", &h2);
        let p2 = store.plan_batch(&[("bwa", 5000.0)]);
        // Different training data -> different (still valid) plans.
        assert!(p1[0].is_valid() && p2[0].is_valid());
    }
}
